"""Serving demo: the paper's 4-port wrapper as a continuous-batching engine.

Each engine macro-cycle services EVICT (W) > PREFILL (W) > DECODE (R/W) >
STATUS (R) in priority order — one traversal of the KV-cache state per cycle,
exactly as the wrapper walks its FSM. Compare against --single-port, which
services one port per cycle (the bare-macro baseline).

    PYTHONPATH=src python examples/serve_multiport.py
    PYTHONPATH=src python examples/serve_multiport.py --single-port
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--single-port", action="store_true")
    ap.add_argument("--kernel-mode", default="pallas",
                    choices=["pallas", "reference"],
                    help="pallas: fused one-traversal data plane (default); "
                         "reference: two-pass jnp oracle")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = MultiPortEngine(params, cfg, slots=4, max_len=64, prefill_bucket=8,
                          kernel_mode=args.kernel_mode,
                          single_port=args.single_port)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        eng.submit(list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8)))),
                   max_new=args.max_new)

    t0 = time.perf_counter()
    while eng.pending_work():
        status = eng.step()
        if status and eng.cycles % 5 == 0:
            print(f"cycle {status['cycle']:4d} queue={status['queue']} "
                  f"active={status['active']} lens={status['lens']}")
    dt = time.perf_counter() - t0

    mode = "single-port" if args.single_port else f"4-port/{args.kernel_mode}"
    toks = sum(len(r.generated) for r in eng.finished)
    print(f"\n[{mode}] {len(eng.finished)} requests, {toks} tokens, "
          f"{eng.cycles} macro-cycles, {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)")
    print(f"pool: {eng.pool_traversals} physical traversals "
          f"({eng.steady_decode_traversals / max(eng.steady_decode_steps, 1):.2f}"
          f" per steady decode step; claim C1: ~1 fused vs 2 two-pass)")
    print("port schedule of the first 6 cycles:",
          [tuple("EPDS"[p] for p in c) for c in eng.port_log[:6]])


if __name__ == "__main__":
    main()
