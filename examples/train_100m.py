"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps with the full production loop — checkpointing, heartbeats,
straggler detection, restart-safe data.

    PYTHONPATH=src python examples/train_100m.py --steps 300 --batch 4

On this container's single CPU core a step takes O(seconds); pass --steps 20
for a smoke run. The same driver runs unchanged on a TPU slice (the mesh and
shardings come from repro.launch).
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.train.loop import RunnerConfig, TrainingRunner
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def config_100m() -> ArchConfig:
    """~100M params: 12L, d=768, GQA 12/4 heads, untied head, 32k vocab."""
    return ArchConfig(
        arch_id="repro-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32_000, qkv_bias=True,
        q_chunk=256, remat="block")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    tcfg = TrainConfig(peak_lr=6e-4, warmup_steps=20, total_steps=args.steps,
                       adamw=AdamWConfig(weight_decay=0.1))
    params = init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"params: {n/1e6:.1f}M")

    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
    loader = ShardedLoader(cfg, DataConfig(seed=0), batch=args.batch,
                           seq=args.seq)
    runner = TrainingRunner(
        step, state, loader.get,
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50, async_ckpt=True,
                     heartbeat_dir=args.ckpt + "/hb"))
    runner.run(args.steps)
    ce = [h["ce"] for h in runner.history]
    print(f"ce: first10={sum(ce[:10])/10:.3f}  last10={sum(ce[-10:])/10:.3f}")
    print(f"straggler events: {len(runner.straggler.events)}")


if __name__ == "__main__":
    main()
