"""The paper's Figures 4 & 6, in software: configure the wrapper as 4-, 3-,
2- and 1-port on successive macro-cycles, drive all ports, and print the
clock-generator waveform plus the serviced transactions.

    PYTHONPATH=src python examples/multiport_memory_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (MemorySpec, PortConfig, READ, WRITE, build_schedule,
                        simulate_waveform, step, write_request, read_request,
                        empty_request)


def waveform_ascii(w, names=("CLK ", "CLKP", "BACK", "CLK2")):
    for name, sig in zip(names, (w.clk, w.clkp, w.back, w.clk2)):
        print(f"  {name} " + "".join("▔" if v else "▁" for v in sig))
    sel = "".join(str(p) if p >= 0 else "." for p in w.selected_port)
    print(f"  port {sel}")


def main():
    spec = MemorySpec(num_words=32, word_width=4, num_banks=4)
    storage = spec.init_storage()

    configs = [
        PortConfig((True,) * 4, (WRITE, READ, WRITE, READ)),          # 4-port
        PortConfig((True, True, True, False), (WRITE, READ, READ, READ)),
        PortConfig((True, True, False, False), (WRITE, READ, READ, READ)),
        PortConfig((True, False, False, False), (READ, READ, READ, READ)),
    ]
    print("== clock generator (paper Fig. 4): BACK=N, CLK2=N-1 pulses ==")
    waveform_ascii(simulate_waveform(configs, resolution=12))

    print("\n== functional walk (paper Fig. 6) ==")
    rng = np.random.default_rng(0)
    for cyc, cfg in enumerate(configs):
        sched = build_schedule(cfg)
        reqs = []
        for p in range(4):
            if not cfg.enabled[p]:
                reqs.append(empty_request(4, spec.word_width))
            elif cfg.roles[p] == WRITE:
                reqs.append(write_request(
                    jnp.asarray(rng.integers(0, 32, 4), jnp.int32),
                    jnp.full((4, 4), float(10 * (p + 1)))))
            else:
                reqs.append(read_request(
                    jnp.asarray(rng.integers(0, 32, 4), jnp.int32), 4))
        storage, reads = step(spec, cfg, storage, reqs)
        served = " > ".join("ABCD"[s] + ("W" if cfg.roles[s] == WRITE else "R")
                            for s in sched.slots)
        print(f"cycle {cyc}: {cfg.describe():28s} slots: {served}")
        for p in range(4):
            if cfg.enabled[p] and cfg.roles[p] == READ:
                print(f"    port {'ABCD'[p]} read lane0 -> {np.asarray(reads[p])[0]}")
    print("\n4x transactions per cycle in 4-port mode — one storage traversal.")


if __name__ == "__main__":
    main()
