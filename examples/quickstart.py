"""Quickstart: train a tiny llama-family model on the synthetic chain task.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    tcfg = TrainConfig(peak_lr=2e-3, warmup_steps=5, total_steps=60,
                       adamw=AdamWConfig(weight_decay=0.0))
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
    loader = ShardedLoader(cfg, DataConfig(seed=0), batch=8, seq=32)

    print(f"arch={cfg.arch_id}  params="
          f"{sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")
    for i in range(60):
        state, metrics = step(state, loader.get(i))
        if i % 10 == 0:
            print(f"step {i:3d}  ce={float(metrics['ce']):.4f}  "
                  f"acc={float(metrics['accuracy']):.3f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    print("done — loss should have dropped by >1 nat.")


if __name__ == "__main__":
    main()
