"""Fault-tolerance demo: a training run that crashes twice, restarts from
checkpoints, and finishes with exactly the loss trajectory of an
uninterrupted run (step-addressable data + atomic checkpoints).

    PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.configs import registry
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.distributed.fault import FailureInjector
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.train.loop import RunnerConfig, TrainingRunner
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60,
                       adamw=AdamWConfig(weight_decay=0.0))
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    loader = ShardedLoader(cfg, DataConfig(seed=0), batch=8, seq=16)

    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        clean = TrainingRunner(step, state, loader.get,
                               RunnerConfig(ckpt_dir=d1, ckpt_every=10,
                                            async_ckpt=False))
        clean.run(40)

        faulty = TrainingRunner(
            step, state, loader.get,
            RunnerConfig(ckpt_dir=d2, ckpt_every=10, async_ckpt=False,
                         heartbeat_dir=d2 + "/hb"),
            injector=FailureInjector(fail_at_steps=(13, 27)))
        faulty.run(40)

        print(f"restarts: {faulty.restarts} (crashed at steps 13 and 27)")
        a = {h["step"]: h["ce"] for h in clean.history}
        b = {h["step"]: h["ce"] for h in faulty.history}
        drift = max(abs(a[s] - b[s]) for s in range(30, 40))
        print(f"post-restart loss drift vs uninterrupted run: {drift:.2e}")
        assert drift < 1e-5
        print("OK: recovery is exact — checkpoint + step-addressable data.")
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


if __name__ == "__main__":
    main()
