"""The HLO analyzer is load-bearing for §Roofline — validate it against
hand-countable programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as HA


def _analyze(fn, *args):
    return HA.analyze(jax.jit(fn).lower(*args).compile().as_text())


def test_scan_trip_count_expansion():
    w = jnp.ones((256, 256), jnp.float32)

    def body(c, _):
        return c @ w, None

    def scanned(x):
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    def unrolled(x):
        for _ in range(7):
            x = x @ w
        return x

    x = jnp.ones((256, 256), jnp.float32)
    want = 2 * 256**3 * 7
    a, b = _analyze(scanned, x), _analyze(unrolled, x)
    assert a["dot_flops"] == want, a["dot_flops"]
    assert b["dot_flops"] == want, b["dot_flops"]


def test_nested_scan_multiplies():
    w = jnp.ones((128, 128), jnp.float32)

    def inner(c, _):
        return c @ w, None

    def outer(c, _):
        y, _ = jax.lax.scan(inner, c, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    a = _analyze(f, jnp.ones((128, 128), jnp.float32))
    assert a["dot_flops"] == 2 * 128**3 * 15, a["dot_flops"]


def test_gqa_einsum_flops():
    # einsum with batch dims: [B,H,S,D] x [B,H,D,S] contraction
    def f(q, k):
        return jnp.einsum("bhsd,bhtd->bhst", q, k)
    q = jnp.ones((2, 4, 64, 32), jnp.float32)
    k = jnp.ones((2, 4, 64, 32), jnp.float32)
    a = _analyze(f, q, k)
    want = 2 * 2 * 4 * 64 * 64 * 32
    assert a["dot_flops"] == want, (a["dot_flops"], want)


def test_slice_counts_window_not_operand():
    big = jnp.ones((4096, 256), jnp.float32)      # 4 MB

    def f(x, i):
        return jax.lax.dynamic_slice(x, (i, 0), (16, 256)) * 2.0

    a = _analyze(f, big, jnp.int32(0))
    # refined traffic must be well under one full read of the operand
    assert a["traffic_bytes"] < big.size * 4 * 0.5, a["traffic_bytes"]
    assert a["traffic_bytes_naive"] >= big.size * 4


def test_dus_counts_update_window():
    big = jnp.zeros((4096, 256), jnp.float32)
    upd = jnp.ones((16, 256), jnp.float32)

    def f(x, u, i):
        return jax.lax.dynamic_update_slice(x, u, (i, 0))

    # donate the target so the in-place update isn't preceded by a copy
    jf = jax.jit(f, donate_argnums=0)
    a = HA.analyze(jf.lower(big, upd, jnp.int32(0)).compile().as_text())
    assert a["traffic_bytes"] < big.size * 4, a["traffic_bytes"]


def test_collectives_counted_with_loop_expansion():
    import os
    import subprocess
    import sys
    import textwrap
    root = __file__.rsplit("/tests/", 1)[0]
    env = dict(os.environ)
    env["PYTHONPATH"] = root + "/src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    body = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch import hlo_analysis as HA
        from repro.launch.mesh import make_mesh, use_mesh
        mesh = make_mesh((8,), ("model",))
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        def f(x, ws):
            y, _ = jax.lax.scan(body, x, ws)
            return y
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
        with use_mesh(mesh):
            jf = jax.jit(f, in_shardings=(
                NamedSharding(mesh, P(None, "model")),
                NamedSharding(mesh, P(None, "model", None))))
            a = HA.analyze(jf.lower(x, ws).compile().as_text())
        n = sum(a["collective_counts"].values())
        assert n >= 5, a["collective_counts"]   # one+ per scan iteration
        print("COLL-OK", n)
    """)
    r = subprocess.run([sys.executable, "-c", body], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0 and "COLL-OK" in r.stdout, r.stdout + r.stderr
