"""Unit tests for the fault-tolerance primitives in distributed/fault.py
— previously only exercised indirectly. The chaos harness
(serve/chaos.py) now wires Heartbeat and StragglerDetector into the
serving engine, so their contracts need pinning on their own: heartbeat
files parse and stale detection keys off wall time, the straggler EMA
excludes the outliers it flags, and the failure injector fires each
configured step exactly once.
"""
import time

import pytest

from repro.distributed.fault import (FailureInjector, Heartbeat,
                                     InjectedFailure, StragglerDetector)


# ---------------------------------------------------------------------------
# Heartbeat

def test_heartbeat_beat_writes_step_and_timestamp(tmp_path):
    hb = Heartbeat(str(tmp_path), worker="w3")
    before = time.time()
    hb.beat(17)
    step, stamp = (tmp_path / "heartbeat_w3").read_text().split()
    assert int(step) == 17
    assert before <= float(stamp) <= time.time()
    hb.beat(18)                                      # overwrites, not appends
    assert (tmp_path / "heartbeat_w3").read_text().split()[0] == "18"


def test_heartbeat_stale_workers_timeout_band(tmp_path):
    Heartbeat(str(tmp_path), worker="fresh").beat(1)
    old = tmp_path / "heartbeat_old"
    old.write_text(f"5 {time.time() - 100.0}")
    (tmp_path / "not_a_heartbeat").write_text("ignored")
    assert Heartbeat.stale_workers(str(tmp_path), timeout_s=60) == ["old"]
    assert set(Heartbeat.stale_workers(str(tmp_path), timeout_s=0.0)) \
        == {"fresh", "old"}
    assert Heartbeat.stale_workers(str(tmp_path / "missing"), 60) == []


# ---------------------------------------------------------------------------
# StragglerDetector

def test_straggler_warmup_and_detection():
    d = StragglerDetector(multiplier=3.0, warmup=3)
    assert not d.record(0, 1.0)                      # seeds the EMA
    assert not d.record(1, 100.0)                    # within warmup: never
    d2 = StragglerDetector(multiplier=3.0, warmup=3)
    for s in range(4):
        assert not d2.record(s, 1.0)
    assert d2.record(4, 10.0)                        # 10 > 3 * EMA(=1.0)
    assert d2.events == [{"step": 4, "duration": 10.0, "ema": 1.0}]
    assert not d2.record(5, 1.0)


def test_straggler_does_not_poison_ema():
    """A flagged outlier must NOT be folded into the EMA — otherwise one
    straggler raises the bar and masks the next one."""
    d = StragglerDetector(multiplier=2.0, ema_decay=0.5, warmup=1)
    d.record(0, 1.0)
    d.record(1, 1.0)
    assert d.record(2, 100.0)
    assert d._ema == 1.0                             # unchanged by outlier
    assert d.record(3, 100.0)                        # still flagged
    assert len(d.events) == 2


def test_straggler_ema_tracks_normal_steps():
    d = StragglerDetector(multiplier=3.0, ema_decay=0.9, warmup=1)
    d.record(0, 1.0)
    d.record(1, 2.0)
    assert d._ema == pytest.approx(0.9 * 1.0 + 0.1 * 2.0)


# ---------------------------------------------------------------------------
# FailureInjector

def test_failure_injector_fires_each_step_once():
    inj = FailureInjector(fail_at_steps=(2, 5))
    inj.maybe_fail(0)
    inj.maybe_fail(1)
    with pytest.raises(InjectedFailure, match="step 2"):
        inj.maybe_fail(2)
    inj.maybe_fail(2)                                # restart: no refire
    with pytest.raises(InjectedFailure, match="step 5"):
        inj.maybe_fail(5)
    inj.maybe_fail(5)
