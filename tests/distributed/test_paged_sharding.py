"""Multi-device paged pool: page-aligned KV sharding.

Three layers of coverage:

* a DEVICE-COUNT-PARAMETRIZED token-identity suite (subprocesses with 8
  forced host devices, meshes of 1/2/4/8): sharded pallas == sharded
  reference == the unsharded single-device oracle, through mid-stream
  admissions and slot-pool growth;
* in-process allocation tests against the pool's device-aware CONTROL
  plane (``kv_shards`` without a mesh — the same free lists / home map /
  precheck the sharded data plane runs over): the ``PoolCapacityError``
  full-home-shard regression and, when ``hypothesis`` is installed (CI's
  ``dev`` extra), a property suite over random alloc/append/scrub/free
  traffic — no page ever straddles a shard boundary, no page is ever
  double-assigned, free-list accounting matches capacity;
* shard-plan validation (page-aligned rounding, straddle rejection).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.sharding import kv_shard_plan
from repro.memory.paged_kv import PagedPool, PoolCapacityError

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def run_py(body: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_sharded_engine_token_identical(n_dev):
    """Greedy decode is token-identical across device counts and kernel
    modes — sharded pallas vs sharded reference vs the unsharded oracle —
    with requests admitted mid-stream and the slot pool growing past its
    initial size along the way."""
    out = run_py(f"""
        import jax, numpy as np
        from repro.configs import registry
        from repro.launch.mesh import make_kv_mesh
        from repro.models import init_params
        from repro.serve.engine import MultiPortEngine

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(17)
        prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 9))))
                   for _ in range(5)]

        def serve(kernel_mode, mesh):
            eng = MultiPortEngine(params, cfg, slots=2, max_slots=8,
                                  max_len=64, chunk_tokens=8, seq_tile=8,
                                  kernel_mode=kernel_mode, mesh=mesh)
            for p in prompts[:3]:
                eng.submit(p, max_new=3)
            for _ in range(3):            # first admissions reach decode
                if eng.pending_work():
                    eng.step()
            for p in prompts[3:]:         # mid-stream admissions
                eng.submit(p, max_new=3)
            done = eng.run(max_cycles=1000)
            assert len(done) == len(prompts)
            return eng, {{r.rid: tuple(r.generated) for r in done}}

        oracle_eng, oracle = serve("pallas", None)
        mesh = make_kv_mesh({n_dev})
        ep, tp = serve("pallas", mesh)
        er, tr = serve("reference", mesh)
        assert tp == oracle, ("pallas", tp, oracle)
        assert tr == oracle, ("reference", tr, oracle)
        assert ep.n_slots > 2, "slot pool must have grown"
        assert ep.n_kv_shards == {n_dev}
        # the pool really sharded: every sequence's pages stayed on one shard
        # during the run (freed on completion), and accounting adds up
        assert sum(ep.steady_decode_tile_reads_by_dev) == \\
            ep.steady_decode_tile_reads
        assert ep.kv_tile_balance >= 1.0
        print("TOKENS-OK", {n_dev})
    """)
    assert f"TOKENS-OK {n_dev}" in out


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
def test_sharded_split_kv_token_identical(n_dev):
    """Split-KV flash-decode under data-parallel KV: with both split
    stages inside the shard_map'd launch, each device partitions ITS rows'
    live ranges from shard-local lengths — greedy decode stays
    token-identical to the unsharded SERIAL oracle at every device count,
    and split counts never perturb the per-device tile accounting."""
    out = run_py(f"""
        import jax, numpy as np
        from repro.configs import registry
        from repro.launch.mesh import make_kv_mesh
        from repro.models import init_params
        from repro.serve.engine import MultiPortEngine

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(23)
        # ragged prompts: one long among shorts, so shards see uneven
        # live lengths (the per-shard split-bound case)
        prompts = [list(rng.integers(0, cfg.vocab, n))
                   for n in (24, 3, 11, 5)]

        def serve(mesh, splits):
            eng = MultiPortEngine(params, cfg, slots=4, max_slots=8,
                                  max_len=64, chunk_tokens=8, seq_tile=8,
                                  kernel_mode="pallas", mesh=mesh,
                                  num_kv_splits=splits)
            for p in prompts:
                eng.submit(list(p), max_new=4)
            done = eng.run(max_cycles=1000)
            assert len(done) == len(prompts)
            return eng, {{r.rid: tuple(r.generated) for r in done}}

        _, oracle = serve(None, 1)
        mesh = make_kv_mesh({n_dev})
        for splits in (1, 4):
            eng, toks = serve(mesh, splits)
            assert toks == oracle, (splits, toks, oracle)
            assert eng.n_kv_shards == {n_dev}
            assert sum(eng.steady_decode_tile_reads_by_dev) == \\
                eng.steady_decode_tile_reads
        print("SPLIT-SHARD-OK", {n_dev})
    """)
    assert f"SPLIT-SHARD-OK {n_dev}" in out


def test_kv_shard_plan_page_aligned():
    """The shard plan never lets a page straddle a boundary: pools round UP
    to whole pages per shard, and a hand-built misaligned plan is
    rejected."""
    plan = kv_shard_plan(4, n_pages=10, page_tokens=8)
    assert plan.n_pages == 12 and plan.pages_per_shard == 3
    assert plan.words_per_shard == 24
    assert plan.words_per_shard % plan.page_tokens == 0
    assert [plan.shard_of_page(p) for p in range(12)] == \
        [0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]
    with pytest.raises(ValueError, match="page-aligned"):
        from repro.distributed.sharding import KVShardPlan
        KVShardPlan(n_shards=4, n_pages=10, page_tokens=8)
    # a pool created with kv_shards rounds itself up the same way
    pool = PagedPool.create(n_pages=10, page_tokens=8, word_width=8,
                            num_banks=4, kv_shards=4)
    assert pool.plan.n_pages == 12
    assert len(pool.free_pages) == 12


def test_kv_pool_spec_validation():
    """kv_pool_spec rejects straddling geometry and missing axes even on a
    single-device mesh (the divisibility rules are mesh-size-independent),
    and the dry-run stand-in ``launch.specs.kv_pool_specs`` mirrors the
    geometry ``PagedPool.create`` actually allocates."""
    from repro.distributed.sharding import kv_pool_spec
    from repro.launch.mesh import make_kv_mesh
    from repro.launch.specs import kv_pool_specs
    mesh = make_kv_mesh(1)
    assert tuple(kv_pool_spec(mesh, num_words=96, page_tokens=8)) == \
        ("kv", None)
    with pytest.raises(ValueError, match="straddles a page"):
        kv_pool_spec(mesh, num_words=96, page_tokens=5)
    with pytest.raises(ValueError, match="no 'model' axis"):
        kv_pool_spec(mesh, num_words=96, page_tokens=8, axis="model")
    # the no-allocation stand-in and the real pool agree on the rounded
    # page count, the lane-padded word width, and the storage sharding spec
    # (10 pages stay 10 on one shard; a 4-shard plan rounds them up to 12)
    sds, ns = kv_pool_specs(mesh, n_pages=10, page_tokens=8, word_width=24)
    pool = PagedPool.create(n_pages=10, page_tokens=8, word_width=24,
                            num_banks=4, kv_shards=1)
    assert sds.shape == pool.storage.shape == (80, 128)
    assert tuple(ns.spec) == ("kv", None)
    pool4 = PagedPool.create(n_pages=10, page_tokens=8, word_width=24,
                             num_banks=4, kv_shards=4)
    from repro.distributed.sharding import kv_shard_plan
    assert pool4.storage.shape[0] == \
        kv_shard_plan(4, n_pages=10, page_tokens=8).num_words == 96


def test_capacity_error_full_home_shard_before_mutation():
    """Regression pin for PoolCapacityError under device-aware allocation:
    when a sequence's HOME shard is full, the cycle raises the named error
    BEFORE any mutation even though other shards still hold free pages —
    pages never spill across shards (the transactional precheck from PR 2,
    now per shard)."""
    # 2 shards x 4 pages x 4 tokens
    pool = PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, kv_shards=2)
    # seq 1 fills shard 0 completely (16 tokens = 4 pages)
    pool.cycle(prefill={"seq": 1, "vectors": np.ones((16, 8), np.float32)})
    assert pool.home_of(1) == 0
    assert len(pool.free_by_shard[0]) == 0
    assert len(pool.free_by_shard[1]) == 4
    # seq 1 wants one more page: home shard 0 is full, shard 1's free pages
    # must NOT be used — named error, nothing mutated
    free_before = [list(f) for f in pool.free_by_shard]
    tables_before = {k: list(v) for k, v in pool.tables.items()}
    with pytest.raises(PoolCapacityError, match="home shard 0"):
        pool.cycle(append={"seq": 1, "vectors": np.ones((1, 8), np.float32)})
    assert [list(f) for f in pool.free_by_shard] == free_before
    assert {k: list(v) for k, v in pool.tables.items()} == tables_before
    assert pool.lengths == {1: 16}
    # a NEW sequence is homed on shard 1 (least loaded with free pages)
    # and still admits fine — the pool as a whole is not wedged
    pool.cycle(prefill={"seq": 2, "vectors": np.ones((8, 8), np.float32)})
    assert pool.home_of(2) == 1
    assert pool.lengths[2] == 8
    # evicting seq 1 returns all four pages to shard 0's free list and the
    # refused grow now succeeds for a fresh sequence homed there
    pool.free(1)
    assert len(pool.free_by_shard[0]) == 4
    pool.cycle(prefill={"seq": 3, "vectors": np.ones((4, 8), np.float32)})
    assert pool.home_of(3) == 0


def test_refused_read_does_not_leak_home_assignment():
    """A cycle refused for an out-of-range READ must not commit the write
    streams' staged home assignments either — a never-admitted sequence
    leaving a phantom entry in the home map would skew every future
    least-loaded placement."""
    pool = PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, kv_shards=2)
    with pytest.raises(IndexError):
        pool.cycle(prefill={"seq": 9, "vectors": np.ones((4, 8), np.float32)},
                   read={"seq": 9, "positions": np.arange(99)})
    assert pool.home_of(9) is None
    assert not pool.home and not pool.tables and not pool.lengths
    assert len(pool.free_pages) == 8


def test_multi_admission_precheck_is_per_shard():
    """A multi-sequence admission whose TOTAL demand fits the pool but
    overflows one home shard is refused up front, atomically."""
    pool = PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, kv_shards=2)
    # both 3-page prompts would be homed round-robin: shard 0 gets seq 5,
    # shard 1 gets seq 6 — fits. A third 3-page prompt in the SAME cycle
    # must overflow someone's 4-page shard while 2 pages sit free overall.
    with pytest.raises(PoolCapacityError, match="never straddle"):
        pool.cycle(prefill=[
            {"seq": 5, "vectors": np.ones((12, 8), np.float32)},
            {"seq": 6, "vectors": np.ones((12, 8), np.float32)},
            {"seq": 7, "vectors": np.ones((12, 8), np.float32)}])
    assert not pool.tables and not pool.lengths and not pool.home
    assert len(pool.free_pages) == 8
    # the two-sequence version commits cleanly on separate shards
    pool.cycle(prefill=[
        {"seq": 5, "vectors": np.ones((12, 8), np.float32)},
        {"seq": 6, "vectors": np.ones((12, 8), np.float32)}])
    assert {pool.home_of(5), pool.home_of(6)} == {0, 1}


def test_allocation_invariants_property():
    """Property (CI installs the ``dev`` extra; skips locally): random
    alloc/append/scrub/free traffic against a sharded pool never produces a
    page outside its owner's home shard (no straddling, by page-aligned
    construction AND by allocation), never double-assigns a page, and the
    free lists always partition exactly the pages no sequence owns."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    N_PAGES, PAGE_TOKENS, WORD = 16, 4, 8

    def check_invariants(pool):
        plan = pool.plan
        owned = [p for t in pool.tables.values() for p in t]
        free = pool.free_pages
        # no double assignment, across tables and free lists
        assert len(owned) == len(set(owned))
        assert len(free) == len(set(free))
        assert not (set(owned) & set(free))
        # accounting matches capacity exactly
        assert sorted(owned + free) == list(range(plan.n_pages))
        # per-shard free lists hold only their own shard's pages
        for s, fl in enumerate(pool.free_by_shard):
            assert all(plan.shard_of_page(p) == s for p in fl)
        for seq, table in pool.tables.items():
            home = pool.home_of(seq)
            # every page of a sequence lives wholly on its home shard:
            # first and last word of each page map to the same shard
            for p in table:
                assert plan.shard_of_page(p) == home
                w0, w1 = p * PAGE_TOKENS, (p + 1) * PAGE_TOKENS - 1
                assert plan.shard_of_word(w0) == plan.shard_of_word(w1) \
                    == home
            # length fits the mapped pages
            assert pool.lengths[seq] <= len(table) * PAGE_TOKENS

    @hyp.settings(max_examples=30, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(kv_shards=st.sampled_from([1, 2, 4]),
               ops=st.lists(
                   st.tuples(st.sampled_from(["grow", "free"]),
                             st.integers(0, 5),       # seq id
                             st.integers(1, 9)),      # token count
                   min_size=1, max_size=24))
    def prop(kv_shards, ops):
        pool = PagedPool.create(n_pages=N_PAGES, page_tokens=PAGE_TOKENS,
                                word_width=WORD, num_banks=4,
                                kv_shards=kv_shards)
        for kind, seq, toks in ops:
            if kind == "grow":
                vec = np.full((toks, WORD), float(seq + 1), np.float32)
                # alternate the two write ports (append vs bulk prefill)
                port = "append" if (seq + toks) % 2 else "prefill"
                try:
                    pool.cycle(**{port: {"seq": seq, "vectors": vec}})
                except PoolCapacityError:
                    pass                       # refusal must be transactional
            else:
                freed = pool.free(seq)
                if freed:                      # scrub through port D
                    pool.cycle(scrub=freed)
            check_invariants(pool)
        # drain: free everything, all pages return, accounting exact
        for seq in list(pool.tables):
            pool.free(seq)
        check_invariants(pool)
        assert len(pool.free_pages) == pool.plan.n_pages
        assert not pool.home

    prop()
