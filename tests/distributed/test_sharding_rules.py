"""Sharding rules: every spec produced for every (arch x mesh) must be
dimensionally valid — sharded dims divide by their mesh axes (the
divisibility guards), stack axes unsharded, norms replicated."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.launch import specs as SP
from repro.train.train_step import TrainConfig


class FakeMesh:
    """Shape-only stand-in (no devices needed for spec computation)."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)
        self.size = 1
        for v in shape.values():
            self.size *= v


MESHES = [FakeMesh({"data": 16, "model": 16}),
          FakeMesh({"pod": 2, "data": 16, "model": 16})]


def _check_tree(shapes, specs, mesh):
    flat_s = jax.tree_util.tree_flatten_with_path(shapes)[0]
    flat_p = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert dim % n == 0, \
                f"{'/'.join(map(str, path))}: dim {dim} ! % {axes}={n}"


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_param_specs_divisible(arch, mesh):
    cfg = registry.get(arch)
    shapes = SP.params_shapes(cfg)
    rules = shd.Rules.for_mesh(mesh)
    specs = shd.param_pspecs(shapes, mesh, rules)
    _check_tree(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ["llama3-405b", "deepseek-moe-16b", "rwkv6-3b"])
def test_train_state_specs_divisible(arch):
    mesh = MESHES[0]
    cfg = registry.get(arch)
    tcfg = TrainConfig(optimizer="adamw8bit" if arch.startswith("llama3")
                       else "adamw")
    shapes = SP.train_state_shapes(cfg, tcfg)
    rules = shd.Rules.for_mesh(mesh)
    specs = SP.train_state_pspecs(cfg, mesh, rules, shapes)
    _check_tree(shapes, specs, mesh)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_decode_state_specs_divisible(arch):
    mesh = MESHES[0]
    cfg = registry.get(arch)
    shapes = SP.decode_state_shapes(cfg, 128, 1024)
    rules = shd.Rules(tp=("data", "model"), fsdp=(), dp=())  # serving rules
    specs = shd.decode_state_pspecs(cfg, mesh, rules, shapes, batch=128)
    _check_tree(shapes, specs, mesh)


def test_norm_scales_replicated():
    cfg = registry.get("qwen2.5-3b")
    shapes = SP.params_shapes(cfg)
    mesh = MESHES[0]
    specs = shd.param_pspecs(shapes, mesh, shd.Rules.for_mesh(mesh))
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if name.endswith(("ln1/scale", "ln2/scale", "final_norm/scale")):
            assert all(a is None for a in tuple(spec)), (name, spec)

def test_attention_tp_mesh_head_mismatch_raises():
    """tp axis larger than (or not dividing) the attention head count used
    to silently replicate EVERY q/k/v column — attention ran with no tensor
    parallelism at all. It is now a hard error naming the mismatch; the
    GQA-standard fallback (q shards, k/v replicate when tp > n_kv_heads but
    tp | n_heads) stays."""
    cfg = registry.get("tinyllama-1.1b", reduced=True)  # heads 8, kv 2
    shapes = SP.params_shapes(cfg)

    # tp=16 does not divide n_heads=8: hard error naming mesh and heads
    mesh = FakeMesh({"data": 2, "model": 16})
    with pytest.raises(ValueError, match=r"n_heads=8.*n_kv_heads=2"):
        shd.param_pspecs(shapes, mesh, shd.Rules.for_mesh(mesh), cfg=cfg)

    # tp=4 divides n_heads=8 but exceeds n_kv_heads=2: the documented GQA
    # fallback — q columns shard, k/v columns replicate, no error
    mesh = FakeMesh({"data": 2, "model": 4})
    specs = shd.param_pspecs(shapes, mesh, shd.Rules.for_mesh(mesh), cfg=cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {"/".join(str(getattr(k, "key", k)) for k in path): spec
               for path, spec in flat}
    wq = next(v for k, v in by_name.items() if k.endswith("attn/wq/w"))
    wk = next(v for k, v in by_name.items() if k.endswith("attn/wk/w"))
    assert tuple(wq)[-1] == "model"            # q still tensor-parallel
    assert tuple(wk)[-1] is None               # kv replicated (GQA fallback)

    # without cfg the raw divisibility guards apply unchanged (no raise)
    shd.param_pspecs(shapes, FakeMesh({"data": 2, "model": 16}),
                     shd.Rules())
