"""Multi-device tests: run in subprocesses with 8 forced host devices so the
main pytest process keeps its single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run_py(body: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import registry
        from repro.data.pipeline import DataConfig, ShardedLoader
        from repro.distributed import sharding as shd
        from repro.launch import specs as SP
        from repro.models import init_params
        from repro.train.train_step import TrainConfig, init_train_state, make_train_step

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params, tcfg)
        loader = ShardedLoader(cfg, DataConfig(seed=1), batch=8, seq=16)
        batch = loader.get(0)
        step = make_train_step(cfg, tcfg)

        # single-device result
        s1, m1 = jax.jit(step)(state, batch)

        # sharded result on (2, 4) mesh
        from repro.launch.mesh import make_mesh, use_mesh
        mesh = make_mesh((2, 4), ("data", "model"))
        rules = shd.Rules.for_mesh(mesh)
        st_shapes = jax.eval_shape(lambda: state)
        st_specs = SP.train_state_pspecs(cfg, mesh, rules, st_shapes)
        bspecs = shd.batch_specs(cfg, mesh, rules, global_batch=8)
        with use_mesh(mesh):
            jf = jax.jit(step,
                         in_shardings=(SP.named_tree(mesh, st_specs),
                                       SP.named_tree(mesh, bspecs)),
                         out_shardings=(SP.named_tree(mesh, st_specs), None))
            s2, m2 = jf(state, batch)
        np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
        d1 = jax.device_get(s1["params"]["lm_head"]["w"])
        d2 = jax.device_get(s2["params"]["lm_head"]["w"])
        np.testing.assert_allclose(d1, d2, atol=2e-5, rtol=1e-4)
        print("SHARDED-OK")
    """)
    assert "SHARDED-OK" in out


def test_grad_compression_close_to_exact_and_ef_accumulates():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import (compressed_mean_pods,
                                                   init_ef_state)
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(2, 64, 33)) * 1e-3, jnp.float32)
        ef = jnp.zeros((2, 64, 33), jnp.float32)
        mean, resid = compressed_mean_pods(g, ef)
        exact = np.asarray(g).mean(0)
        # int8 with per-256 block scales: relative error small
        err = np.abs(np.asarray(mean) - exact).max()
        scale = np.abs(exact).max()
        assert err < 0.03 * scale + 1e-6, (err, scale)
        # error feedback: residual equals quantization error exactly
        # and, summed over steps of a CONSTANT gradient, the running mean of
        # dequantized values converges to the true mean
        acc = np.zeros_like(exact)
        ef_ = jnp.zeros_like(ef)
        for i in range(30):
            m, ef_ = compressed_mean_pods(g, ef_)
            acc += np.asarray(m)
        drift = np.abs(acc / 30 - exact).max()
        assert drift < 2e-3 * scale + 1e-7, drift
        print("COMPRESS-OK")
    """)
    assert "COMPRESS-OK" in out


def test_compressed_train_step_converges_and_int8_on_wire():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import registry
        from repro.data.pipeline import DataConfig, ShardedLoader
        from repro.distributed import sharding as shd
        from repro.launch import specs as SP
        from repro.models import init_params
        from repro.train.train_step import TrainConfig, init_train_state, make_train_step

        from repro.optim import AdamWConfig
        cfg = registry.get("tinyllama-1.1b", reduced=True)
        tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=3, total_steps=60,
                           adamw=AdamWConfig(weight_decay=0.0),
                           grad_compression="int8_ef", n_pods=2)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params, tcfg)
        loader = ShardedLoader(cfg, DataConfig(seed=2), batch=8, seq=16)
        step = make_train_step(cfg, tcfg)

        from repro.launch.mesh import make_mesh, use_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = shd.Rules.for_mesh(mesh)
        st_shapes = jax.eval_shape(lambda: state)
        st_specs = SP.train_state_pspecs(cfg, mesh, rules, st_shapes)
        bspecs = shd.batch_specs(cfg, mesh, rules, global_batch=8)
        state = jax.device_put(state, SP.named_tree(mesh, st_specs))
        bshard = SP.named_tree(mesh, bspecs)
        with use_mesh(mesh):
            jf = jax.jit(step, in_shardings=(SP.named_tree(mesh, st_specs),
                                             SP.named_tree(mesh, bspecs)),
                         out_shardings=(SP.named_tree(mesh, st_specs), None))
            lowered = jf.lower(state, loader.get(0))
            txt = lowered.compile().as_text()
            assert "s8[" in txt, "int8 wire format missing from HLO"
            losses = []
            for i in range(40):
                batch = {k: jax.device_put(v, bshard[k])
                         for k, v in loader.get(i).items()}
                state, m = jf(state, batch)
                losses.append(float(m["ce"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
        print("COMPRESSED-TRAIN-OK")
    """)
    assert "COMPRESSED-TRAIN-OK" in out


def test_elastic_reshard_between_meshes():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import registry
        from repro.checkpoint import ckpt
        from repro.distributed import sharding as shd
        from repro.distributed.elastic import reshard_tree
        from repro.launch import specs as SP
        from repro.models import init_params
        from repro.train.train_step import TrainConfig, init_train_state, make_train_step
        from repro.data.pipeline import DataConfig, ShardedLoader

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        tcfg = TrainConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
        params = init_params(jax.random.PRNGKey(0), cfg)
        state = init_train_state(params, tcfg)
        loader = ShardedLoader(cfg, DataConfig(seed=1), batch=8, seq=16)
        step = make_train_step(cfg, tcfg)

        from repro.launch.mesh import make_mesh, use_mesh
        mesh8 = make_mesh((2, 4), ("data", "model"))
        rules8 = shd.Rules.for_mesh(mesh8)
        st_shapes = jax.eval_shape(lambda: state)
        specs8 = SP.train_state_pspecs(cfg, mesh8, rules8, st_shapes)
        state8 = jax.device_put(state, SP.named_tree(mesh8, specs8))
        with use_mesh(mesh8):
            jf8 = jax.jit(step, in_shardings=(SP.named_tree(mesh8, specs8), None),
                          out_shardings=(SP.named_tree(mesh8, specs8), None))
            s8, _ = jf8(state8, loader.get(0))
        ckpt.save("/tmp/elastic_ck", 0, s8)

        # "pod loss": restart on a 4-device mesh, restore + reshard
        mesh4 = make_mesh((2, 2), ("data", "model"))
        rules4 = shd.Rules.for_mesh(mesh4)
        specs4 = SP.train_state_pspecs(cfg, mesh4, rules4, st_shapes)
        restored, _ = ckpt.restore("/tmp/elastic_ck", st_shapes,
                                   shardings=SP.named_tree(mesh4, specs4))
        with use_mesh(mesh4):
            jf4 = jax.jit(step, in_shardings=(SP.named_tree(mesh4, specs4), None),
                          out_shardings=(SP.named_tree(mesh4, specs4), None))
            s4, m4 = jf4(restored, loader.get(1))

        # reference: continue on the 8-device mesh
        with use_mesh(mesh8):
            s8b, m8 = jf8(s8, loader.get(1))
        np.testing.assert_allclose(float(m4["ce"]), float(m8["ce"]), rtol=1e-5)
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out
