"""Checkpoint: atomicity, checksum verification, async, gc, restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": [jnp.ones((2, 2)), jnp.zeros((3,))]}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 5, t, extra={"note": "x"})
    restored, manifest = ckpt.restore(str(tmp_path), t)
    assert manifest["step"] == 5 and manifest["extra"]["note"] == "x"
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b)),
        t, restored)


def test_checksum_detects_corruption(tmp_path):
    t = _tree()
    d = ckpt.save(str(tmp_path), 1, t)
    # corrupt the manifest's crc
    mpath = os.path.join(d, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    first = next(iter(m["leaves"]))
    m["leaves"][first]["crc32"] ^= 0xFF
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(IOError, match="checksum"):
        ckpt.restore(str(tmp_path), t)


def test_gc_keeps_last_n(tmp_path):
    t = _tree()
    for s in range(6):
        ckpt.save(str(tmp_path), s, t, keep_last=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_async_saver(tmp_path):
    t = _tree()
    s = ckpt.AsyncSaver()
    s.save(str(tmp_path), 7, t)
    s.wait()
    restored, m = ckpt.restore(str(tmp_path), t)
    assert m["step"] == 7


def test_shape_mismatch_rejected(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    bad = dict(t, a=jnp.zeros((4, 4)))
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_no_partial_checkpoint_on_crash(tmp_path, monkeypatch):
    """A crash mid-write leaves only a .tmp dir; restore uses the previous
    complete step."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    orig_rename = os.rename

    def boom(src, dst):
        raise RuntimeError("simulated crash before publish")
    monkeypatch.setattr(os, "rename", boom)
    with pytest.raises(RuntimeError):
        ckpt.save(str(tmp_path), 2, t)
    monkeypatch.setattr(os, "rename", orig_rename)
    assert ckpt.latest_step(str(tmp_path)) == 1
    restored, m = ckpt.restore(str(tmp_path), t)
    assert m["step"] == 1
