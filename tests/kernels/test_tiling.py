"""Tiling helpers: aligned-divisor tile clamping (with its one-time warning)
and the exact word-layout pad/crop round trip."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tiling
from repro.kernels.tiling import (LANE, SUBLANE, fit_seq_tile, pack_words,
                                  unpack_words, word_pad)


def test_word_pad():
    assert word_pad(1) == LANE
    assert word_pad(LANE) == LANE
    assert word_pad(LANE + 1) == 2 * LANE
    assert word_pad(3, SUBLANE) == SUBLANE
    assert word_pad(16, SUBLANE) == 16


def test_fit_seq_tile_divisible_is_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert fit_seq_tile(64, 16) == 16
        assert fit_seq_tile(64, 128) == 64     # clamp to s, still divides


def test_fit_seq_tile_prefers_aligned_divisor():
    # 88 = 8 * 11: the largest divisor <= 60 is 44, but it is not a sublane
    # multiple — the aligned divisor 8 wins (Mosaic geometry beats raw size)
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="aligned divisor 8"):
        assert fit_seq_tile(88, 60) == 8
    # 63 has no aligned divisor at all: largest raw divisor, flagged as
    # interpret-only geometry
    with pytest.warns(UserWarning, match="interpret-only"):
        assert fit_seq_tile(63, 32) == 21


def test_fit_seq_tile_prime_capacity_warns_once():
    """Regression: a prime capacity degrades the tile all the way to 1 —
    loudly, once, instead of silently on every call."""
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="divisor 1"):
        assert fit_seq_tile(97, 64) == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # second call must stay silent
        assert fit_seq_tile(97, 64) == 1


def test_pack_unpack_words_round_trip(rng):
    b, s, hkv, d, tile = 2, 33, 2, 16, 8
    cache = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    packed = pack_words(cache, tile)
    sp = -(-s // tile) * tile
    assert packed.shape == (b, sp, hkv * word_pad(d))
    assert packed.shape[1] % tile == 0
    assert packed.shape[2] % LANE == 0
    back = unpack_words(packed, s, hkv, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(cache))
