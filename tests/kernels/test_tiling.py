"""Tiling helpers: the single-sourced live-tile bound, aligned-divisor tile
clamping (with its one-time warnings) and the exact word-layout pad/crop
round trip."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tiling
from repro.kernels.tiling import (LANE, SUBLANE, clamp_seq_tile, fit_seq_tile,
                                  live_tile_bound, pack_words, unpack_words,
                                  word_pad)


def test_word_pad():
    assert word_pad(1) == LANE
    assert word_pad(LANE) == LANE
    assert word_pad(LANE + 1) == 2 * LANE
    assert word_pad(3, SUBLANE) == SUBLANE
    assert word_pad(16, SUBLANE) == 16


@pytest.mark.parametrize("seq_tile", [1, 8, 16, 128])
@pytest.mark.parametrize("k", [1, 2, 5])
def test_live_tile_bound_off_by_one_edges(seq_tile, k):
    """The ONE ceil-div bound both kernels (and the split path) share,
    pinned at the off-by-one edges: an exclusive end one short of a tile
    boundary (a whole tile fewer when the tile is a single token), exactly
    on it, and one past it."""
    assert live_tile_bound(k * seq_tile - 1, seq_tile) == \
        (k if seq_tile > 1 else k - 1)
    assert live_tile_bound(k * seq_tile, seq_tile) == k
    assert live_tile_bound(k * seq_tile + 1, seq_tile) == k + 1


def test_live_tile_bound_degenerate_and_traced():
    assert live_tile_bound(0, 8) == 0          # empty live range
    assert live_tile_bound(1, 8) == 1
    # accepts traced/array scalars (the dynamic-grid path feeds jnp.max)
    got = live_tile_bound(jnp.int32(17), 8)
    assert int(got) == 3


def test_live_tile_bound_matches_both_historic_forms():
    """Regression for the split-brain this helper replaced: the decode
    kernel's inclusive ``(last + tile) // tile`` over ``max(lens)`` and the
    chunk kernel's exclusive ``(last + tile - 1) // tile`` must BOTH equal
    the shared bound on their own inputs."""
    for tile in (1, 4, 8, 128):
        for length in range(0, 3 * tile + 2):
            # decode: append position == length, live end is length + 1
            assert live_tile_bound(length + 1, tile) == \
                (length + tile) // tile
            # chunk: exclusive last == length
            assert live_tile_bound(length, tile) == \
                (length + tile - 1) // tile


def test_clamp_seq_tile_warns_once_then_silent():
    """Satellite regression: a configured seq_tile larger than the
    traversed capacity used to clamp silently — now it warns once per
    (s, seq_tile) geometry and stays silent after."""
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="exceeds the traversed capacity"):
        assert clamp_seq_tile(24, 128) == 24
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # second call must stay silent
        assert clamp_seq_tile(24, 128) == 24


def test_clamp_seq_tile_in_range_is_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert clamp_seq_tile(64, 16) == 16
        assert clamp_seq_tile(64, 64) == 64


def test_fit_seq_tile_divisible_is_silent():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert fit_seq_tile(64, 16) == 16
        assert fit_seq_tile(64, 128) == 64     # clamp to s, still divides


def test_fit_seq_tile_prefers_aligned_divisor():
    # 88 = 8 * 11: the largest divisor <= 60 is 44, but it is not a sublane
    # multiple — the aligned divisor 8 wins (Mosaic geometry beats raw size)
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="aligned divisor 8"):
        assert fit_seq_tile(88, 60) == 8
    # 63 has no aligned divisor at all: largest raw divisor, flagged as
    # interpret-only geometry
    with pytest.warns(UserWarning, match="interpret-only"):
        assert fit_seq_tile(63, 32) == 21


def test_fit_seq_tile_prime_capacity_warns_once():
    """Regression: a prime capacity degrades the tile all the way to 1 —
    loudly, once, instead of silently on every call."""
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="divisor 1"):
        assert fit_seq_tile(97, 64) == 1
    with warnings.catch_warnings():
        warnings.simplefilter("error")        # second call must stay silent
        assert fit_seq_tile(97, 64) == 1


def test_pack_unpack_words_round_trip(rng):
    b, s, hkv, d, tile = 2, 33, 2, 16, 8
    cache = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    packed = pack_words(cache, tile)
    sp = -(-s // tile) * tile
    assert packed.shape == (b, sp, hkv * word_pad(d))
    assert packed.shape[1] % tile == 0
    assert packed.shape[2] % LANE == 0
    back = unpack_words(packed, s, hkv, d)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(cache))
