"""Pallas multiport_sram kernel vs the jnp oracle: shape/dtype sweeps, and
the 1-traversal bandwidth property (claim C1) via cost accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MemorySpec, PortConfig, READ, WRITE, PortRequest, step
from repro.kernels import ops


def _random_case(rng, spec, q, roles):
    reqs = []
    for p in range(4):
        addr = rng.integers(0, spec.num_words, q)
        data = rng.normal(size=(q, spec.word_width)).astype(np.float32)
        mask = rng.random(q) > 0.25
        reqs.append(PortRequest(addr=jnp.asarray(addr, jnp.int32),
                                data=jnp.asarray(data, spec.dtype),
                                mask=jnp.asarray(mask)))
    storage = jnp.asarray(
        rng.normal(size=(spec.num_words, spec.word_width)), spec.dtype)
    return storage, reqs


@pytest.mark.parametrize("num_words,width,banks,q", [
    (32, 4, 4, 4),
    (64, 8, 8, 16),
    (128, 16, 4, 32),
    (64, 4, 1, 8),        # single bank edge case
    (64, 4, 64, 8),       # one word per bank
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_vs_oracle_sweep(rng, num_words, width, banks, q, dtype):
    spec = MemorySpec(num_words=num_words, word_width=width, num_banks=banks,
                      dtype=dtype)
    cfg = PortConfig(enabled=(True, True, True, True),
                     roles=(WRITE, READ, WRITE, READ))
    storage, reqs = _random_case(rng, spec, q, cfg.roles)
    s_ref, r_ref = step(spec, cfg, storage, reqs)
    s_k, r_k = ops.multiport_step(spec, cfg, storage, reqs, interpret=True)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s_k, np.float32),
                               np.asarray(s_ref, np.float32), atol=tol)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(r_k[p], np.float32),
                                   np.asarray(r_ref[p], np.float32), atol=tol)


@pytest.mark.parametrize("n_ports", [1, 2, 3, 4])
def test_kernel_port_count_configs(rng, n_ports):
    spec = MemorySpec(num_words=64, word_width=4, num_banks=8)
    roles = (WRITE, READ, READ, WRITE)
    cfg = PortConfig(enabled=tuple(i < n_ports for i in range(4)), roles=roles)
    storage, reqs = _random_case(rng, spec, 8, roles)
    s_ref, r_ref = step(spec, cfg, storage, reqs)
    s_k, r_k = ops.multiport_step(spec, cfg, storage, reqs, interpret=True)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref), atol=1e-6)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(r_k[p]), np.asarray(r_ref[p]),
                                   atol=1e-6)


def test_one_traversal_regardless_of_port_count():
    """C1: kernel HBM traffic over the storage is ~constant in the enabled
    port count, while the single-port baseline's scales linearly."""
    spec = MemorySpec(num_words=512, word_width=8, num_banks=8)
    q = 16

    def kernel_storage_bytes(n_ports):
        cfg = PortConfig(enabled=tuple(i < n_ports for i in range(4)),
                         roles=(WRITE, READ, WRITE, READ))
        rng = np.random.default_rng(0)
        storage, reqs = _random_case(rng, spec, q, cfg.roles)
        f = jax.jit(lambda s, r: ops.multiport_step(spec, cfg, s, r,
                                                    interpret=True))
        lowered = f.lower(storage, reqs)
        cost = lowered.compile().cost_analysis()
        if isinstance(cost, list):        # pre-0.5 JAX returns [dict]
            cost = cost[0]
        return cost.get("bytes accessed", 0.0)

    b1, b4 = kernel_storage_bytes(1), kernel_storage_bytes(4)
    # storage dominates the traffic; ports add only queue-sized metadata
    assert b4 < 1.6 * b1, (b1, b4)
