"""Fused decode append+attend and flash attention kernels vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,s,hkv,g,d,tile", [
    (1, 128, 1, 1, 16, 64),
    (2, 256, 2, 4, 32, 64),
    (3, 128, 4, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_decode_sweep(rng, b, s, hkv, g, d, tile, dtype):
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), dtype)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), dtype)
    lens = jnp.asarray(rng.integers(0, s - 1, b), jnp.int32)
    o_r, ck_r, cv_r = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
    o_k, ck_k, cv_k = ops.fused_decode_attention(q, ck, cv, nk, nv, lens,
                                                 seq_tile=tile)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(ck_k, np.float32),
                               np.asarray(ck_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(cv_k, np.float32),
                               np.asarray(cv_r, np.float32), atol=tol)


def test_fused_decode_edge_positions(rng):
    """Append at position 0 and at the last tile boundary."""
    b, s, hkv, g, d = 2, 128, 2, 2, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    for lens in ([0, 0], [s - 1, 63], [0, s - 1]):
        lens = jnp.asarray(lens, jnp.int32)
        o_r, ck_r, _ = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
        o_k, ck_k, _ = ops.fused_decode_attention(q, ck, cv, nk, nv, lens,
                                                  seq_tile=64)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r))


@pytest.mark.parametrize("s,tile", [(100, 64), (63, 32), (33, 8)])
def test_fused_decode_odd_capacity(rng, s, tile):
    """Regression: S_max not a multiple of seq_tile must clamp the tile to
    the largest divisor instead of crashing on the divisibility assert."""
    b, hkv, g, d = 2, 2, 2, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    lens = jnp.asarray([0, s - 1], jnp.int32)
    o_r, ck_r, _ = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
    o_k, ck_k, _ = ops.fused_decode_attention(q, ck, cv, nk, nv, lens,
                                              seq_tile=tile)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r))


def test_fused_decode_length_bounded(rng):
    """live_len bounding + per-sequence tile masking are numerically
    transparent, and the suffix past the bound rides through untouched."""
    b, s, hkv, g, d, tile = 2, 128, 2, 2, 16, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    lens = jnp.asarray([5, 30], jnp.int32)
    o_r, ck_r, cv_r = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
    for live in (32, 48, s):
        for mask in (True, False):
            o_k, ck_k, cv_k = ops.fused_decode_attention(
                q, ck, cv, nk, nv, lens, seq_tile=tile, live_len=live,
                length_mask=mask)
            np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r))
            np.testing.assert_allclose(np.asarray(cv_k), np.asarray(cv_r))
    # suffix untouched under the tightest bound
    o_k, ck_k, cv_k = ops.fused_decode_attention(
        q, ck, cv, nk, nv, lens, seq_tile=tile, live_len=32)
    np.testing.assert_array_equal(np.asarray(ck_k)[:, 32:],
                                  np.asarray(ck)[:, 32:])


def test_fused_decode_tile_counts_measured(rng):
    """The KERNEL-MEASURED serviced-tile counts equal the analytic
    ceil((cache_len+1)/seq_tile) budget the engine accounts (and the CI
    bench gate enforces) — masked tiles are genuinely not serviced."""
    from repro.kernels import kv_multiport as kvmp
    b, s, hkv, g, d, tile = 3, 128, 2, 2, 16, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    lens = jnp.asarray([0, 17, 100], jnp.int32)
    *_, tiles = kvmp.fused_append_attend(q, ck, cv, nk, nv, lens,
                                         seq_tile=tile, return_tiles=True)
    np.testing.assert_array_equal(np.asarray(tiles),
                                  [-(-(int(p) + 1) // tile) for p in lens])
    # live_len bounding doesn't change serviced counts, only the grid
    *_, tiles = kvmp.fused_append_attend(q, ck, cv, nk, nv, lens,
                                         seq_tile=tile, live_len=112,
                                         return_tiles=True)
    np.testing.assert_array_equal(np.asarray(tiles), [1, 2, 7])
    # the unbounded comparator really does service every grid tile
    *_, tiles = kvmp.fused_append_attend(q, ck, cv, nk, nv, lens,
                                         seq_tile=tile, length_mask=False,
                                         return_tiles=True)
    np.testing.assert_array_equal(np.asarray(tiles), [s // tile] * b)
    # dead-row sentinel (engine batch padding): zero tiles serviced, zero
    # output, cache row untouched — under BOTH masking modes
    lens = jnp.asarray([-1, 17, -1], jnp.int32)
    for mask in (True, False):
        o, ck_k, cv_k, tiles = kvmp.fused_append_attend(
            q, ck, cv, nk, nv, lens, seq_tile=tile, length_mask=mask,
            return_tiles=True)
        np.testing.assert_array_equal(
            np.asarray(tiles), [0, s // tile if not mask else 2, 0])
        np.testing.assert_array_equal(np.asarray(o)[0], 0.0)
        np.testing.assert_array_equal(np.asarray(ck_k)[0], np.asarray(ck)[0])
        np.testing.assert_array_equal(np.asarray(cv_k)[2], np.asarray(cv)[2])


@pytest.mark.parametrize("b,h,hkv,sq,sk,d,qt,kt", [
    (1, 2, 1, 128, 128, 32, 64, 64),
    (2, 4, 2, 128, 128, 64, 128, 64),
    (1, 8, 8, 256, 256, 16, 64, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, b, h, hkv, sq, sk, d, qt, kt, causal):
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), jnp.float32)
    o_r = ref.attention_ref(q, k, v, causal=causal)
    o_k = ops.flash_attention(q, k, v, causal=causal, q_tile=qt, k_tile=kt)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(rng):
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    o_r = ref.attention_ref(q, k, v, causal=True)
    o_k = ops.flash_attention(q, k, v, causal=True, q_tile=64, k_tile=64)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=5e-2)
