"""Fused decode append+attend and flash attention kernels vs jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,s,hkv,g,d,tile", [
    (1, 128, 1, 1, 16, 64),
    (2, 256, 2, 4, 32, 64),
    (3, 128, 4, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_decode_sweep(rng, b, s, hkv, g, d, tile, dtype):
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), dtype)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), dtype)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), dtype)
    lens = jnp.asarray(rng.integers(0, s - 1, b), jnp.int32)
    o_r, ck_r, cv_r = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
    o_k, ck_k, cv_k = ops.fused_decode_attention(q, ck, cv, nk, nv, lens,
                                                 seq_tile=tile)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(ck_k, np.float32),
                               np.asarray(ck_r, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(cv_k, np.float32),
                               np.asarray(cv_r, np.float32), atol=tol)


def test_fused_decode_edge_positions(rng):
    """Append at position 0 and at the last tile boundary."""
    b, s, hkv, g, d = 2, 128, 2, 2, 16
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    for lens in ([0, 0], [s - 1, 63], [0, s - 1]):
        lens = jnp.asarray(lens, jnp.int32)
        o_r, ck_r, _ = ref.decode_attention_ref(q, ck, cv, nk, nv, lens)
        o_k, ck_k, _ = ops.fused_decode_attention(q, ck, cv, nk, nv, lens,
                                                  seq_tile=64)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r))


@pytest.mark.parametrize("b,h,hkv,sq,sk,d,qt,kt", [
    (1, 2, 1, 128, 128, 32, 64, 64),
    (2, 4, 2, 128, 128, 64, 128, 64),
    (1, 8, 8, 256, 256, 16, 64, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(rng, b, h, hkv, sq, sk, d, qt, kt, causal):
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), jnp.float32)
    o_r = ref.attention_ref(q, k, v, causal=causal)
    o_k = ops.flash_attention(q, k, v, causal=causal, q_tile=qt, k_tile=kt)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(rng):
    b, h, hkv, s, d = 1, 4, 2, 128, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.bfloat16)
    o_r = ref.attention_ref(q, k, v, causal=True)
    o_k = ops.flash_attention(q, k, v, causal=True, q_tile=64, k_tile=64)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_r, np.float32), atol=5e-2)
