"""Fused chunked-prefill append+attend kernel vs the jnp oracle: the cache
serviced as a 2-port (1W+1R) memory with the R port bounded to live tiles
must agree with the dense two-pass reference for every offset/chunk_len/
seq_tile/S_max combination (the `attention_prefill_chunk` contract)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.tiling import fit_seq_tile


def _case(rng, b, c, s, hkv, g, d, lo_off=0):
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, c, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, c, hkv, d)), jnp.float32)
    off = jnp.asarray(rng.integers(lo_off, s - c + 1, b), jnp.int32)
    cl = jnp.asarray(rng.integers(0, c + 1, b), jnp.int32)
    return q, ck, cv, nk, nv, off, cl


def _assert_matches(q, ck, cv, nk, nv, off, cl, *, seq_tile, live_len=None):
    o_r, ck_r, cv_r = ref.prefill_chunk_attention_ref(q, ck, cv, nk, nv,
                                                      off, cl)
    o_k, ck_k, cv_k = ops.fused_prefill_chunk_attention(
        q, ck, cv, nk, nv, off, cl, seq_tile=seq_tile, live_len=live_len)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(ck_k), np.asarray(ck_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(cv_k), np.asarray(cv_r), atol=1e-6)


@pytest.mark.parametrize("b,c,s,hkv,g,d,tile", [
    (1, 4, 32, 1, 1, 16, 8),
    (2, 8, 64, 2, 2, 16, 16),
    (3, 4, 33, 1, 2, 8, 8),       # S_max not a tile multiple: clamp, no crash
    (2, 5, 50, 2, 1, 16, 16),
])
def test_fused_prefill_chunk_sweep(rng, b, c, s, hkv, g, d, tile):
    _assert_matches(*_case(rng, b, c, s, hkv, g, d), seq_tile=tile)


def test_fused_prefill_chunk_live_len_bound(rng):
    """Bounding the traversal to a bucketed live prefix leaves the suffix
    untouched and changes nothing numerically."""
    b, c, s, hkv, g, d, tile = 2, 4, 64, 2, 2, 16, 8
    q, ck, cv, nk, nv, _, cl = _case(rng, b, c, s, hkv, g, d)
    off = jnp.asarray([0, 3], jnp.int32)       # live prefix well under S_max
    need = int(np.max(np.asarray(off) + np.asarray(cl)))
    n_tiles = 1
    while n_tiles * tile < need:
        n_tiles *= 2
    live = min(n_tiles * tile, s)
    _assert_matches(q, ck, cv, nk, nv, off, cl, seq_tile=tile, live_len=live)
    # the suffix [live, S) must ride through bit-identical
    _, ck_k, cv_k = ops.fused_prefill_chunk_attention(
        q, ck, cv, nk, nv, off, cl, seq_tile=tile, live_len=live)
    np.testing.assert_array_equal(np.asarray(ck_k)[:, live:],
                                  np.asarray(ck)[:, live:])
    np.testing.assert_array_equal(np.asarray(cv_k)[:, live:],
                                  np.asarray(cv)[:, live:])


def test_fused_prefill_chunk_zero_len_rows(rng):
    """chunk_len = 0 (a padded batch row): nothing written, finite output."""
    b, c, s, hkv, g, d = 2, 4, 32, 1, 1, 8
    q, ck, cv, nk, nv, off, _ = _case(rng, b, c, s, hkv, g, d, lo_off=1)
    cl = jnp.zeros((b,), jnp.int32)
    _assert_matches(q, ck, cv, nk, nv, off, cl, seq_tile=8)
    o_k, ck_k, _ = ops.fused_prefill_chunk_attention(
        q, ck, cv, nk, nv, off, cl, seq_tile=8)
    assert np.isfinite(np.asarray(o_k)).all()
    np.testing.assert_array_equal(np.asarray(ck_k), np.asarray(ck))


def test_fused_prefill_chunk_tile_counts_measured(rng):
    """KERNEL-MEASURED serviced-tile counts match the analytic bound the
    engine accounts: tiles [0, ceil((offset+chunk_len)/seq_tile)) only."""
    from repro.kernels.kv_prefill_chunk import fused_chunk_append_attend
    b, c, s, hkv, g, d, tile = 3, 4, 64, 1, 1, 8, 8
    q, ck, cv, nk, nv, _, _ = _case(rng, b, c, s, hkv, g, d)
    off = jnp.asarray([0, 10, 40], jnp.int32)
    cl = jnp.asarray([4, 3, 0], jnp.int32)
    *_, tiles = fused_chunk_append_attend(q, ck, cv, nk, nv, off, cl,
                                          seq_tile=tile, return_tiles=True)
    # last query position is offset + max(chunk_len-1, 0)
    want = [(-(-(int(o) + int(n)) // tile)) if int(n) else int(o) // tile + 1
            for o, n in zip(off, cl)]
    np.testing.assert_array_equal(np.asarray(tiles), want)   # [1, 2, 6]
    # dead-row sentinel (engine batch padding): offset -1 services nothing
    off = jnp.asarray([-1, 10, -1], jnp.int32)
    o, ck_k, cv_k, tiles = fused_chunk_append_attend(
        q, ck, cv, nk, nv, off, cl, seq_tile=tile, return_tiles=True)
    np.testing.assert_array_equal(np.asarray(tiles), [0, 2, 0])
    np.testing.assert_array_equal(np.asarray(o)[0], 0.0)
    np.testing.assert_array_equal(np.asarray(ck_k)[0], np.asarray(ck)[0])
    np.testing.assert_array_equal(np.asarray(cv_k)[2], np.asarray(cv)[2])


def test_fit_seq_tile():
    assert fit_seq_tile(64, 128) == 64
    assert fit_seq_tile(64, 16) == 16
    assert fit_seq_tile(33, 8) == 3          # largest divisor <= 8
    assert fit_seq_tile(63, 32) == 21
    assert fit_seq_tile(7, 1) == 1


def test_fused_prefill_chunk_property(rng):
    """Property (CI installs the ``dev`` extra; skips locally): kernel ==
    oracle over random offset / chunk_len / seq_tile / S_max."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(
        b=st.integers(1, 3),
        c=st.integers(1, 6),
        s_extra=st.integers(0, 40),
        hkv=st.sampled_from([1, 2]),
        g=st.sampled_from([1, 2]),
        seq_tile=st.sampled_from([1, 4, 8, 16, 128]),
        seed=st.integers(0, 2**31 - 1),
        data=st.data())
    def prop(b, c, s_extra, hkv, g, seq_tile, seed, data):
        s = c + s_extra                      # S_max always fits the chunk
        d = 8
        r = np.random.default_rng(seed)
        q, ck, cv, nk, nv, off, cl = _case(r, b, c, s, hkv, g, d)
        # any live bound covering the written range must be transparent
        need = int(np.max(np.asarray(off) + np.asarray(cl)))
        live = data.draw(st.one_of(st.none(),
                                   st.integers(max(need, 1), s + 8)),
                         label="live_len")
        _assert_matches(q, ck, cv, nk, nv, off, cl, seq_tile=seq_tile,
                        live_len=live)

    prop()
