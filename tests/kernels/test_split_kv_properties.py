"""Hypothesis property suite for split-KV flash-decode (importorskip
pattern, per the ROADMAP's property-testing direction): split-KV ≡ serial
decode within fp tolerance over random ``num_kv_splits`` ∈ {1..8} × ragged
``cache_len`` — dead rows (-1 sentinel) and rows shorter than one split
included — with bit-identical cache updates and serviced-tile counts.

Whole-module skip when hypothesis is absent; the deterministic parametrized
cases in test_split_kv.py cover the same contract without it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.kernels.kv_multiport import fused_append_attend  # noqa: E402


def _run(lens, splits, seed):
    rng = np.random.default_rng(seed)
    b, s, hkv, g, d = len(lens), 64, 2, 2, 16
    args = (jnp.asarray(rng.normal(size=(b, hkv * g, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32))
    return fused_append_attend(*args, jnp.asarray(lens, jnp.int32),
                               seq_tile=8, dynamic_grid=True,
                               num_kv_splits=splits, return_tiles=True)


@hyp.given(
    splits=st.integers(min_value=1, max_value=8),
    lens=st.lists(st.integers(min_value=-1, max_value=63),
                  min_size=1, max_size=5),
    seed=st.integers(min_value=0, max_value=2**16),
)
@hyp.settings(deadline=None, max_examples=30)
def test_split_kv_equals_serial_property(splits, lens, seed):
    ref = _run(lens, 1, seed)
    got = _run(lens, splits, seed)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=2e-6, atol=2e-6)   # attention out
    for a, b in zip(ref[1:], got[1:]):                 # caches + tile counts
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
