"""Mosaic geometry lint + dynamic-grid equivalence for the fused KV kernels.

Two contracts of the compiled (``interpret=False``) path that CPU CI can
still enforce:

* every block spec the kernels launch — across the engine's whole stage-
  length bucket ladder AND the dynamic-grid full-capacity launch, at CI and
  production word widths — satisfies the Mosaic (8, 128)/f32 tiling rules
  (minor dim a 128-lane multiple via ``word_pad``, second-minor a sublane
  multiple or the full array dim, rank <= 4 — the old rank-5
  ``[1, C, Hkv, G, D]`` q/out blocks do not lower);
* the dynamic-grid traversal (live bound read from the prefetched scalars
  at run time — ONE trace for every cache length) is BIT-identical to the
  static bucketed traversal it replaces, over random live lengths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.kv_multiport import (decode_block_specs,
                                        fused_append_attend,
                                        split_block_specs)
from repro.kernels.kv_prefill_chunk import (chunk_block_specs,
                                            fused_chunk_append_attend)
from repro.kernels.tiling import LANE, SUBLANE, check_block
from repro.memory.paged_kv import _bucket, seq_tile_buckets

# (name, b, chunk, h, hkv, d, s_max, seq_tile)
GEOMETRIES = [
    ("ci-reduced", 4, 16, 8, 2, 8, 128, 64),     # tinyllama-1.1b-reduced
    ("bench", 8, 8, 8, 2, 8, 64, 8),             # engine_bench tile sweep
    ("production", 8, 16, 32, 8, 128, 4096, 128),
    ("awkward-capacity", 3, 8, 4, 1, 16, 100, 16),  # padded, not clamped
]


@pytest.mark.parametrize("name,b,c,h,hkv,d,s_max,tile", GEOMETRIES)
def test_kernel_blocks_mosaic_aligned(name, b, c, h, hkv, d, s_max, tile):
    """Every block spec of both kernels is (8,128)/f32-tileable at every
    stage length the engine can launch: each bucket of the ladder (the
    dynamic_grid=False fallback) and the padded full capacity (the
    dynamic-grid path's single launch shape)."""
    stages = set(seq_tile_buckets(s_max, min(tile, s_max))) | {s_max}
    for stage in stages:
        for nm, blk, arr in (decode_block_specs(b, stage, h, hkv, d, tile)
                             + chunk_block_specs(b, c, stage, h, hkv, d,
                                                 tile)):
            errs = check_block(blk, arr)
            assert not errs, (name, stage, nm, errs)
            assert len(blk) <= 4, (name, stage, nm, blk)


@pytest.mark.parametrize("splits", [2, 3, 4, 8])
@pytest.mark.parametrize("name,b,c,h,hkv,d,s_max,tile", GEOMETRIES)
def test_split_kernel_blocks_mosaic_aligned(name, b, c, h, hkv, d, s_max,
                                            tile, splits):
    """The split-KV launch table (serial table + the stage-1 partial
    acc/LSE blocks, stacked per-split on the head axis) stays
    (8,128)/f32-tileable at every stage length and split count."""
    stages = set(seq_tile_buckets(s_max, min(tile, s_max))) | {s_max}
    for stage in stages:
        for nm, blk, arr in split_block_specs(b, stage, h, hkv, d, tile,
                                              splits):
            errs = check_block(blk, arr)
            assert not errs, (name, stage, splits, nm, errs)
            assert len(blk) <= 4, (name, stage, splits, nm, blk)


@pytest.mark.parametrize("n_dev", [1, 2, 4, 8])
@pytest.mark.parametrize("name,b,c,h,hkv,d,s_max,tile", GEOMETRIES)
def test_kernel_blocks_shard_local(name, b, c, h, hkv, d, s_max, tile,
                                   n_dev):
    """Per-shard block specs under data-parallel KV, at every device count
    in the CI matrix: each shard_map shard launches the kernels over its
    OWN batch block (the engine pads rows-per-device to a power of two, so
    the local batch is ``bucket(ceil(b / n_dev))``) against the full staged
    cache — the sequence axis is NOT sharded (a sequence lives wholly on
    its home device), so shard-local Sp equals the staged Sp and must stay
    a whole tile count, and every (8,128) rule must hold on the shard-local
    shapes exactly as on the global ones."""
    local_b = _bucket(-(-b // n_dev), lo=1)
    assert local_b * n_dev >= b            # the padded batch covers everyone
    stages = set(seq_tile_buckets(s_max, min(tile, s_max))) | {s_max}
    for stage in stages:
        for nm, blk, arr in (decode_block_specs(local_b, stage, h, hkv, d,
                                                tile)
                             + chunk_block_specs(local_b, c, stage, h, hkv,
                                                 d, tile)):
            errs = check_block(blk, arr)
            assert not errs, (name, n_dev, stage, nm, errs)
            assert len(blk) <= 4, (name, n_dev, stage, nm, blk)
            if nm in ("cache_k", "cache_v", "out_k", "out_v"):
                # shard-local Sp (= the staged Sp: the sequence axis is not
                # sharded) stays a whole count of the EFFECTIVE tile the
                # spec table picked, so per-shard traversals never need a
                # degenerate partial tile at any device count
                sp, eff_tile = arr[1], blk[1]
                assert sp % eff_tile == 0, (name, n_dev, stage, nm)
                assert sp >= stage, (name, n_dev, stage, nm)


def test_lint_flags_bad_geometry():
    """The lint has teeth: rank-5 blocks and unaligned minor dims fail."""
    assert check_block((1, 4, 2, 2, 16), (2, 4, 2, 2, 16))   # rank 5
    assert check_block((1, 8, 16), (2, 64, 16))              # minor !% 128
    assert check_block((1, 4, LANE), (2, 64, LANE))          # sublane 4
    assert not check_block((1, SUBLANE, LANE), (2, 64, LANE))


def _decode_case(rng, b=3, s=128, hkv=2, g=2, d=16):
    h = hkv * g
    return (jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32))


def _bucketed_live(lens, tile, s):
    need = max(max(lens) + 1, 1)
    live = tile
    while live < need:
        live *= 2
    return min(live, s)


def test_dynamic_grid_decode_bit_identical(rng):
    """Dynamic-grid decode == bucketed decode, bit for bit, and one jitted
    trace serves every cache length (the bucketed path retraces per
    bucket)."""
    s, tile = 128, 16
    q, ck, cv, nk, nv = _decode_case(rng, s=s)
    f = jax.jit(lambda lens: fused_append_attend(
        q, ck, cv, nk, nv, lens, seq_tile=tile, dynamic_grid=True,
        return_tiles=True))
    for lens in ([0, 17, 100], [5, -1, 30], [-1, -1, -1], [127, 0, 64]):
        la = jnp.asarray(lens, jnp.int32)
        o_d, k_d, v_d, tiles = f(la)
        o_s, k_s, v_s = fused_append_attend(
            q, ck, cv, nk, nv, la, seq_tile=tile,
            live_len=_bucketed_live(lens, tile, s))
        np.testing.assert_array_equal(np.asarray(o_d), np.asarray(o_s))
        np.testing.assert_array_equal(np.asarray(k_d), np.asarray(k_s))
        np.testing.assert_array_equal(np.asarray(v_d), np.asarray(v_s))
        # kernel-measured serviced tiles: exactly the live count per row
        want = [-(-(p + 1) // tile) if p >= 0 else 0 for p in lens]
        assert np.asarray(tiles).tolist() == want
    assert f._cache_size() == 1, "dynamic grid must not retrace on length"


def test_dynamic_grid_chunk_bit_identical(rng):
    s, tile, c = 128, 16, 4
    _, ck, cv, _, _ = _decode_case(rng, s=s)
    h, hkv, d = 4, 2, 16
    q = jnp.asarray(rng.normal(size=(3, c, h, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(3, c, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(3, c, hkv, d)), jnp.float32)
    f = jax.jit(lambda off, cl: fused_chunk_append_attend(
        q, ck, cv, nk, nv, off, cl, seq_tile=tile, dynamic_grid=True))
    for off, cl in (([0, 20, 100], [4, 3, 2]), ([-1, 5, -1], [0, 4, 0]),
                    ([3, 60, 124], [4, 4, 4])):
        offa = jnp.asarray(off, jnp.int32)
        cla = jnp.asarray(cl, jnp.int32)
        got = f(offa, cla)
        want = fused_chunk_append_attend(q, ck, cv, nk, nv, offa, cla,
                                         seq_tile=tile)
        for gg, ww in zip(got, want):
            np.testing.assert_array_equal(np.asarray(gg), np.asarray(ww))
    assert f._cache_size() == 1


def test_dynamic_grid_decode_property(rng):
    """Property (CI installs the ``dev`` extra; skips locally): dynamic-grid
    == bucketed over random live lengths, dead rows included."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=20, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(b=st.integers(1, 4),
               n_tiles=st.integers(1, 6),
               tile=st.sampled_from([8, 16, 32]),
               hkv=st.sampled_from([1, 2]),
               g=st.sampled_from([1, 2]),
               seed=st.integers(0, 2**31 - 1),
               data=st.data())
    def prop(b, n_tiles, tile, hkv, g, seed, data):
        s = n_tiles * tile
        r = np.random.default_rng(seed)
        q, ck, cv, nk, nv = _decode_case(r, b=b, s=s, hkv=hkv, g=g, d=8)
        lens = [data.draw(st.integers(-1, s - 1), label=f"len{i}")
                for i in range(b)]
        la = jnp.asarray(lens, jnp.int32)
        dyn = fused_append_attend(q, ck, cv, nk, nv, la, seq_tile=tile,
                                  dynamic_grid=True)
        buck = fused_append_attend(q, ck, cv, nk, nv, la, seq_tile=tile,
                                   live_len=_bucketed_live(lens, tile, s))
        for gg, ww in zip(dyn, buck):
            np.testing.assert_array_equal(np.asarray(gg), np.asarray(ww))

    prop()
