"""Split-KV flash-decode: the two-stage (grid-parallel partial attention +
LSE combine) path against the serial traversal it parallelizes.

The contract under test (see kernels/kv_multiport.py):

* ``num_kv_splits=1`` IS the serial kernel — bit-identical, same trace;
* ``num_kv_splits>1`` agrees with serial within fp tolerance on every
  ragged batch shape (dead rows, rows shorter than one split, append at a
  tile edge), on both the dynamic-grid and static-prefix launches;
* cache updates and serviced-tile counts are identical either way (the
  same tiles are touched, just on parallel chains);
* the configured-``seq_tile > S_max`` clamp is no longer silent.

A hypothesis property suite widens the sweep when hypothesis is installed
(importorskip pattern, in test_split_kv_properties.py so CI without it
still runs these parametrized cases).
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import tiling
from repro.kernels.kv_multiport import fused_append_attend


def _case(rng, b=3, s=64, hkv=2, g=2, d=16):
    h = hkv * g
    return (jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32),
            jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32))


def _run(args, lens, *, splits, tile=8, dynamic=True, **kw):
    return fused_append_attend(*args, jnp.asarray(lens, jnp.int32),
                               seq_tile=tile, dynamic_grid=dynamic,
                               num_kv_splits=splits, return_tiles=True, **kw)


def _assert_split_matches_serial(args, lens, splits, **kw):
    ref = _run(args, lens, splits=1, **kw)
    got = _run(args, lens, splits=splits, **kw)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=2e-6, atol=2e-6)   # attention out
    for a, b in zip(ref[1:], got[1:]):                 # caches + tile counts
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


LENS_CASES = [
    [0, 17, 60],        # ragged: fresh row, mid row, near-capacity row
    [-1, 17, -1],       # dead-row sentinels around a live row
    [5, 5, 5],          # every row shorter than one split at high splits
    [63, 0, 31],        # append at the last slot of the last tile
    [7, 8, 9],          # straddling one tile boundary (tile=8)
]


@pytest.mark.parametrize("splits", [2, 3, 4, 8])
@pytest.mark.parametrize("lens", LENS_CASES, ids=[str(c) for c in LENS_CASES])
def test_split_matches_serial_dynamic_grid(rng, lens, splits):
    _assert_split_matches_serial(_case(rng), lens, splits)


@pytest.mark.parametrize("splits", [2, 4])
def test_split_matches_serial_static_prefix(rng, splits):
    """The bucketed (dynamic_grid=False) launch splits identically — the
    split partition is per-row arithmetic, not a grid-shape property."""
    _assert_split_matches_serial(_case(rng), [0, 17, 60], splits,
                                 dynamic=False, live_len=61)


def test_split_one_is_bit_exact(rng):
    """num_kv_splits=1 dispatches the serial kernel itself: bitwise equal,
    not merely close."""
    args = _case(rng)
    ref = fused_append_attend(*args, jnp.asarray([0, 17, 60], jnp.int32),
                              seq_tile=8, dynamic_grid=True)
    one = fused_append_attend(*args, jnp.asarray([0, 17, 60], jnp.int32),
                              seq_tile=8, dynamic_grid=True, num_kv_splits=1)
    for a, b in zip(ref, one):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_dead_rows_zero_output(rng):
    """A dead row leaves every split bank empty: the combine emits exactly
    the serial kernel's zeros, and zero tiles are serviced."""
    out, _, _, tiles = _run(_case(rng), [-1, 17, -1], splits=4)
    np.testing.assert_array_equal(np.asarray(out[0]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[2]), 0.0)
    assert list(np.asarray(tiles)) == [0, 3, 0]


def test_split_more_splits_than_tiles(rng):
    """Rows whose live range is shorter than one tile per split: surplus
    banks stay empty (m = -inf) and the combine ignores them."""
    _assert_split_matches_serial(_case(rng), [0, 1, 2], 8)


def test_split_partial_specs_match_kernel_geometry():
    """launch.specs.kv_split_partial_specs must stay in sync with the
    stage-1 spill geometry the kernel actually launches (read off the same
    lint-checked table): per-split banks stacked on the padded head axis,
    word-padded depth / LANE-wide stats, f32 regardless of q dtype."""
    from repro.configs import registry
    from repro.kernels.tiling import LANE, SUBLANE, word_pad
    from repro.launch.specs import kv_split_partial_specs

    cfg = registry.get("tinyllama-1.1b", reduced=True)
    specs = kv_split_partial_specs(cfg, batch=4, num_kv_splits=4)
    hp = word_pad(cfg.n_heads, SUBLANE)
    assert specs["acc_partial"].shape == (4, 4 * hp,
                                          word_pad(cfg.head_dim_))
    assert specs["lse_partial"].shape == (4, 4 * hp, LANE)
    assert all(s.dtype == jnp.float32 for s in specs.values())


def test_oversize_seq_tile_clamps_with_warning(rng):
    """Satellite regression: configured seq_tile > S_max used to clamp
    silently inside the kernel wrapper; now the clamp warns once (through
    the shared tiling machinery) and the result is unchanged."""
    args = _case(rng, s=24)
    lens = jnp.asarray([0, 10, 23], jnp.int32)
    tiling._fit_warned.clear()
    with pytest.warns(UserWarning, match="exceeds the traversed capacity"):
        big = fused_append_attend(*args, lens, seq_tile=128,
                                  dynamic_grid=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # once per geometry
        again = fused_append_attend(*args, lens, seq_tile=128,
                                    dynamic_grid=True)
    ref = fused_append_attend(*args, lens, seq_tile=24, dynamic_grid=True)
    for a, b, c in zip(big, again, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=2e-6, atol=2e-6)
