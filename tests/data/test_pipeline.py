"""Data pipeline: step-addressable determinism (the fault-tolerance
substrate) and the learnable chain structure."""
import numpy as np

from repro.configs import registry
from repro.data.pipeline import DataConfig, ShardedLoader, make_batch


def test_batches_are_pure_functions_of_step():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    dc = DataConfig(seed=42)
    a = make_batch(cfg, dc, step=7, batch=4, seq=16)
    b = make_batch(cfg, dc, step=7, batch=4, seq=16)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    np.testing.assert_array_equal(a["labels"], b["labels"])
    c = make_batch(cfg, dc, step=8, batch=4, seq=16)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_different_seeds_differ():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    a = make_batch(cfg, DataConfig(seed=1), 0, 4, 16)
    b = make_batch(cfg, DataConfig(seed=2), 0, 4, 16)
    assert not np.array_equal(a["inputs"], b["inputs"])


def test_chain_task_structure():
    """labels are the chain continuation of inputs: x_{t+1} = a*x_t + b."""
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    batch = make_batch(cfg, DataConfig(seed=0), 0, 4, 32)
    x, y = batch["inputs"], batch["labels"]
    np.testing.assert_array_equal(x[:, 1:], y[:, :-1])   # shifted by one
    # recover (a, b) from the first two transitions and verify the rest
    v = cfg.vocab
    for row in range(4):
        ok = False
        for a in range(1, 97):
            b = (int(y[row, 0]) - a * int(x[row, 0])) % v
            if all((a * int(x[row, t]) + b) % v == int(y[row, t])
                   for t in range(8)):
                ok = True
                break
        assert ok, f"row {row} is not a mod-{v} chain"


def test_embeddings_mode_stub_frontend():
    cfg = registry.get("musicgen-large", reduced=True)
    batch = make_batch(cfg, DataConfig(seed=0), 0, 2, 8)
    assert batch["inputs"].shape == (2, 8, cfg.d_model)
    assert batch["inputs"].dtype == np.float32
    assert batch["labels"].shape == (2, 8)


def test_mrope_positions():
    cfg = registry.get("qwen2-vl-7b", reduced=True)
    batch = make_batch(cfg, DataConfig(seed=0), 0, 2, 8)
    assert batch["positions"].shape == (2, 8, 3)


def test_loader_iteration():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    loader = ShardedLoader(cfg, DataConfig(seed=0), batch=2, seq=8)
    it = iter(loader)
    b0, b1 = next(it), next(it)
    assert b0["inputs"].shape == (2, 8)
    assert not np.array_equal(np.asarray(b0["inputs"]),
                              np.asarray(b1["inputs"]))