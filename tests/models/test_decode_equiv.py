"""Decode ≡ full-forward equivalence: stepping tokens one-by-one through the
multi-port KV cache must reproduce the training forward's logits (E4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import decode_step, forward, init_decode_state, init_params, prefill

ARCHS = ["tinyllama-1.1b", "qwen2.5-3b", "deepseek-moe-16b", "rwkv6-3b",
         "zamba2-7b", "musicgen-large", "qwen2-vl-7b"]
B, S = 2, 12


def _inputs(cfg, key):
    if cfg.input_mode == "tokens":
        return jax.random.randint(key, (B, S), 0, cfg.vocab)
    return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)


@pytest.mark.parametrize("arch_id", ARCHS)
def test_stepwise_decode_matches_forward(arch_id):
    cfg = registry.get(arch_id, reduced=True)
    if cfg.moe is not None:  # avoid capacity drops breaking exactness
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=4.0))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    inputs = _inputs(cfg, key)
    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(
        params, {"inputs": inputs})

    state = init_decode_state(cfg, B, 32)
    step = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b))
    outs = []
    for t in range(S):
        state, lg = step(params, state, {"inputs": inputs[:, t:t + 1]})
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits),
                               atol=3e-3, rtol=1e-3)


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "zamba2-7b", "rwkv6-3b"])
def test_prefill_then_decode_matches_forward(arch_id):
    """prefill(prompt) + decode(one token) == forward logits at that step."""
    cfg = registry.get(arch_id, reduced=True)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    inputs = _inputs(cfg, key)
    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(
        params, {"inputs": inputs})

    split = S // 2
    state = init_decode_state(cfg, B, 32)
    state, lg_prefill = jax.jit(lambda p, s, b: prefill(p, cfg, s, b))(
        params, state, {"inputs": inputs[:, :split]})
    np.testing.assert_allclose(np.asarray(lg_prefill),
                               np.asarray(logits[:, split - 1]),
                               atol=3e-3, rtol=1e-3)
    step = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b))
    for t in range(split, S):
        state, lg = step(params, state, {"inputs": inputs[:, t:t + 1]})
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, t]),
                                   atol=3e-3, rtol=1e-3)


def test_multiport_kernel_mode_matches_reference_mode():
    """decode with the fused Pallas path == two-pass reference path."""
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    inputs = jax.random.randint(key, (B, S), 0, cfg.vocab)
    s_ref = init_decode_state(cfg, B, 64)
    s_ker = init_decode_state(cfg, B, 64)
    step_r = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b,
                                                 kernel_mode="reference"))
    step_k = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b,
                                                 kernel_mode="multiport"))
    for t in range(S):
        b = {"inputs": inputs[:, t:t + 1]}
        s_ref, lr = step_r(params, s_ref, b)
        s_ker, lk = step_k(params, s_ker, b)
        np.testing.assert_allclose(np.asarray(lk), np.asarray(lr),
                                   atol=2e-4, rtol=1e-4)
