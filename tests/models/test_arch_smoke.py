"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU; asserts output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_batch
from repro.models import forward, init_params, loss_fn
from repro.optim import AdamWConfig, make_optimizer

B, S = 2, 16


def _batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in make_batch(cfg, DataConfig(), 0, B, S).items()}


@pytest.mark.parametrize("arch_id", registry.ARCH_IDS)
def test_reduced_forward_and_train_step(arch_id):
    cfg = registry.get(arch_id, reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite logits"

    # one full optimizer step
    opt_init, opt_update, _ = make_optimizer("adamw", AdamWConfig())
    opt = opt_init(params)

    @jax.jit
    def train_one(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
        params, opt, stats = opt_update(grads, opt, params, 1e-3)
        return params, opt, loss, stats["grad_norm"]

    params2, opt2, loss, gnorm = train_one(params, opt, batch)
    assert bool(jnp.isfinite(loss)), f"{arch_id}: non-finite loss"
    assert bool(jnp.isfinite(gnorm)), f"{arch_id}: non-finite grad norm"
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x[0] - x[1]).sum()),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, params2), 0.0)
    assert moved > 0.0, f"{arch_id}: optimizer step was a no-op"


@pytest.mark.parametrize("arch_id", registry.ARCH_IDS)
def test_full_config_constructs(arch_id):
    """Full configs build and report sane analytic sizes (no allocation)."""
    cfg = registry.get(arch_id)
    n = cfg.param_count()
    assert n > 1e8, arch_id
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    total = sum(np.prod(l.shape) for l in jax.tree_util.tree_leaves(shapes))
    # analytic count within 2% of the real tree
    assert abs(total - n) / n < 0.02, (arch_id, total, n)
