"""Property tests: chunked linear attention == stepwise recurrence for both
SSD (Mamba2) and bonus (RWKV6) semantics, across chunk sizes and decays."""
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra")
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from repro.models.linear_scan import (chunked_linear_attention,
                                      linear_attention_step)


def _stepwise(q, k, v, lw, bonus):
    B, T, H, K = q.shape
    V = v.shape[-1]
    state = jnp.zeros((B, H, K, V))
    ys = []
    for t in range(T):
        y, state = linear_attention_step(q[:, t], k[:, t], v[:, t], lw[:, t],
                                         state, bonus_u=bonus)
        ys.append(y)
    return jnp.stack(ys, 1), state


@hp.given(
    t=st.integers(1, 40),
    chunk=st.sampled_from([2, 4, 8, 16, 64]),
    use_bonus=st.booleans(),
    seed=st.integers(0, 2**16),
)
@hp.settings(max_examples=30, deadline=None)
def test_chunked_equals_stepwise(t, chunk, use_bonus, seed):
    rng = np.random.default_rng(seed)
    B, H, K, V = 2, 2, 4, 3
    q = jnp.asarray(rng.normal(size=(B, t, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, t, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, t, H, V)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(B, t, H, K))) * 2, jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)), jnp.float32) if use_bonus else None

    yc, sc = chunked_linear_attention(q, k, v, lw, chunk=chunk, bonus_u=u)
    yr, sr = _stepwise(q, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr),
                               atol=1e-4, rtol=1e-4)


def test_initial_state_threading():
    """Splitting a sequence in two with state carry == one pass."""
    rng = np.random.default_rng(0)
    B, T, H, K, V = 1, 24, 2, 4, 4
    q = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, V)), jnp.float32)
    lw = jnp.asarray(-np.abs(rng.normal(size=(B, T, H, K))), jnp.float32)

    y_full, s_full = chunked_linear_attention(q, k, v, lw, chunk=8)
    y1, s1 = chunked_linear_attention(q[:, :10], k[:, :10], v[:, :10],
                                      lw[:, :10], chunk=8)
    y2, s2 = chunked_linear_attention(q[:, 10:], k[:, 10:], v[:, 10:],
                                      lw[:, 10:], chunk=8, initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


def test_strong_decay_no_overflow():
    """Very strong decay (log_w << 0) must not produce inf/nan — the pairwise
    masked-decay formulation is overflow-free by construction."""
    B, T, H, K, V = 1, 32, 1, 4, 4
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, V)), jnp.float32)
    lw = jnp.full((B, T, H, K), -30.0, jnp.float32)
    y, s = chunked_linear_attention(q, k, v, lw, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(s).all())
