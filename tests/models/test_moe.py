"""MoE layer: dispatch/combine correctness against a token-loop reference,
capacity-drop behavior, and aux-loss sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.mlp import moe_apply, moe_init, swiglu_apply


def _reference_moe(p, x, cfg):
    """Per-token loop: route, run top-k experts densely, weighted-sum."""
    b, s, d = x.shape
    logits = x @ np.asarray(p["router"]["w"], np.float32)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, cfg.top_k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)
    wg, wu, wd = (np.asarray(p[k], np.float32)
                  for k in ("w_gate", "w_up", "w_down"))
    out = np.zeros((b, s, d), np.float32)
    xs = np.asarray(x, np.float32)
    for bi in range(b):
        for si in range(s):
            tok = xs[bi, si]
            for j in range(cfg.top_k):
                e = int(gate_e[bi, si, j])
                g = tok @ wg[e]
                u = tok @ wu[e]
                h = (g * jax.nn.sigmoid(g)) * u
                out[bi, si] += float(gate_w[bi, si, j]) * np.asarray(h @ wd[e])
    if "shared" in p:
        out = out + np.asarray(swiglu_apply(p["shared"], x), np.float32)
    return out


@pytest.mark.parametrize("n_shared", [0, 1])
def test_moe_matches_token_loop_reference(n_shared):
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, n_shared=n_shared,
                    capacity_factor=8.0)   # generous: no drops
    key = jax.random.PRNGKey(0)
    p = moe_init(key, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    got, aux = moe_apply(p, x, cfg)
    want = _reference_moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5, rtol=2e-4)
    assert float(aux) >= 0


def test_capacity_drops_tokens_but_stays_finite():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, capacity_factor=0.25)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    got, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(got).all())
    # with tight capacity, output differs from the no-drop reference
    ref = _reference_moe(p, x, cfg)
    assert not np.allclose(np.asarray(got), ref, atol=1e-5)


def test_moe_grads_flow_to_all_param_groups():
    cfg = MoEConfig(n_experts=4, top_k=2, d_expert=8, n_shared=1,
                    capacity_factor=4.0)
    p = moe_init(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.sum(y * y) + aux
    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        assert float(jnp.abs(leaf).sum()) > 0, f"zero grad at {name}"
