"""Fault-injection harness: plan determinism, invariant audit teeth, and
the end-to-end contract — a chaos run's SURVIVORS (neither shed nor
cancelled) generate tokens identical to the fault-free run of the same
schedule. Faults change who finishes and when, never what is generated.

The audit itself is tested adversarially: a deliberately corrupted pool
(duplicated free page, orphaned table) must RAISE — an invariant checker
that passes everything would make every chaos gate vacuous.
"""
import time

import jax
import pytest

from repro.configs import registry
from repro.distributed.fault import Heartbeat
from repro.models import init_params
from repro.serve.chaos import (KINDS, ChaosHarness, Fault, FaultPlan,
                               InvariantViolation, check_invariants)
from repro.serve.engine import MultiPortEngine
from repro.serve.traffic import Arrival, drive, poisson_arrivals


@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(params, cfg):
    return MultiPortEngine(params, cfg, slots=2, max_slots=2, max_len=32,
                           seq_tile=8, chunk_tokens=8)


def _arrivals(cfg, n=10):
    return poisson_arrivals(n, 0.8, seed=3, vocab=cfg.vocab,
                            max_prompt=16, max_output=4)


# ---------------------------------------------------------------------------
# plan generation

def test_fault_plan_deterministic_and_sorted():
    a = FaultPlan.generate(7, 40)
    b = FaultPlan.generate(7, 40)
    assert a == b                                    # bit-for-bit
    assert a != FaultPlan.generate(8, 40)
    ticks = [f.tick for f in a.faults]
    assert ticks == sorted(ticks)
    assert {f.kind for f in a.faults} == set(KINDS)  # every kind cycled in
    assert all(0 <= f.tick < 40 for f in a.faults)


def test_fault_validation():
    with pytest.raises(ValueError):
        Fault(tick=0, kind="meteor")
    with pytest.raises(ValueError):
        Fault(tick=-1, kind="stall")
    with pytest.raises(ValueError):
        Fault(tick=0, kind="squeeze", magnitude=0)
    with pytest.raises(ValueError):
        Fault(tick=0, kind="cancel", choice=1.0)
    with pytest.raises(ValueError):
        FaultPlan.generate(0, horizon=0)
    with pytest.raises(ValueError):
        FaultPlan.generate(0, 10, kinds=("squeeze", "meteor"))


# ---------------------------------------------------------------------------
# the invariant audit has teeth

def test_check_invariants_clean_engine(served):
    cfg, params = served
    eng = _engine(params, cfg)
    check_invariants(eng)                            # no-op on a fresh pool
    eng.submit([1, 2, 3], max_new=2)
    eng.step()
    check_invariants(eng)                            # and mid-flight


def test_check_invariants_catches_duplicate_free_page(served):
    cfg, params = served
    eng = _engine(params, cfg)
    eng.pool.free_by_shard[0].append(eng.pool.free_by_shard[0][0])
    with pytest.raises(InvariantViolation):
        check_invariants(eng)


def test_check_invariants_catches_orphan_table(served):
    cfg, params = served
    eng = _engine(params, cfg)
    page = eng.pool.free_by_shard[0].pop()
    eng.pool.tables[999] = [page]                    # rid not in any slot
    with pytest.raises(InvariantViolation):
        check_invariants(eng)


# ---------------------------------------------------------------------------
# end-to-end: survivors are token-identical to the fault-free run

def test_chaos_run_survivor_token_identity(served):
    cfg, params = served
    arrivals = _arrivals(cfg)

    ref = _engine(params, cfg)
    drive(ref, arrivals)
    ref_toks = {r.rid: tuple(r.generated) for r in ref.finished}
    assert len(ref_toks) == len(arrivals)

    plan = FaultPlan.generate(23, horizon=arrivals[-1].arrival_tick + 1,
                              max_squeeze=4)
    eng = _engine(params, cfg)
    harness = ChaosHarness(plan)
    drive(eng, arrivals, on_cycle=harness)
    harness.finalize(eng)

    assert harness.invariant_checks >= len(plan.faults) + 1
    assert [i["kind"] for i in harness.injected if i["kind"] in KINDS]
    survivors = [r for r in eng.finished
                 if not r.cancelled and r.shed_reason is None]
    assert survivors, "chaos run must still serve someone"
    for r in survivors:
        assert tuple(r.generated) == ref_toks[r.rid], r.rid
    # everyone is accounted for exactly once
    served_rids = {r.rid for r in eng.finished}
    shed_rids = {r.rid for r in eng.shed}
    assert served_rids | shed_rids == set(ref_toks)
    assert not served_rids & shed_rids
    check_invariants(eng)                            # final state clean


def test_chaos_stall_preserves_tokens(served):
    """A pure-stall plan (delayed retirement only): every request still
    finishes, tokens untouched — the stall moves retirement, not data."""
    cfg, params = served
    arrivals = _arrivals(cfg, n=6)
    ref = _engine(params, cfg)
    drive(ref, arrivals)

    plan = FaultPlan(seed=0, faults=(
        Fault(tick=1, kind="stall", magnitude=2),
        Fault(tick=4, kind="stall", magnitude=3),
    ))
    eng = _engine(params, cfg)
    harness = ChaosHarness(plan)
    drive(eng, arrivals, on_cycle=harness)
    harness.finalize(eng)
    assert eng.stalled_retirements > 0               # the stall really bit
    assert ({r.rid: tuple(r.generated) for r in eng.finished}
            == {r.rid: tuple(r.generated) for r in ref.finished})


def test_fault_in_idle_stretch_fires_on_real_cycle(served):
    """Satellite regression (injection-tick vs plan-tick): a fault whose
    plan tick lands inside an idle stretch used to be injected on a cycle
    that never ran a traversal — drive() called the hook before
    discovering there was no pending work, so the fault's effect was
    consumed by the idle fast-forward and its effective tick silently
    drifted. Now the hook fires ONLY on cycles that step: the fault lands
    on the first real macro-cycle after the gap, with its plan tick and
    residual drift stamped on the injected record."""
    cfg, params = served
    arrivals = (Arrival(arrival_tick=0, prompt=(5, 7, 11, 13), max_new=2),
                Arrival(arrival_tick=500, prompt=(3, 9, 2, 6), max_new=2))
    ref = _engine(params, cfg)
    drive(ref, arrivals)

    # plan tick 400: strictly inside the idle gap between the clusters
    plan = FaultPlan(seed=0, faults=(
        Fault(tick=400, kind="stall", magnitude=2),))
    harness = ChaosHarness(plan)
    seen = []

    def hook(eng):
        seen.append((eng.vclock, eng.pending_work()))
        harness(eng)

    eng = _engine(params, cfg)
    drive(eng, arrivals, on_cycle=hook)
    harness.finalize(eng)

    # the hook only ever fires on cycles with real work to step
    assert seen and all(pw for _, pw in seen)
    # and the plan tick really fell where no stepping cycle's clock landed
    assert all(not (400 <= v < 500) for v, _ in seen)
    rec = next(i for i in harness.injected if i["kind"] == "stall")
    assert rec["plan_tick"] == 400
    assert rec["tick"] >= 500                # first REAL cycle after the gap
    assert rec["drift"] == rec["tick"] - 400 > 0
    assert eng.retire_stall_cycles == 0      # the stall drained in-run
    # faults move WHEN, never WHAT: tokens identical to the fault-free run
    assert ({r.rid: tuple(r.generated) for r in eng.finished}
            == {r.rid: tuple(r.generated) for r in ref.finished})


# ---------------------------------------------------------------------------
# distributed/fault.py wiring: heartbeat + straggler detector

def test_chaos_harness_heartbeat_and_straggler(served, tmp_path):
    cfg, params = served
    arrivals = _arrivals(cfg, n=6)
    plan = FaultPlan.generate(5, horizon=8)
    harness = ChaosHarness(plan, heartbeat_dir=str(tmp_path),
                           worker="chaos0", straggler_multiplier=0.5)
    eng = _engine(params, cfg)
    drive(eng, arrivals, on_cycle=harness)
    harness.finalize(eng)

    beat = tmp_path / "heartbeat_chaos0"
    assert beat.exists()
    step, stamp = beat.read_text().split()
    assert int(step) <= eng.cycles and float(stamp) <= time.time()
    assert Heartbeat.stale_workers(str(tmp_path), timeout_s=3600) == []
    # multiplier 0.5 flags any tick-delta above half the EMA: the idle
    # gaps in a Poisson schedule guarantee outliers after warmup
    assert harness.straggler_events > 0
    assert harness.straggler.events
