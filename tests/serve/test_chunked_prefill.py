"""Property: chunked batched prefill is token-identical to per-request
(single-chunk) prefill for random prompt lengths, chunk sizes and slot
counts — including slot pools grown past the seed's 4 and requests admitted
mid-stream while earlier requests are already decoding."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, prompts, *, chunk_tokens, slots, max_slots,
           admit_split, max_new=3):
    """Run the engine admitting ``prompts[:admit_split]`` up front and the
    rest mid-stream (after the first batch has started decoding)."""
    eng = MultiPortEngine(params, cfg, slots=slots, max_slots=max_slots,
                          max_len=64, chunk_tokens=chunk_tokens)
    for p in prompts[:admit_split]:
        eng.submit(p, max_new=max_new)
    for _ in range(3):                     # first admissions reach decode
        if eng.pending_work():
            eng.step()
    for p in prompts[admit_split:]:
        eng.submit(p, max_new=max_new)
    done = eng.run(max_cycles=2000)
    assert len(done) == len(prompts)
    return {r.rid: tuple(r.generated) for r in done}, eng


def _check(cfg, params, prompt_lens, chunk_tokens, slots, max_slots,
           admit_split):
    rng = np.random.default_rng(sum(prompt_lens) + chunk_tokens + slots)
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in prompt_lens]
    got, eng = _serve(cfg, params, prompts, chunk_tokens=chunk_tokens,
                      slots=slots, max_slots=max_slots,
                      admit_split=admit_split)
    # baseline: every prompt prefilled in ONE chunk (per-request prefill
    # compute), ample slots, all admitted up front
    want, _ = _serve(cfg, params, prompts, chunk_tokens=64,
                     slots=len(prompts), max_slots=len(prompts),
                     admit_split=len(prompts))
    assert got == want, (chunk_tokens, slots, max_slots, got, want)
    return eng


def test_chunked_prefill_fixed_cases(setup):
    """Deterministic spot-checks of the property (run even without the
    ``dev`` extra): tiny chunks, growth past 4 slots, mid-stream admission."""
    cfg, params = setup
    eng = _check(cfg, params, [3, 9, 5, 12, 7, 4], chunk_tokens=4, slots=2,
                 max_slots=6, admit_split=6)     # one burst: must grow
    assert eng.n_slots == 6                      # grew past the seed's cap
    _check(cfg, params, [3, 9, 5, 12, 7, 4], chunk_tokens=4, slots=2,
           max_slots=6, admit_split=3)           # mid-stream admissions
    _check(cfg, params, [11, 2], chunk_tokens=1, slots=1, max_slots=2,
           admit_split=1)


def test_prefill_chunk_specs_match_model_contract(setup):
    """launch.specs.prefill_chunk_specs must stay in sync with the batch
    dict repro.models.prefill_chunk actually consumes (the dry-run's
    no-allocation stand-in for the engine's admission compute)."""
    cfg, params = setup
    from repro.launch.specs import decode_state_shapes, prefill_chunk_specs
    from repro.models import prefill_chunk
    batch = prefill_chunk_specs(cfg, 4, 8)
    state = decode_state_shapes(cfg, 4, 64)
    out_state, logits = jax.eval_shape(
        lambda p, s, b: prefill_chunk(p, cfg, s, b), params, state, batch)
    assert logits.shape == (4, cfg.vocab)
    assert out_state["cache_k"].shape == state["cache_k"].shape


def test_seq_tile_buckets_validation():
    """launch.specs.seq_tile_buckets is the raw bucket ladder: power-of-two
    tile counts covering S_max, rejecting tiles that cannot tile the cache.
    (--seq-tile validation itself goes through
    ``MultiPortEngine.final_stage_ladder``, which layers the engine's
    seq_tile clamp on top of these buckets — checked below.)"""
    from repro.launch.specs import seq_tile_buckets
    assert seq_tile_buckets(64, 8) == (8, 16, 32, 64)
    assert seq_tile_buckets(128, 128) == (128,)
    # awkward capacity: the tail pads UP to a whole tile count (112 = 7*16)
    # so staged lengths never need degenerate fit-down tile sizes
    assert seq_tile_buckets(100, 16) == (16, 32, 64, 112)
    with pytest.raises(ValueError):
        seq_tile_buckets(64, 0)
    with pytest.raises(ValueError):
        seq_tile_buckets(64, 128)              # tile exceeds S_max
    # the launcher's validation surface wraps these buckets with the
    # engine's clamp: an oversized tile validates clamped, not rejected
    assert MultiPortEngine.final_stage_ladder(64, 8) == seq_tile_buckets(64, 8)
    assert MultiPortEngine.final_stage_ladder(64, 128) == (64,)


def test_engine_stage_lengths_walk_the_bucket_ladder(setup):
    """The bucketed fallback (dynamic_grid=False) stages exactly the ladder
    the launcher validates --seq-tile against — including awkward
    capacities, where the padded tail keeps every staged length a whole
    tile count. The dynamic-grid default stages only the padded capacity
    (the ladder's last entry)."""
    cfg, params = setup
    from repro.launch.specs import seq_tile_buckets
    eng = MultiPortEngine(params, cfg, slots=2, max_len=100, seq_tile=16,
                          dynamic_grid=False)
    ladder = seq_tile_buckets(100, 16)
    assert eng._stage_buckets == ladder == (16, 32, 64, 112)
    for need in range(1, 101):
        got = eng._stage_len(need)
        assert got in ladder and got >= need
        assert got % eng.seq_tile == 0
    dyn = MultiPortEngine(params, cfg, slots=2, max_len=100, seq_tile=16)
    assert all(dyn._stage_len(need) == ladder[-1]
               for need in (1, 50, 100))


def test_chunked_prefill_property(setup):
    """Randomized version (CI installs the ``dev`` extra; skips locally)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    cfg, params = setup

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(
        prompt_lens=st.lists(st.integers(2, 12), min_size=1, max_size=6),
        chunk_tokens=st.sampled_from([1, 3, 4, 8]),
        slots=st.integers(1, 3),
        extra_slots=st.integers(0, 5),
        data=st.data())
    def prop(prompt_lens, chunk_tokens, slots, extra_slots, data):
        max_slots = min(slots + extra_slots, 8)
        admit_split = data.draw(
            st.integers(1, len(prompt_lens)), label="admit_split")
        _check(cfg, params, prompt_lens, chunk_tokens, slots, max_slots,
               admit_split)

    prop()
