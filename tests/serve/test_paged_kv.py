"""Paged KV pool on the multi-port memory: paging correctness, port
priority semantics (append visible to same-cycle reads), allocation reuse."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.memory.paged_kv import PagedPool


def _pool(**kw):
    return PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, **kw)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_prefill_then_read(use_kernel):
    pool = _pool(use_kernel=use_kernel)
    rng = np.random.default_rng(0)
    prompt = rng.normal(size=(10, 8)).astype(np.float32)   # spans 3 pages
    pool.cycle(prefill={"seq": 1, "vectors": prompt})
    out = pool.cycle(read={"seq": 1, "positions": np.arange(10)})["read"]
    np.testing.assert_allclose(np.asarray(out), prompt, atol=1e-6)
    assert pool.lengths[1] == 10 and len(pool.tables[1]) == 3


def test_append_visible_to_same_cycle_read():
    """Port A (append, priority 1) writes BEFORE port B (read) — the paper's
    same-cycle W->R visibility, now at the KV-pool level."""
    pool = _pool()
    rng = np.random.default_rng(1)
    prompt = rng.normal(size=(3, 8)).astype(np.float32)
    pool.cycle(prefill={"seq": 7, "vectors": prompt})
    new = rng.normal(size=(1, 8)).astype(np.float32)
    out = pool.cycle(append={"seq": 7, "vectors": new},
                     read={"seq": 7, "positions": np.arange(4)})["read"]
    np.testing.assert_allclose(np.asarray(out[:3]), prompt, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[3:]), new, atol=1e-6)


def test_multiple_sequences_share_pool_without_interference():
    pool = _pool()
    rng = np.random.default_rng(2)
    a = rng.normal(size=(5, 8)).astype(np.float32)
    b = rng.normal(size=(6, 8)).astype(np.float32)
    pool.cycle(prefill={"seq": 1, "vectors": a})
    pool.cycle(prefill={"seq": 2, "vectors": b})
    ra = pool.cycle(read={"seq": 1, "positions": np.arange(5)})["read"]
    rb = pool.cycle(read={"seq": 2, "positions": np.arange(6)})["read"]
    np.testing.assert_allclose(np.asarray(ra), a, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rb), b, atol=1e-6)
    assert pool.utilization == pytest.approx((2 + 2) / 8)


def test_free_recycles_pages():
    pool = _pool()
    x = np.ones((16, 8), np.float32)           # 4 pages
    pool.cycle(prefill={"seq": 1, "vectors": x})
    assert len(pool.free_pages) == 4
    pool.free(1)
    assert len(pool.free_pages) == 8
    # a new sequence reuses the freed pages
    pool.cycle(prefill={"seq": 2, "vectors": 2 * x})
    out = pool.cycle(read={"seq": 2, "positions": np.arange(16)})["read"]
    np.testing.assert_allclose(np.asarray(out), 2 * x)


def test_pool_exhaustion_raises():
    pool = _pool()
    with pytest.raises(MemoryError):
        pool.cycle(prefill={"seq": 1, "vectors": np.ones((33, 8), np.float32)})