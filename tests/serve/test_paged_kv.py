"""Paged KV pool on the multi-port memory: paging correctness, port
priority semantics (append visible to same-cycle reads), allocation reuse."""
import numpy as np
import pytest

from repro.memory.paged_kv import PagedPool, PoolCapacityError


def _pool(**kw):
    return PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, **kw)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_prefill_then_read(use_kernel):
    pool = _pool(use_kernel=use_kernel)
    rng = np.random.default_rng(0)
    prompt = rng.normal(size=(10, 8)).astype(np.float32)   # spans 3 pages
    pool.cycle(prefill={"seq": 1, "vectors": prompt})
    out = pool.cycle(read={"seq": 1, "positions": np.arange(10)})["read"]
    np.testing.assert_allclose(np.asarray(out), prompt, atol=1e-6)
    assert pool.lengths[1] == 10 and len(pool.tables[1]) == 3


def test_append_visible_to_same_cycle_read():
    """Port A (append, priority 1) writes BEFORE port B (read) — the paper's
    same-cycle W->R visibility, now at the KV-pool level."""
    pool = _pool()
    rng = np.random.default_rng(1)
    prompt = rng.normal(size=(3, 8)).astype(np.float32)
    pool.cycle(prefill={"seq": 7, "vectors": prompt})
    new = rng.normal(size=(1, 8)).astype(np.float32)
    out = pool.cycle(append={"seq": 7, "vectors": new},
                     read={"seq": 7, "positions": np.arange(4)})["read"]
    np.testing.assert_allclose(np.asarray(out[:3]), prompt, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out[3:]), new, atol=1e-6)


def test_multiple_sequences_share_pool_without_interference():
    pool = _pool()
    rng = np.random.default_rng(2)
    a = rng.normal(size=(5, 8)).astype(np.float32)
    b = rng.normal(size=(6, 8)).astype(np.float32)
    pool.cycle(prefill={"seq": 1, "vectors": a})
    pool.cycle(prefill={"seq": 2, "vectors": b})
    ra = pool.cycle(read={"seq": 1, "positions": np.arange(5)})["read"]
    rb = pool.cycle(read={"seq": 2, "positions": np.arange(6)})["read"]
    np.testing.assert_allclose(np.asarray(ra), a, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rb), b, atol=1e-6)
    assert pool.utilization == pytest.approx((2 + 2) / 8)


def test_free_recycles_pages():
    pool = _pool()
    x = np.ones((16, 8), np.float32)           # 4 pages
    pool.cycle(prefill={"seq": 1, "vectors": x})
    assert len(pool.free_pages) == 4
    pool.free(1)
    assert len(pool.free_pages) == 8
    # a new sequence reuses the freed pages
    pool.cycle(prefill={"seq": 2, "vectors": 2 * x})
    out = pool.cycle(read={"seq": 2, "positions": np.arange(16)})["read"]
    np.testing.assert_allclose(np.asarray(out), 2 * x)


def test_pool_exhaustion_raises():
    pool = _pool()
    with pytest.raises(MemoryError):
        pool.cycle(prefill={"seq": 1, "vectors": np.ones((33, 8), np.float32)})


def test_over_capacity_admission_is_transactional():
    """An admission that exceeds pool capacity raises a clear error BEFORE
    any state mutation: no pages leak, and a fitting admission still works."""
    pool = _pool()                                  # 8 pages x 4 tokens
    pool.cycle(prefill={"seq": 1, "vectors": np.ones((16, 8), np.float32)})
    free_before = list(pool.free_pages)
    with pytest.raises(PoolCapacityError, match="pages"):
        pool.cycle(prefill=[{"seq": 2, "vectors": np.ones((12, 8), np.float32)},
                            {"seq": 3, "vectors": np.ones((8, 8), np.float32)}])
    # nothing committed: free list, tables and lengths are untouched
    assert pool.free_pages == free_before
    assert 2 not in pool.tables and 3 not in pool.tables
    assert pool.lengths == {1: 16}
    # the pool is still serviceable after the refused transaction
    pool.cycle(prefill={"seq": 2, "vectors": np.ones((16, 8), np.float32)})
    assert pool.lengths[2] == 16


def test_over_capacity_append_counts_existing_pages():
    """Growing an existing sequence only demands the DELTA pages; a grow that
    fits the partially-filled tail page is not refused."""
    pool = _pool()
    pool.cycle(prefill={"seq": 1, "vectors": np.ones((30, 8), np.float32)})
    pool.cycle(append={"seq": 1, "vectors": np.ones((2, 8), np.float32)})
    assert pool.lengths[1] == 32
    with pytest.raises(PoolCapacityError):
        pool.cycle(append={"seq": 1, "vectors": np.ones((1, 8), np.float32)})


def test_bad_read_aborts_cycle_before_writes_land():
    """A cycle whose READ stream is out of range is refused up front: its
    write streams must not land either (no half-committed transactions).
    Same-cycle append + read of the just-appended fresh-page position stays
    legal — reads are validated against the projected post-write mapping."""
    pool = _pool()
    pool.cycle(prefill={"seq": 1, "vectors": np.ones((4, 8), np.float32)})
    free_before = list(pool.free_pages)
    with pytest.raises(IndexError):
        pool.cycle(append={"seq": 1, "vectors": np.ones((1, 8), np.float32)},
                   read={"seq": 1, "positions": np.arange(99)})
    assert pool.lengths == {1: 4}
    assert pool.free_pages == free_before
    # append crosses into a fresh page; reading position 4 in the SAME cycle
    # is within the projected mapping and must succeed
    out = pool.cycle(append={"seq": 1,
                             "vectors": 2 * np.ones((1, 8), np.float32)},
                     read={"seq": 1, "positions": np.arange(5)})["read"]
    assert pool.lengths[1] == 5
    np.testing.assert_allclose(np.asarray(out)[4], 2.0)


def test_read_past_mapped_words_raises():
    """Out-of-range positions (including negative ones, which numpy would
    silently wrap around to the table's tail) raise a clear IndexError."""
    pool = _pool()
    pool.cycle(prefill={"seq": 1, "vectors": np.ones((6, 8), np.float32)})
    with pytest.raises(IndexError, match="page table"):
        pool.cycle(read={"seq": 1, "positions": np.arange(6, 12)})
    with pytest.raises(IndexError, match="page table"):
        pool.cycle(read={"seq": 1, "positions": np.asarray([-1])})
    with pytest.raises(IndexError, match="no pages"):
        pool.cycle(read={"seq": 9, "positions": np.arange(2)})