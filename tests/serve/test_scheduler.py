"""Dependency-tracked macro-cycle scheduler: hazard rules at page
granularity, the static-walk oracle, and the engine integration — on a
mixed prefill+decode workload the ooo scheduler merges hazard-free phases
into shared multi-port traversals while staying token-identical to the
rigid walk across schedule modes, kernel modes and port budgets.

This module also runs in the CI ``tier1-multidevice`` job (see
.github/workflows/ci.yml); the sharded test spawns its own forced-8-device
subprocess like tests/distributed does."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.ports import READ, WRITE
from repro.memory.paged_kv import APPEND, ATTN_READ, BULK_FILL, SCRUB
from repro.serve.scheduler import PhaseTxn, PortTxn, conflicts, plan

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

# the engine's program-order phase ids (engine.EVICT/PREFILL/DECODE)
EVICT, PREFILL, DECODE = 0, 1, 2


def _evict(pages):
    return PhaseTxn(EVICT, "evict",
                    (PortTxn(SCRUB, WRITE, frozenset(pages)),))


def _prefill(pages):
    return PhaseTxn(PREFILL, "prefill",
                    (PortTxn(BULK_FILL, WRITE, frozenset(pages)),))


def _decode(append_pages, read_pages):
    txns = []
    if append_pages is not None:
        txns.append(PortTxn(APPEND, WRITE, frozenset(append_pages)))
    if read_pages is not None:
        txns.append(PortTxn(ATTN_READ, READ, frozenset(read_pages)))
    return PhaseTxn(DECODE, "decode", tuple(txns))


# --------------------------------------------------------------------------
# hazard rules
# --------------------------------------------------------------------------

def test_raw_same_page_prefill_then_decode_never_coschedules():
    """Same-page prefill write then decode read is a RAW hazard: two
    traversals, even though in-traversal service order would happen to
    read-after-write correctly — the conservative split is the contract."""
    phases = [_prefill({3}), _decode({5}, {3, 5})]
    assert conflicts(phases[0], phases[1]) == "raw"
    sched = plan(phases, mode="ooo")
    assert len(sched.traversals) == 2
    assert not sched.co_scheduled
    assert [t.phase_ids() for t in sched.traversals] == [(PREFILL,), (DECODE,)]


def test_disjoint_pages_coschedule_into_one_multiport_traversal():
    """Prefill writes and decode append/read of DISJOINT pages share ONE
    pool traversal with a 3-port 2W+1R mix, priority = program order."""
    phases = [_prefill({3}), _decode({5}, {5, 6})]
    assert conflicts(phases[0], phases[1]) is None
    sched = plan(phases, mode="ooo")
    assert len(sched.traversals) == 1
    assert sched.co_scheduled
    trav = sched.traversals[0]
    assert trav.ports() == (BULK_FILL, APPEND, ATTN_READ)
    assert trav.priority() == (BULK_FILL, APPEND, ATTN_READ, SCRUB)
    cfg = trav.port_config()
    assert cfg.mix() == "2W+1R"
    assert cfg.service_order() == (BULK_FILL, APPEND, ATTN_READ)
    assert cfg.describe() == "3-port[2W+1R|C:W > A:W > B:R]"


def test_waw_coschedules_with_program_order_priority():
    """Evict's scrub and a decode append hitting the same (reused) page are
    WAW — co-schedulable because the traversal services program order:
    scrub first, append's words land last (the fix over the old fixed pool
    priority that serviced APPEND before SCRUB)."""
    phases = [_evict({2}), _decode({2}, None)]
    assert conflicts(phases[0], phases[1]) is None     # WAW, not a hazard
    sched = plan(phases, mode="ooo")
    assert len(sched.traversals) == 1 and sched.co_scheduled
    assert sched.traversals[0].port_config().service_order() == \
        (SCRUB, APPEND)


def test_war_never_coschedules():
    a = PhaseTxn(0, "reader", (PortTxn(ATTN_READ, READ, frozenset({4})),))
    b = PhaseTxn(1, "writer", (PortTxn(SCRUB, WRITE, frozenset({4})),))
    assert conflicts(a, b) == "war"
    assert len(plan([a, b], mode="ooo").traversals) == 2


def test_port_collision_splits_even_disjoint_pages():
    a = PhaseTxn(0, "w1", (PortTxn(BULK_FILL, WRITE, frozenset({1})),))
    b = PhaseTxn(1, "w2", (PortTxn(BULK_FILL, WRITE, frozenset({9})),))
    assert conflicts(a, b) == "port"
    assert len(plan([a, b], mode="ooo").traversals) == 2


def test_intra_phase_append_read_pair_is_exempt():
    """A decode phase's own append+read of the same page stays ONE
    traversal: the in-traversal W-before-R service order IS the fused
    kernel's same-cycle contract; hazard rules apply between phases."""
    sched = plan([_decode({7}, {7})], mode="ooo")
    assert len(sched.traversals) == 1
    assert sched.traversals[0].ports() == (APPEND, ATTN_READ)
    assert not sched.co_scheduled      # one phase, nothing merged


# --------------------------------------------------------------------------
# modes, port budget, role splitting
# --------------------------------------------------------------------------

def test_static_mode_is_the_rigid_walk_oracle():
    phases = [_evict({0}), _prefill({3}), _decode({5}, {5, 6})]
    sched = plan(phases, mode="static")
    assert [t.phase_ids() for t in sched.traversals] == \
        [(EVICT,), (PREFILL,), (DECODE,)]
    assert not sched.co_scheduled


def test_max_ports_one_presplits_to_single_txn_traversals():
    sched = plan([_decode({5}, {5, 6})], mode="ooo", max_ports=1)
    assert len(sched.traversals) == 2
    assert [t.ports() for t in sched.traversals] == \
        [(APPEND,), (ATTN_READ,)]
    assert [ph.label for t in sched.traversals for ph in t.phases] == \
        ["decode[0]", "decode[1]"]


def test_max_ports_bounds_the_merge():
    phases = [_evict({0}), _prefill({3}), _decode({5}, {5, 6})]
    full = plan(phases, mode="ooo", max_ports=4)
    assert len(full.traversals) == 1                   # 4-port 3W+1R
    assert full.traversals[0].port_config().mix() == "3W+1R"
    two = plan(phases, mode="ooo", max_ports=2)
    assert all(len(t.ports()) <= 2 for t in two.traversals)
    # evict+prefill merge into one 2W traversal; decode keeps its own pair
    assert [t.phase_ids() for t in two.traversals] == \
        [(EVICT, PREFILL), (DECODE,)]


def test_split_roles_emits_writes_then_reads():
    sched = plan([_prefill({3}), _decode({5}, {5, 6})], mode="ooo",
                 split_roles=True)
    roles = [tuple({t.role for t in trav.txns()}) for trav in sched.traversals]
    assert roles == [(WRITE,), (READ,)]
    assert sched.traversals[0].ports() == (BULK_FILL, APPEND)
    assert sched.traversals[1].ports() == (ATTN_READ,)


def test_plan_validates_inputs():
    with pytest.raises(ValueError, match="unknown schedule mode"):
        plan([], mode="speculative")
    with pytest.raises(ValueError, match="max_ports"):
        plan([], max_ports=0)
    with pytest.raises(ValueError, match="program order"):
        plan([_decode({5}, {5}), _prefill({3})])
    # empty phases are dropped, an all-empty cycle plans to zero traversals
    assert plan([PhaseTxn(0, "idle", ())]).traversals == ()


# --------------------------------------------------------------------------
# engine integration: mixed prefill+decode workload
# --------------------------------------------------------------------------

STAGGER_LENS = (6, 14, 22, 30)


@pytest.fixture(scope="module")
def setup():
    import jax
    from repro.configs import registry
    from repro.models import init_params
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _staggered(cfg, params, max_new=4, **kw):
    """Staggered prompt lengths + a small prefill chunk keep some slots
    mid-prefill while others decode, so macro-cycles carry multiple
    phases — the workload the scheduler exists for."""
    from repro.serve.engine import MultiPortEngine
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in STAGGER_LENS]
    eng = MultiPortEngine(params, cfg, slots=4, max_len=64, chunk_tokens=8,
                          seq_tile=8, **kw)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    done = eng.run(max_cycles=500)
    assert len(done) == len(prompts)
    return eng, {r.rid: tuple(r.generated) for r in done}


def test_ooo_coschedules_and_saves_traversals(setup):
    """Acceptance: under a mixed workload ooo commits STRICTLY fewer pool
    traversals per macro-cycle than the static walk, co-schedules
    multi-phase cycles, and stays token-identical."""
    cfg, params = setup
    eo, to = _staggered(cfg, params, schedule_mode="ooo")
    es, ts = _staggered(cfg, params, schedule_mode="static")
    assert to == ts
    assert eo.multi_phase_cycles > 0 and es.multi_phase_cycles > 0
    assert eo.coscheduled_cycles > 0
    assert es.coscheduled_cycles == 0
    assert eo.coschedule_frac > 0.5
    assert (eo.pool_traversals / eo.cycles
            < es.pool_traversals / es.cycles)
    # the merges really produced >2-port mixes (per-mix tile accounting ran)
    assert any(k.startswith("3-port[") for k in eo.pool.mix_counts)
    # static only ever issues the legacy single-phase mixes
    assert all(k.startswith(("1-port[", "2-port[1W+1R"))
               for k in es.pool.mix_counts)


def test_reference_kernels_coschedule_too(setup):
    """The two-pass reference pool discipline (split_roles) still merges
    phases before the role split — fewer traversals, same tokens."""
    cfg, params = setup
    eo, to = _staggered(cfg, params, kernel_mode="reference",
                        schedule_mode="ooo")
    es, ts = _staggered(cfg, params, kernel_mode="reference",
                        schedule_mode="static")
    assert to == ts
    assert eo.coscheduled_cycles > 0
    assert eo.pool_traversals < es.pool_traversals


def test_port_budget_degradations_token_identical(setup):
    """max_ports is the paper's B1B0 knob: 2-port and 1-port budgets still
    decode the same tokens; 1-port degrades the compute to the two-pass
    oracle and never issues a multi-port traversal."""
    cfg, params = setup
    _, oracle = _staggered(cfg, params, schedule_mode="static")
    e2, t2 = _staggered(cfg, params, schedule_mode="ooo", max_ports=2)
    e1, t1 = _staggered(cfg, params, schedule_mode="ooo", max_ports=1)
    assert t2 == oracle and t1 == oracle
    assert e1.compute_port_mix == "w+r" and not e1._fused_compute
    assert all(k.startswith("1-port[") for k in e1.pool.mix_counts)
    assert all(int(k[0]) <= 2 for k in e2.pool.mix_counts)


def test_page_reuse_raw_split_regression(setup):
    """page_tokens=1 makes every decode append allocate a fresh page — the
    page evict just freed — so evict's scrub write hazards (RAW) against
    the decode READ of that page and the scheduler must keep them in
    separate traversals. Tokens must match the reference oracle."""
    from repro.serve.engine import MultiPortEngine
    cfg, params = setup
    rng = np.random.default_rng(9)
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in (4, 6, 5)]
    max_news = (2, 10, 3)          # staggered finishes: evict mid-decode

    def serve(kernel_mode, schedule_mode):
        eng = MultiPortEngine(params, cfg, slots=2, max_len=16,
                              page_tokens=1, chunk_tokens=4, seq_tile=8,
                              kernel_mode=kernel_mode,
                              schedule_mode=schedule_mode)
        for p, mn in zip(prompts, max_news):
            eng.submit(p, max_new=mn)
        done = eng.run(max_cycles=500)
        assert len(done) == len(prompts)
        return eng, {r.rid: tuple(r.generated) for r in done}

    eo, to = serve("pallas", "ooo")
    _, tr = serve("reference", "static")
    assert to == tr
    # at least one cycle carried evict AND decode yet did NOT merge them
    # (the RAW split), visible in the per-cycle schedule log
    split_cycles = [
        log for log in eo.schedule_log
        if {EVICT, DECODE} <= {ph for t in log for ph in t}
        and all(len(set(t)) == 1 for t in log)]
    assert split_cycles, "expected a RAW-split evict+decode cycle"


def test_ooo_token_identical_property(setup):
    """Property (CI installs the ``dev`` extra; skips locally): random
    staggered admissions and port budgets — ooo stays token-identical to
    the static oracle through arbitrary admission/eviction interleavings."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    from repro.serve.engine import MultiPortEngine
    cfg, params = setup

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(
        prompt_lens=st.lists(st.integers(2, 20), min_size=2, max_size=5),
        chunk_tokens=st.sampled_from([4, 8]),
        max_ports=st.integers(1, 4),
        data=st.data())
    def prop(prompt_lens, chunk_tokens, max_ports, data):
        rng = np.random.default_rng(7)
        prompts = [list(rng.integers(0, cfg.vocab, n)) for n in prompt_lens]
        gaps = data.draw(st.lists(st.integers(0, 3),
                                  min_size=len(prompts),
                                  max_size=len(prompts)), label="gaps")

        def serve(schedule_mode, mp):
            eng = MultiPortEngine(params, cfg, slots=2, max_slots=4,
                                  max_len=32, chunk_tokens=chunk_tokens,
                                  seq_tile=8, schedule_mode=schedule_mode,
                                  max_ports=mp)
            for p, gap in zip(prompts, gaps):
                eng.submit(p, max_new=3)
                for _ in range(gap):          # stagger: run between admits
                    if eng.pending_work():
                        eng.step()
            done = eng.run(max_cycles=500)
            assert len(done) == len(prompts)
            return {r.rid: tuple(r.generated) for r in done}

        assert serve("ooo", max_ports) == serve("static", 4)

    prop()


def test_sharded_ooo_matches_static():
    """Data-parallel KV + scheduler: over 4 forced host devices the ooo
    schedule still co-schedules, saves traversals, and decodes the same
    tokens as the sharded static walk and the unsharded oracle."""
    body = """
        import jax, numpy as np
        from repro.configs import registry
        from repro.models import init_params
        from repro.launch.mesh import make_kv_mesh
        from repro.serve.engine import MultiPortEngine

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        prompts = [list(rng.integers(0, cfg.vocab, n))
                   for n in (6, 14, 22, 30)]

        def serve(schedule_mode, mesh):
            eng = MultiPortEngine(params, cfg, slots=4, max_len=64,
                                  chunk_tokens=8, seq_tile=8, mesh=mesh,
                                  schedule_mode=schedule_mode)
            for p in prompts:
                eng.submit(p, max_new=4)
            done = eng.run(max_cycles=500)
            assert len(done) == len(prompts)
            return eng, {r.rid: tuple(r.generated) for r in done}

        _, oracle = serve("ooo", None)
        mesh = make_kv_mesh(4)
        eo, to = serve("ooo", mesh)
        es, ts = serve("static", mesh)
        assert to == oracle and ts == oracle
        assert eo.n_kv_shards == 4
        assert eo.coscheduled_cycles > 0 and es.coscheduled_cycles == 0
        assert eo.pool_traversals < es.pool_traversals
        print("SCHED-SHARDED-OK")
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SCHED-SHARDED-OK" in r.stdout
