"""Admission-order contracts: FIFO under slot contention, no starvation of
long-prompt requests, and the queue/engine pressure counters. The bugfix
these pin: admission used to be an implementation detail of the prefill
phase — any future 'pick the cheapest queued request' optimization would
silently starve long prompts behind a stream of short ones. AdmissionQueue
only ever surfaces its HEAD.

The overload-safety layer rides the same contracts: the bounded queue
rejects at push (never mid-queue), deadline shedding only ever drops
expired HEADS (an expired request buried behind a live head is not
reaped early — that would bypass arrival order), and the
OverloadController's degrade/restore transitions follow its hysteresis
band exactly."""
import dataclasses
from typing import Optional

import jax
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.admission import AdmissionQueue, OverloadController
from repro.serve.engine import MultiPortEngine


@dataclasses.dataclass
class FakeReq:
    arrival_tick: int
    deadline_tick: Optional[float] = None


# ---------------------------------------------------------------------------
# queue-level semantics (payload-generic: anything with arrival_tick)

def test_head_ready_and_pop_follow_arrival_time():
    q = AdmissionQueue()
    a, b = FakeReq(5), FakeReq(2)
    q.push(a)                  # submission order IS queue order,
    q.push(b)                  # even when a later push has an earlier tick
    assert not q.head_ready(4)
    assert q.pop_ready(4) is None      # b is ready at t=4, but b is not head
    assert q.ready_depth(4) == 1
    assert q.head_ready(5)
    assert q.pop_ready(5) is a
    assert q.pop_ready(5) is b
    assert q.pop_ready(5) is None


def test_queue_counters():
    q = AdmissionQueue()
    for t in (0, 0, 1):
        q.push(FakeReq(t))
    assert (q.submitted, q.peak_depth, q.admitted) == (3, 3, 0)
    assert len(q) == 3 and bool(q)
    q.pop_ready(10)
    q.push(FakeReq(2))
    assert q.peak_depth == 3           # depth never re-peaked
    assert q.admitted == 1


# ---------------------------------------------------------------------------
# overload semantics: bounded depth + deadline shedding (queue level)

def test_bounded_depth_rejects_at_push():
    q = AdmissionQueue(max_depth=2)
    assert q.push(FakeReq(0)) and q.push(FakeReq(0))
    assert not q.push(FakeReq(0))          # full: refused, not queued
    assert (len(q), q.submitted, q.rejected) == (2, 2, 1)
    q.pop_ready(0)
    assert q.push(FakeReq(1))              # slot freed -> accepted again
    assert q.rejected == 1


def test_bounded_depth_validation():
    with pytest.raises(ValueError):
        AdmissionQueue(max_depth=0)


def test_deadline_shed_is_head_only():
    """An expired request buried behind a LIVE head stays queued — reaping
    it early would bypass arrival order. It is shed when it surfaces."""
    q = AdmissionQueue()
    live = FakeReq(arrival_tick=5)                       # not ready at t=3
    expired = FakeReq(arrival_tick=0, deadline_tick=2)
    q.push(live)
    q.push(expired)
    assert q.shed_expired_heads(3) == []                 # head is live
    assert len(q) == 2 and q.shed_expired == 0
    assert q.pop_ready(5) is live                        # FIFO intact
    assert q.shed_expired_heads(5) == [expired]
    assert q.shed_expired == 1 and len(q) == 0


def test_pop_ready_sheds_expired_heads_first():
    q = AdmissionQueue()
    a = FakeReq(arrival_tick=0, deadline_tick=1)
    b = FakeReq(arrival_tick=0, deadline_tick=1)
    c = FakeReq(arrival_tick=0)                          # no deadline
    for r in (a, b, c):
        q.push(r)
    assert q.pop_ready(4) is c                           # a, b shed en route
    assert q.shed_expired == 2
    assert q.admitted == 1                               # sheds not admitted


def test_deadline_boundary_is_inclusive():
    """now == deadline_tick is still servable; only now > deadline sheds."""
    q = AdmissionQueue()
    r = FakeReq(arrival_tick=0, deadline_tick=3)
    q.push(r)
    assert q.shed_expired_heads(3) == []
    assert q.pop_ready(3) is r


# ---------------------------------------------------------------------------
# OverloadController: hysteresis band, degrade/restore transitions

def test_overload_controller_validation():
    with pytest.raises(ValueError):
        OverloadController(depth_high=2, depth_low=2)    # band collapsed
    with pytest.raises(ValueError):
        OverloadController(sustain=0)
    with pytest.raises(ValueError):
        OverloadController(chunk_shrink=0)
    with pytest.raises(ValueError):
        OverloadController(admission_cap=0)


def test_overload_controller_hysteresis_and_transitions():
    c = OverloadController(depth_high=4, depth_low=1, sustain=3)
    # pressure must SUSTAIN: 2 hot cycles + a cool one resets the count
    for depth in (5, 6, 0, 5, 5):
        c.observe(depth, cycle=0, tick=0)
    assert not c.degraded
    c.observe(4, cycle=7, tick=9)                        # 3rd consecutive
    assert c.degraded
    assert c.transitions == [
        {"cycle": 7, "tick": 9, "to": "degraded", "ready_depth": 4}]
    # degraded policy: smaller chunk, capped admissions
    assert c.chunk_tokens(8) == 4
    assert c.cap() == c.admission_cap == 1
    # recovery needs sustained calm at/below depth_low
    for depth in (1, 0, 2, 1, 1):                        # the 2 resets
        c.observe(depth, cycle=10, tick=20)
    assert c.degraded
    c.observe(0, cycle=13, tick=26)
    assert not c.degraded
    assert c.transitions[-1]["to"] == "normal"
    assert c.degraded_cycles == 6                        # every degraded obs
    # restored: full chunk, uncapped
    assert c.chunk_tokens(8) == 8 and c.cap() is None


def test_overload_controller_chunk_floor():
    c = OverloadController(chunk_shrink=16)
    c.state = "degraded"
    assert c.chunk_tokens(8) == 1                        # never 0


# ---------------------------------------------------------------------------
# engine-level regression: FIFO admission under slot contention

@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_fifo_admission_no_long_prompt_starvation(served):
    """One slot, a long-prompt request queued behind the occupant, then a
    stream of short cheap requests behind it: the long prompt MUST win the
    freed slot (arrival order), not be bypassed by younger short ones."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    occupant = eng.submit([1, 2, 3], max_new=3)
    long_req = eng.submit(list(range(1, 21)), max_new=2)     # 20-token prompt
    shorts = [eng.submit([5, 6], max_new=1) for _ in range(3)]
    done = eng.run()
    assert len(done) == 5                                    # no starvation
    order = [eng.finished[i].rid for i in range(5)]
    assert order == [occupant.rid, long_req.rid] + [s.rid for s in shorts]
    admits = [r.admit_cycle for r in
              (occupant, long_req, *shorts)]
    assert all(a is not None for a in admits)
    assert admits == sorted(admits)                          # arrival order
    assert long_req.admit_cycle < shorts[0].admit_cycle
    assert eng.slot_contention_cycles > 0                    # queue really hit
    assert eng.admission.peak_depth == 5                     # all 5 queued
    assert eng.admission.admitted == 5


def test_contended_slot_goes_to_oldest_ready(served):
    """Open-loop flavor: the short request ARRIVES later than the long one;
    when the single slot frees, the long request (older arrival) gets it
    even though the short one would finish faster."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    eng.submit([1, 2, 3, 4], max_new=2, arrival_tick=0)
    long_req = eng.submit(list(range(1, 17)), max_new=1, arrival_tick=1)
    short = eng.submit([7], max_new=1, arrival_tick=2)
    eng.run()
    assert long_req.admit_cycle < short.admit_cycle
    assert long_req.admit_tick <= short.admit_tick


def test_eviction_pressure_counter_under_churn(served):
    """An admission that rides a slot freed by an eviction in the SAME
    macro-cycle bumps the evict-pressure counter the serve bench reports.
    Geometry: the admit port only enables when a slot is free at plan
    time, so keep one spare slot free while a quick request finishes —
    the late arrival is then admitted in the eviction's own cycle, and
    lowest-free-slot placement puts it in the just-freed slot."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=3, max_slots=3, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    eng.submit(list(range(1, 9)), max_new=8, arrival_tick=0)   # long occupant
    quick = eng.submit([3, 1], max_new=1, arrival_tick=0)      # frees slot 1
    # ready exactly when the quick request's eviction cycle plans
    late = eng.submit([5, 6, 7], max_new=1, arrival_tick=1)
    done = eng.run()
    assert len(done) == 3
    assert eng.evictions == 3
    assert quick.finish_cycle < late.admit_cycle
    assert eng.evict_pressure_admissions >= 1


# ---------------------------------------------------------------------------
# engine-level load shedding: bounded queue + deadline TTL

def test_engine_bounded_queue_sheds_at_submit(served):
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8, max_queue_depth=1)
    kept = eng.submit([1, 2, 3], max_new=1)
    over = [eng.submit([4, 5], max_new=1) for _ in range(2)]
    assert [r.shed_reason for r in over] == ["queue_full"] * 2
    assert eng.shed == over and eng.shed_queue_full == 2
    assert eng.admission.rejected == 2
    done = eng.run()
    assert [r.rid for r in done] == [kept.rid]           # sheds never served
    assert all(r.admit_tick is None and not r.generated for r in over)


def test_engine_deadline_ttl_sheds_queued_request(served):
    """A request whose TTL expires while it waits behind the slot occupant
    is shed with reason/tick stamped — it never gets a slot or a token."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    occupant = eng.submit(list(range(1, 9)), max_new=8)  # holds the slot
    doomed = eng.submit([2, 3], max_new=1, ttl_ticks=2)
    assert doomed.deadline_tick == doomed.arrival_tick + 2
    done = eng.run()
    assert [r.rid for r in done] == [occupant.rid]
    assert doomed.shed_reason == "deadline"
    assert doomed.shed_tick is not None
    assert doomed.shed_tick > doomed.deadline_tick
    assert eng.shed_deadline == 1 and eng.shed == [doomed]
    assert doomed.admit_tick is None and not doomed.generated


def test_engine_default_ttl_applies_to_every_submit(served):
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8, default_ttl_ticks=5.0)
    a = eng.submit([1, 2], max_new=1)
    b = eng.submit([3, 4], max_new=1, ttl_ticks=99)      # per-request wins
    assert a.deadline_tick == a.arrival_tick + 5.0
    assert b.deadline_tick == b.arrival_tick + 99
    with pytest.raises(ValueError):
        eng.submit([5], max_new=1, ttl_ticks=0)
