"""Admission-order contracts: FIFO under slot contention, no starvation of
long-prompt requests, and the queue/engine pressure counters. The bugfix
these pin: admission used to be an implementation detail of the prefill
phase — any future 'pick the cheapest queued request' optimization would
silently starve long prompts behind a stream of short ones. AdmissionQueue
only ever surfaces its HEAD."""
import dataclasses

import jax
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.admission import AdmissionQueue
from repro.serve.engine import MultiPortEngine


@dataclasses.dataclass
class FakeReq:
    arrival_tick: int


# ---------------------------------------------------------------------------
# queue-level semantics (payload-generic: anything with arrival_tick)

def test_head_ready_and_pop_follow_arrival_time():
    q = AdmissionQueue()
    a, b = FakeReq(5), FakeReq(2)
    q.push(a)                  # submission order IS queue order,
    q.push(b)                  # even when a later push has an earlier tick
    assert not q.head_ready(4)
    assert q.pop_ready(4) is None      # b is ready at t=4, but b is not head
    assert q.ready_depth(4) == 1
    assert q.head_ready(5)
    assert q.pop_ready(5) is a
    assert q.pop_ready(5) is b
    assert q.pop_ready(5) is None


def test_queue_counters():
    q = AdmissionQueue()
    for t in (0, 0, 1):
        q.push(FakeReq(t))
    assert (q.submitted, q.peak_depth, q.admitted) == (3, 3, 0)
    assert len(q) == 3 and bool(q)
    q.pop_ready(10)
    q.push(FakeReq(2))
    assert q.peak_depth == 3           # depth never re-peaked
    assert q.admitted == 1


# ---------------------------------------------------------------------------
# engine-level regression: FIFO admission under slot contention

@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_fifo_admission_no_long_prompt_starvation(served):
    """One slot, a long-prompt request queued behind the occupant, then a
    stream of short cheap requests behind it: the long prompt MUST win the
    freed slot (arrival order), not be bypassed by younger short ones."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    occupant = eng.submit([1, 2, 3], max_new=3)
    long_req = eng.submit(list(range(1, 21)), max_new=2)     # 20-token prompt
    shorts = [eng.submit([5, 6], max_new=1) for _ in range(3)]
    done = eng.run()
    assert len(done) == 5                                    # no starvation
    order = [eng.finished[i].rid for i in range(5)]
    assert order == [occupant.rid, long_req.rid] + [s.rid for s in shorts]
    admits = [r.admit_cycle for r in
              (occupant, long_req, *shorts)]
    assert all(a is not None for a in admits)
    assert admits == sorted(admits)                          # arrival order
    assert long_req.admit_cycle < shorts[0].admit_cycle
    assert eng.slot_contention_cycles > 0                    # queue really hit
    assert eng.admission.peak_depth == 5                     # all 5 queued
    assert eng.admission.admitted == 5


def test_contended_slot_goes_to_oldest_ready(served):
    """Open-loop flavor: the short request ARRIVES later than the long one;
    when the single slot frees, the long request (older arrival) gets it
    even though the short one would finish faster."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=1, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    eng.submit([1, 2, 3, 4], max_new=2, arrival_tick=0)
    long_req = eng.submit(list(range(1, 17)), max_new=1, arrival_tick=1)
    short = eng.submit([7], max_new=1, arrival_tick=2)
    eng.run()
    assert long_req.admit_cycle < short.admit_cycle
    assert long_req.admit_tick <= short.admit_tick


def test_eviction_pressure_counter_under_churn(served):
    """An admission that rides a slot freed by an eviction in the SAME
    macro-cycle bumps the evict-pressure counter the serve bench reports.
    Geometry: the admit port only enables when a slot is free at plan
    time, so keep one spare slot free while a quick request finishes —
    the late arrival is then admitted in the eviction's own cycle, and
    lowest-free-slot placement puts it in the just-freed slot."""
    cfg, params = served
    eng = MultiPortEngine(params, cfg, slots=3, max_slots=3, max_len=32,
                          seq_tile=8, chunk_tokens=8)
    eng.submit(list(range(1, 9)), max_new=8, arrival_tick=0)   # long occupant
    quick = eng.submit([3, 1], max_new=1, arrival_tick=0)      # frees slot 1
    # ready exactly when the quick request's eviction cycle plans
    late = eng.submit([5, 6, 7], max_new=1, arrival_tick=1)
    done = eng.run()
    assert len(done) == 3
    assert eng.evictions == 3
    assert quick.finish_cycle < late.admit_cycle
    assert eng.evict_pressure_admissions >= 1
