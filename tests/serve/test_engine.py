"""Multi-port serving engine: correctness of scheduling + generation, and
the 4-port vs single-port cycle-count advantage (claim C1 at system level)."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, rng):
    return [list(rng.integers(0, cfg.vocab, rng.integers(3, 8)))
            for _ in range(n)]


def test_engine_completes_all_requests(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=4, max_len=64, prefill_bucket=8)
    rng = np.random.default_rng(0)
    for p in _prompts(cfg, 6, rng):
        eng.submit(p, max_new=4)
    done = eng.run(max_cycles=500)
    assert len(done) == 6
    for r in done:
        assert len(r.generated) == 4


def test_engine_matches_unbatched_decode(setup):
    """Engine output for one request == direct prefill+decode."""
    cfg, params = setup
    from repro.models import decode_step, init_decode_state, prefill
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab, 5))

    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, prefill_bucket=8)
    eng.submit(prompt, max_new=5)
    done = eng.run(max_cycles=100)
    got = done[0].generated

    state = init_decode_state(cfg, 1, 64)
    toks = np.zeros((1, 8), np.int32)
    toks[0, :5] = prompt
    state, _ = jax.jit(lambda p, s, b: prefill(p, cfg, s, b))(
        params, state, {"inputs": jnp.asarray(toks)})
    state = dict(state, len=jnp.asarray([5], jnp.int32))
    cur = prompt[-1]
    want = []
    step = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b))
    for _ in range(5):
        state, lg = step(params, state, {"inputs": jnp.asarray([[cur]])})
        cur = int(jnp.argmax(lg[0]))
        want.append(cur)
    assert got == want, (got, want)


def test_multiport_uses_fewer_cycles_than_single_port(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = _prompts(cfg, 6, rng)

    multi = MultiPortEngine(params, cfg, slots=4, max_len=64, prefill_bucket=8)
    single = MultiPortEngine(params, cfg, slots=4, max_len=64,
                             prefill_bucket=8, single_port=True)
    for p in prompts:
        multi.submit(p, max_new=4)
        single.submit(p, max_new=4)
    done_m = multi.run(max_cycles=1000)
    done_s = single.run(max_cycles=1000)
    assert len(done_m) == len(done_s) == 6
    # same outputs regardless of scheduling
    for a, b in zip(sorted(done_m, key=lambda r: r.rid),
                    sorted(done_s, key=lambda r: r.rid)):
        assert a.generated == b.generated
    assert multi.cycles < single.cycles, (multi.cycles, single.cycles)


def test_priority_evict_before_prefill(setup):
    """With a full slot table, eviction (A) must precede admission (B) in the
    same macro-cycle — the FSM's priority order makes the freed slot usable
    one cycle earlier than single-port scheduling."""
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=1, max_len=64, prefill_bucket=8)
    eng.submit([1, 2, 3], max_new=1)
    eng.submit([4, 5, 6], max_new=1)
    eng.run(max_cycles=50)
    assert len(eng.finished) == 2
