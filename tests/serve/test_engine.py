"""Multi-port serving engine: correctness of scheduling + generation, and
the 4-port vs single-port cycle-count advantage (claim C1 at system level)."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompts(cfg, n, rng):
    return [list(rng.integers(0, cfg.vocab, rng.integers(3, 8)))
            for _ in range(n)]


def test_engine_completes_all_requests(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=4, max_len=64, prefill_bucket=8)
    rng = np.random.default_rng(0)
    for p in _prompts(cfg, 6, rng):
        eng.submit(p, max_new=4)
    done = eng.run(max_cycles=500)
    assert len(done) == 6
    for r in done:
        assert len(r.generated) == 4


def test_engine_matches_unbatched_decode(setup):
    """Engine output for one request == direct prefill+decode with the
    prefill-logits contract: the FIRST generated token is the argmax of the
    prefill logits (the prompt's last position), and decode then feeds each
    generated token exactly once — no re-feed of prompt[-1], no KV word
    landing twice at positions plen-1 and plen."""
    cfg, params = setup
    from repro.models import decode_step, init_decode_state, prefill
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    prompt = list(rng.integers(0, cfg.vocab, 5))

    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, prefill_bucket=8)
    eng.submit(prompt, max_new=5)
    done = eng.run(max_cycles=100)
    got = done[0].generated

    state = init_decode_state(cfg, 1, 64)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])   # exact length
    state, lg = jax.jit(lambda p, s, b: prefill(p, cfg, s, b))(
        params, state, {"inputs": toks})
    cur = int(jnp.argmax(lg[0]))
    want = [cur]
    step = jax.jit(lambda p, s, b: decode_step(p, cfg, s, b))
    for _ in range(4):
        state, lg = step(params, state, {"inputs": jnp.asarray([[cur]])})
        cur = int(jnp.argmax(lg[0]))
        want.append(cur)
    assert got == want, (got, want)


def test_first_token_comes_from_prefill_logits(setup):
    """A max_new=1 request never enters decode at all: its single token is
    the prefill argmax, and the engine carries no decode traffic for it."""
    cfg, params = setup
    from repro.models import init_decode_state, prefill
    import jax.numpy as jnp
    rng = np.random.default_rng(7)
    prompt = list(rng.integers(0, cfg.vocab, 6))

    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, prefill_bucket=8)
    eng.submit(prompt, max_new=1)
    done = eng.run(max_cycles=50)
    assert eng.decode_steps == 0

    state = init_decode_state(cfg, 1, 64)
    _, lg = jax.jit(lambda p, s, b: prefill(p, cfg, s, b))(
        params, state, {"inputs": jnp.asarray(np.asarray(prompt)[None],
                                              dtype=jnp.int32)})
    assert done[0].generated == [int(jnp.argmax(lg[0]))]


def test_slot_pool_grows_on_demand(setup):
    """The slot table starts at ``slots`` and grows (bounded by
    ``max_slots``) when admissions outnumber free slots — continuous
    batching past the seed's fixed 4, token-identical to a small pool."""
    cfg, params = setup
    rng = np.random.default_rng(8)
    prompts = _prompts(cfg, 12, rng)

    big = MultiPortEngine(params, cfg, slots=2, max_slots=12, max_len=64,
                          prefill_bucket=8)
    small = MultiPortEngine(params, cfg, slots=2, max_len=64,
                            prefill_bucket=8)
    for p in prompts:
        big.submit(p, max_new=3)
        small.submit(p, max_new=3)
    done_b = big.run(max_cycles=1000)
    done_s = small.run(max_cycles=1000)
    assert len(done_b) == len(done_s) == 12
    assert big.n_slots > 4 and big.n_slots <= 12
    assert small.n_slots == 2
    for a, b in zip(sorted(done_b, key=lambda r: r.rid),
                    sorted(done_s, key=lambda r: r.rid)):
        assert a.generated == b.generated
    # all 12 requests decode concurrently: far fewer macro-cycles
    assert big.cycles < small.cycles


def test_multiport_uses_fewer_cycles_than_single_port(setup):
    cfg, params = setup
    rng = np.random.default_rng(2)
    prompts = _prompts(cfg, 6, rng)

    multi = MultiPortEngine(params, cfg, slots=4, max_len=64, prefill_bucket=8)
    single = MultiPortEngine(params, cfg, slots=4, max_len=64,
                             prefill_bucket=8, single_port=True)
    for p in prompts:
        multi.submit(p, max_new=4)
        single.submit(p, max_new=4)
    done_m = multi.run(max_cycles=1000)
    done_s = single.run(max_cycles=1000)
    assert len(done_m) == len(done_s) == 6
    # same outputs regardless of scheduling
    for a, b in zip(sorted(done_m, key=lambda r: r.rid),
                    sorted(done_s, key=lambda r: r.rid)):
        assert a.generated == b.generated
    assert multi.cycles < single.cycles, (multi.cycles, single.cycles)


def test_priority_evict_before_prefill(setup):
    """With a full slot table, eviction (A) must precede admission (B) in the
    same macro-cycle — the FSM's priority order makes the freed slot usable
    one cycle earlier than single-port scheduling."""
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=1, max_len=64, prefill_bucket=8)
    eng.submit([1, 2, 3], max_new=1)
    eng.submit([4, 5, 6], max_new=1)
    eng.run(max_cycles=50)
    assert len(eng.finished) == 2
