"""The paged pallas data plane (tentpole of the serving engine rebuild):
kernel_mode="pallas" is the default, runs every macro-cycle as ONE physical
pool traversal, and is token-identical to the two-pass reference through a
full prefill -> decode -> evict lifecycle of concurrent requests."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, prompts, **kw):
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, prefill_bucket=8,
                          **kw)
    for p in prompts:
        eng.submit(p, max_new=5)
    done = eng.run(max_cycles=500)
    return eng, {r.rid: tuple(r.generated) for r in done}


def test_pallas_is_default_and_uses_paged_pool(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64)
    assert eng.kernel_mode == "pallas"
    assert eng.pool.use_kernel            # step_banked backs the data plane


def test_pallas_matches_reference_tokens(setup):
    """Acceptance: >=2 concurrent requests through prefill->decode->evict,
    greedy decode token-identical across kernel modes."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(4)]           # 4 requests through 2 slots
    ep, tp = _run(cfg, params, prompts, kernel_mode="pallas")
    er, tr = _run(cfg, params, prompts, kernel_mode="reference")
    assert len(tp) == len(tr) == 4
    assert tp == tr, (tp, tr)


def test_fused_path_single_traversal_per_decode(setup):
    """C1 at the system level: steady-state decode costs ONE pool traversal
    fused vs TWO for the two-pass reference."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab, 5)) for _ in range(2)]
    ep, _ = _run(cfg, params, prompts, kernel_mode="pallas")
    er, _ = _run(cfg, params, prompts, kernel_mode="reference")
    assert ep.steady_decode_steps > 0 and er.steady_decode_steps > 0
    assert ep.steady_decode_traversals == ep.steady_decode_steps      # ~1
    assert er.steady_decode_traversals == 2 * er.steady_decode_steps  # >=2


def test_evict_releases_and_scrubs_pool(setup):
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab, 6)) for _ in range(3)]
    eng, toks = _run(cfg, params, prompts, kernel_mode="pallas")
    assert len(toks) == 3
    # all pages returned to the free list after the last eviction
    assert eng.pool.utilization == 0.0
    assert not eng.pool.tables and not eng.pool.lengths
    # scrubbed: the pool storage is all zeros again
    assert float(np.abs(np.asarray(eng.pool.storage)).max()) == 0.0


def test_interpret_flag_threads_to_pool(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, interpret=True)
    assert eng.pool.interpret


def test_tokens_identical_across_seq_tiles_and_bounding(setup):
    """Acceptance: greedy decode is token-identical across seq_tile settings
    and with length bounding on/off — the bounded traversal is numerically
    transparent end-to-end."""
    cfg, params = setup
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 9))))
               for _ in range(3)]
    runs = [_run(cfg, params, prompts, kernel_mode="pallas", seq_tile=8),
            _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=16),
            _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=64),
            _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=8,
                 length_bound=False),
            _run(cfg, params, prompts, kernel_mode="reference", seq_tile=8)]
    toks = [t for _, t in runs]
    assert all(t == toks[0] for t in toks[1:]), toks


def test_decode_tile_reads_track_cache_len(setup):
    """Length-bounded decode touches only live tiles: steady-decode tile
    reads stay within ceil((cache_len+1)/seq_tile) per slot per step, and
    the unbounded traversal pays the full allocated grid."""
    cfg, params = setup
    rng = np.random.default_rng(12)
    prompts = [list(rng.integers(0, cfg.vocab, 6)) for _ in range(2)]
    eb, _ = _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=8)
    eu, _ = _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=8,
                 length_bound=False)
    assert eb.steady_decode_steps > 0
    assert eb.steady_decode_tile_reads <= eb.steady_decode_tile_bound
    # live lengths here are ~7-10 tokens vs a 64-token capacity (8 tiles)
    assert eu.steady_decode_tile_reads > eb.steady_decode_tile_reads * 2
    # the pool's own traversal accounting is tile-bounded too
    assert eb.pool.tile_reads > 0 and eb.pool.tile_writes > 0
    assert eb.pool.seq_tile == 8


def test_prefill_chunk_tile_reads_bounded(setup):
    """The fused chunk kernel reads only live tiles per chunk; the jnp
    reference pays the dense O(S_max) read."""
    cfg, params = setup
    rng = np.random.default_rng(13)
    prompts = [list(rng.integers(0, cfg.vocab, 12)) for _ in range(2)]
    ep, _ = _run(cfg, params, prompts, kernel_mode="pallas", seq_tile=8)
    er, _ = _run(cfg, params, prompts, kernel_mode="reference", seq_tile=8)
    assert ep.prefill_chunks == er.prefill_chunks > 0
    dense = (64 // 8) * er.prefill_chunks          # max_len=64 staged densely
    assert er.prefill_tile_reads == dense
    assert ep.prefill_tile_reads < dense / 2
