"""The paged pallas data plane (tentpole of the serving engine rebuild):
kernel_mode="pallas" is the default, runs every macro-cycle as ONE physical
pool traversal, and is token-identical to the two-pass reference through a
full prefill -> decode -> evict lifecycle of concurrent requests."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, prompts, **kw):
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, prefill_bucket=8,
                          **kw)
    for p in prompts:
        eng.submit(p, max_new=5)
    done = eng.run(max_cycles=500)
    return eng, {r.rid: tuple(r.generated) for r in done}


def test_pallas_is_default_and_uses_paged_pool(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64)
    assert eng.kernel_mode == "pallas"
    assert eng.pool.use_kernel            # step_banked backs the data plane


def test_pallas_matches_reference_tokens(setup):
    """Acceptance: >=2 concurrent requests through prefill->decode->evict,
    greedy decode token-identical across kernel modes."""
    cfg, params = setup
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(4)]           # 4 requests through 2 slots
    ep, tp = _run(cfg, params, prompts, kernel_mode="pallas")
    er, tr = _run(cfg, params, prompts, kernel_mode="reference")
    assert len(tp) == len(tr) == 4
    assert tp == tr, (tp, tr)


def test_fused_path_single_traversal_per_decode(setup):
    """C1 at the system level: steady-state decode costs ONE pool traversal
    fused vs TWO for the two-pass reference."""
    cfg, params = setup
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab, 5)) for _ in range(2)]
    ep, _ = _run(cfg, params, prompts, kernel_mode="pallas")
    er, _ = _run(cfg, params, prompts, kernel_mode="reference")
    assert ep.steady_decode_steps > 0 and er.steady_decode_steps > 0
    assert ep.steady_decode_traversals == ep.steady_decode_steps      # ~1
    assert er.steady_decode_traversals == 2 * er.steady_decode_steps  # >=2


def test_evict_releases_and_scrubs_pool(setup):
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab, 6)) for _ in range(3)]
    eng, toks = _run(cfg, params, prompts, kernel_mode="pallas")
    assert len(toks) == 3
    # all pages returned to the free list after the last eviction
    assert eng.pool.utilization == 0.0
    assert not eng.pool.tables and not eng.pool.lengths
    # scrubbed: the pool storage is all zeros again
    assert float(np.abs(np.asarray(eng.pool.storage)).max()) == 0.0


def test_interpret_flag_threads_to_pool(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, interpret=True)
    assert eng.pool.interpret
