"""Property suite: refcount invariants under arbitrary share/CoW traffic.

Hypothesis drives random interleavings of the pool's five ownership-
changing operations — prefill a fresh sequence, register its prefix,
match+attach a sharer, append (which may copy-on-write a shared tail),
and free (detach or die) — and after EVERY operation checks the books:

* refcounts equal table multiplicity exactly, for every mapped page;
* free ∪ quarantined ∪ mapped-with-multiplicity partitions capacity
  (no page both free and mapped, none lost, none double-freed);
* the prefix index only registers live pages;
* every sequence's committed words read back as the token content that
  produced them — CoW never corrupts either side of a split.

Follows the repo's ``importorskip`` pattern: tier-1 skips cleanly when
the hypothesis dev extra is absent.
"""
import numpy as np
import pytest

from repro.memory.paged_kv import PagedPool, PoolCapacityError

hyp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")


def _vecs(tokens):
    toks = np.asarray(tokens, np.float32)
    return toks[:, None] + np.arange(8, dtype=np.float32) / 8.0


def _audit(pool, toks_by_seq):
    mult = {}
    for t in pool.tables.values():
        for p in t:
            mult[p] = mult.get(p, 0) + 1
    assert pool.refcounts == mult, "refcounts != table multiplicity"
    free = pool.free_pages
    quar = list(pool.quarantined_pages)
    assert len(set(free + quar)) == len(free) + len(quar)
    assert not (set(free) | set(quar)) & set(mult)
    assert set(free) | set(quar) | set(mult) == set(range(pool.plan.n_pages))
    assert set(pool.page_reg) <= set(mult)
    for seq, toks in toks_by_seq.items():
        got = pool.gather_words(seq, np.arange(pool.lengths[seq]))
        np.testing.assert_allclose(got, _vecs(toks), atol=1e-6)


OPS = st.lists(
    st.tuples(st.integers(0, 4), st.integers(0, 7), st.integers(1, 9)),
    min_size=1, max_size=25)


@hyp.settings(max_examples=20, deadline=None,
              suppress_health_check=[hyp.HealthCheck.too_slow])
@hyp.given(ops=OPS, seed=st.integers(0, 2**16))
def test_refcount_books_balance_under_any_interleaving(ops, seed):
    pool = PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4)
    rng = np.random.default_rng(seed)
    toks_by_seq: dict = {}
    next_seq = 0
    for kind, pick, count in ops:
        live = sorted(toks_by_seq)
        if kind == 0:                                    # fresh prefill
            toks = [int(t) for t in rng.integers(0, 50, count)]
            try:
                pool.cycle(prefill={"seq": next_seq, "vectors": _vecs(toks)})
            except PoolCapacityError:
                continue
            toks_by_seq[next_seq] = toks
            next_seq += 1
        elif kind == 1 and live:                         # register prefix
            seq = live[pick % len(live)]
            pool.register_prefix(seq, toks_by_seq[seq])
        elif kind == 2 and live:                         # match + attach
            donor = live[pick % len(live)]
            toks = toks_by_seq[donor] + [int(t) for t in
                                         rng.integers(0, 50, 2)]
            m = pool.match_prefix(toks)
            if m is None:
                continue
            pool.attach_prefix(next_seq, m)
            toks_by_seq[next_seq] = toks[:m.tokens]
            next_seq += 1
        elif kind == 3 and live:                         # append (maybe CoW)
            seq = live[pick % len(live)]
            new = [int(t) for t in rng.integers(0, 50, 1 + count % 3)]
            try:
                pool.cycle(append={"seq": seq, "vectors": _vecs(new)})
            except PoolCapacityError:
                continue
            toks_by_seq[seq] = toks_by_seq[seq] + new
        elif kind == 4 and live:                         # free (detach/die)
            seq = live[pick % len(live)]
            dead = pool.free(seq)
            assert len(set(dead)) == len(dead), "page double-freed"
            del toks_by_seq[seq]
        _audit(pool, toks_by_seq)
    for seq in sorted(toks_by_seq):                      # full drain
        pool.free(seq)
    assert pool.free_page_count == 8
    assert not pool.refcounts and not pool.page_reg and not pool.prefix_index
