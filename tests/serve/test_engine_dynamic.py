"""Dynamic-grid serving: one decode trace for every cache length, token-
identical to the bucketed ladder fallback, and --seq-tile validation against
the FINAL (post-growth) stage ladder."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


@pytest.fixture(scope="module")
def setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run(cfg, params, prompts, max_new=4, **kw):
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, **kw)
    for p in prompts:
        eng.submit(p, max_new=max_new)
    done = eng.run(max_cycles=500)
    return eng, {r.rid: tuple(r.generated) for r in done}


def test_dynamic_grid_single_trace_token_identical(setup):
    """Acceptance: across prompt lengths spanning several tile buckets the
    dynamic-grid engine (the pallas default) keeps ONE decode trace and ONE
    chunk trace, while staying token-identical to the bucketed fallback and
    the jnp reference."""
    cfg, params = setup
    rng = np.random.default_rng(21)
    # live lengths cross the 8/16/32-token buckets of the seq_tile=8 ladder
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in (3, 9, 18, 30)]
    ed, td = _run(cfg, params, prompts, seq_tile=8)
    eb, tb = _run(cfg, params, prompts, seq_tile=8, dynamic_grid=False)
    er, tr = _run(cfg, params, prompts, seq_tile=8,
                  kernel_mode="reference")
    assert td == tb == tr
    assert ed.dynamic_grid and not eb.dynamic_grid
    assert ed.decode_traces == 1
    assert ed.prefill_traces == 1
    # the bucketed fallback really does retrace per stage-length bucket
    assert eb.decode_traces > 1
    assert len(eb.stage_lens_seen) == eb.decode_traces
    # dynamic grid stages ONE shape: the padded full capacity
    assert ed.stage_lens_seen == {ed._stage_buckets[-1]}
    # and stays inside the tile budget while doing so
    assert ed.steady_decode_tile_reads <= ed.steady_decode_tile_bound
    assert ed.steady_decode_tile_reads == eb.steady_decode_tile_reads


def test_split_kv_serving_token_identical(setup):
    """Split-KV decode end-to-end: greedy tokens are identical across
    num_kv_splits, schedule modes and kernel modes; the same tiles are
    serviced (splits parallelize chains, they never add work) while the
    critical-path latency proxy strictly shrinks."""
    cfg, params = setup
    rng = np.random.default_rng(23)
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in (3, 9, 18, 30)]
    e1, t1 = _run(cfg, params, prompts, seq_tile=8)
    e4, t4 = _run(cfg, params, prompts, seq_tile=8, num_kv_splits=4)
    _, ts = _run(cfg, params, prompts, seq_tile=8, num_kv_splits=2,
                 schedule_mode="static")
    er, tr = _run(cfg, params, prompts, seq_tile=8, num_kv_splits=4,
                  kernel_mode="reference")
    assert t1 == t4 == ts == tr
    # the reference (two-pass jnp) path has no split stage: forced off
    assert er.num_kv_splits == 1 and e4.num_kv_splits == 4
    # identical tile accounting — the bound gate needs no split-awareness
    assert e4.steady_decode_tile_reads == e1.steady_decode_tile_reads
    assert e4.steady_decode_tile_bound == e1.steady_decode_tile_bound
    # but the longest per-row chain (the latency proxy) got shorter
    assert (e4.steady_decode_critical_tiles
            < e1.steady_decode_critical_tiles)


def test_split_kv_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError):
        MultiPortEngine(params, cfg, slots=2, max_len=64, num_kv_splits=0)


def test_dynamic_grid_off_for_reference_mode(setup):
    cfg, params = setup
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64,
                          kernel_mode="reference")
    assert not eng.dynamic_grid
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64,
                          length_bound=False)
    assert not eng.dynamic_grid


def test_growth_past_bucket_edge_keeps_final_ladder(setup):
    """Regression (--seq-tile validation): launchers must validate against
    ``final_stage_ladder`` — the ladder the engine keeps through max_slots
    growth — not a hand-rolled startup snapshot. Growing the slot table
    past a batch-bucket edge must leave the engine's live ladder equal to
    the validated final one, and every stage length it ever staged inside
    it (if ladder construction ever becomes growth-dependent, this is the
    test that forces the validation surface to follow)."""
    cfg, params = setup
    rng = np.random.default_rng(22)
    eng = MultiPortEngine(params, cfg, slots=1, max_slots=8, max_len=100,
                          seq_tile=16, chunk_tokens=8, dynamic_grid=False)
    final = MultiPortEngine.final_stage_ladder(100, 16)
    assert eng._stage_buckets == final == (16, 32, 64, 112)
    for n in (3, 10, 20, 40, 3, 9):
        eng.submit(list(rng.integers(0, cfg.vocab, n)), max_new=3)
    done = eng.run(max_cycles=500)
    assert len(done) == 6
    assert eng.n_slots > 1                     # grew past the 1-slot start
    assert eng._stage_buckets == final         # regeneration is ladder-stable
    assert eng.stage_lens_seen <= set(final)   # staged only validated lengths


def test_final_stage_ladder_mirrors_engine_clamp(setup):
    """The validation surface applies the engine's own seq_tile clamp: a
    --seq-tile larger than max_len validates (and runs) clamped instead of
    diverging from what the engine actually does."""
    cfg, params = setup
    assert MultiPortEngine.final_stage_ladder(64, 128) == (64,)
    eng = MultiPortEngine(params, cfg, slots=2, max_len=64, seq_tile=128)
    assert eng._stage_buckets == (64,)
    with pytest.raises(ValueError):
        MultiPortEngine.final_stage_ladder(64, 0)
