"""Traffic-generator contracts: seeded determinism, heavy-tail sanity,
trace round-trips, and the open-loop == closed-loop identity property
(hypothesis, importorskip per ROADMAP)."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine
from repro.serve.traffic import (Arrival, _bounded_pareto, drive,
                                 poisson_arrivals, scenario_spread,
                                 trace_arrivals, write_trace)

VOCAB = registry.get("tinyllama-1.1b", reduced=True).vocab


def _gen(seed, n=24, rate=0.4):
    return poisson_arrivals(n, rate, seed=seed, vocab=VOCAB,
                            max_prompt=40, max_output=10)


def test_same_seed_identical_schedule():
    a, b = _gen(7), _gen(7)
    assert a == b          # Arrival is frozen: full bit-for-bit equality


def test_different_seeds_differ():
    assert _gen(1) != _gen(2)


def test_arrivals_sorted_and_bounded():
    arr = _gen(3, n=64)
    ticks = [a.arrival_tick for a in arr]
    assert ticks == sorted(ticks)
    assert all(t >= 0 for t in ticks)
    for a in arr:
        assert 2 <= a.prompt_len <= 40
        assert 1 <= a.max_new <= 10
        assert all(0 <= t < VOCAB for t in a.prompt)
        assert a.scenario in registry.ARCH_IDS


def test_bounded_pareto_heavy_tail():
    # the length distribution must be genuinely heavy-tailed: hard-bounded,
    # mass concentrated near the lower bound (median well below the
    # midpoint), yet right-skewed (mean above median) with the upper half
    # of the range actually reached
    rng = np.random.default_rng(0)
    lo, hi = 2.0, 40.0
    x = _bounded_pareto(rng, 1.2, lo, hi, 4000)
    assert float(x.min()) >= lo and float(x.max()) <= hi
    med, mean = float(np.median(x)), float(x.mean())
    assert med < lo + 0.25 * (hi - lo)
    assert mean > med
    assert float(x.max()) > lo + 0.5 * (hi - lo)


def test_scenario_spread_deterministic_and_spread():
    s1, s2 = scenario_spread(), scenario_spread()
    assert s1 == s2
    assert len(s1) == len(registry.ARCH_IDS)
    scales = sorted(s.prompt_scale for s in s1)
    assert scales[0] == pytest.approx(0.5)
    assert scales[-1] == pytest.approx(2.0)
    assert len(set(scales)) >= 2


def test_trace_round_trip(tmp_path):
    arr = _gen(11, n=8)
    p = tmp_path / "trace.jsonl"
    write_trace(str(p), arr)
    assert trace_arrivals(str(p), vocab=VOCAB) == arr


def test_trace_prompt_len_deterministic(tmp_path):
    p = tmp_path / "trace.jsonl"
    p.write_text('{"arrival": 0, "prompt_len": 5, "max_new": 2}\n'
                 '{"arrival": 3, "prompt_len": 3, "max_new": 1}\n')
    a1 = trace_arrivals(str(p), vocab=VOCAB, seed=4)
    a2 = trace_arrivals(str(p), vocab=VOCAB, seed=4)
    assert a1 == a2
    assert [x.prompt_len for x in a1] == [5, 3]


def test_trace_errors_carry_line_numbers(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"arrival": 5, "prompt_len": 2, "max_new": 1}\n'
                 '{"arrival": 3, "prompt_len": 2, "max_new": 1}\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:2.*sorted"):
        trace_arrivals(str(p), vocab=VOCAB)
    p.write_text('{"arrival": 0, "max_new": 1}\n')
    with pytest.raises(ValueError, match=r"bad\.jsonl:1"):
        trace_arrivals(str(p), vocab=VOCAB)


# ---------------------------------------------------------------------------
# open-loop == closed-loop identity (the bench gate's property, in-tree)

@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _tokens(eng):
    return {r.rid: tuple(r.generated) for r in eng.finished}


def _run_identity(served, arrivals):
    cfg, params = served
    n = len(arrivals)
    kw = dict(slots=n, max_slots=n, max_len=32, seq_tile=8, chunk_tokens=8)
    open_eng = MultiPortEngine(params, cfg, **kw)
    drive(open_eng, arrivals)
    closed = MultiPortEngine(params, cfg, **kw)
    for a in arrivals:
        closed.submit(list(a.prompt), a.max_new, arrival_tick=0)
    closed.run()
    assert len(open_eng.finished) == n
    assert _tokens(open_eng) == _tokens(closed)


def test_open_loop_matches_closed_loop_smoke(served):
    arr = poisson_arrivals(4, 0.3, seed=5, vocab=served[0].vocab,
                           max_prompt=12, max_output=4)
    _run_identity(served, arr)


def test_open_loop_admission_reproduces_closed_loop(served):
    """Property (CI installs the ``dev`` extra; skips locally): arrival
    timing decides WHEN work happens, never WHAT is generated — with one
    slot per request, open-loop admission of ANY schedule yields exactly
    the closed-loop token output."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=[hyp.HealthCheck.too_slow])
    @hyp.given(st.lists(
        st.tuples(st.integers(0, 9),       # arrival gap (ticks)
                  st.integers(1, 10),      # prompt length
                  st.integers(1, 4)),      # max_new
        min_size=1, max_size=4))
    def prop(spec):
        rng = np.random.default_rng(0)
        tick, arrivals = 0, []
        for gap, plen, max_new in spec:
            tick += gap
            arrivals.append(Arrival(
                arrival_tick=tick,
                prompt=tuple(int(t) for t in
                             rng.integers(0, served[0].vocab, plen)),
                max_new=max_new))
        _run_identity(served, tuple(arrivals))

    prop()
