"""Refcounted copy-on-write page sharing: the prefix cache end-to-end.

Pool layer: content-addressed registration/matching at page granularity,
attach by refcount bump, detach-not-scrub on free, CoW on append into a
shared tail, share-aware footprint projection, and quarantine refusing
referenced pages. Engine layer: prefix-aware admission shrinks both page
demand and prefill compute while greedy tokens stay BIT-IDENTICAL to the
cache-off run (sharing is a storage optimization, never a numerics
change). Plus the satellite regressions: the over-precheck (a request
shed for capacity a prefix hit would have satisfied), scheduler RAR
co-scheduling over shared pages, chaos invariants under sharing, and the
8-shard cross-shard admission arc (subprocess, forced host devices).

Pool geometry below: 8 pages x 4 tokens (direct pool tests) or
page_tokens == seq_tile == 8 with max_len=32/64 (engine tests).
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.memory.paged_kv import PagedPool, PoolCapacityError
from repro.models import init_params
from repro.serve.chaos import InvariantViolation, check_invariants
from repro.serve.engine import MultiPortEngine

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _pool(**kw):
    return PagedPool.create(n_pages=8, page_tokens=4, word_width=8,
                            num_banks=4, **kw)


def _vecs(tokens):
    """Deterministic token -> word embedding for content checks."""
    toks = np.asarray(tokens, np.float32)
    return toks[:, None] + np.arange(8, dtype=np.float32) / 8.0


def _seed_registered(pool, seq, tokens):
    pool.cycle(prefill={"seq": seq, "vectors": _vecs(tokens)})
    pool.register_prefix(seq, tokens)


# ---- pool: registration, matching, attach ---------------------------------

def test_match_full_and_partial_pages():
    pool = _pool()
    toks = list(range(10, 20))                       # 10 tokens: 2.5 pages
    _seed_registered(pool, 1, toks)
    m = pool.match_prefix(toks)
    assert m.tokens == 10 and len(m.pages) == 3      # 2 full + partial tail
    assert m.full_pages == 2
    # a prompt agreeing on 6 tokens matches 1 full page + 2-token partial
    m2 = pool.match_prefix(toks[:6] + [99, 98])
    assert m2.tokens == 6 and m2.full_pages == 1 and len(m2.pages) == 2
    # divergence inside the first page: no full page, partial head only
    m3 = pool.match_prefix(toks[:3] + [99])
    assert m3.tokens == 3 and m3.full_pages == 0
    assert pool.match_prefix([99, 98, 97]) is None
    # the limit cap (engine passes len(prompt) - 1)
    m4 = pool.match_prefix(toks, limit=8)
    assert m4.tokens == 8 and m4.full_pages == 2 and len(m4.pages) == 2


def test_attach_bumps_refcounts_and_free_detaches():
    pool = _pool()
    toks = list(range(30, 40))
    _seed_registered(pool, 1, toks)
    m = pool.match_prefix(toks)
    pool.attach_prefix(2, m)
    assert pool.lengths[2] == 10 and pool.tables[2] == list(m.pages)
    assert all(pool.page_refcount(p) == 2 for p in m.pages)
    # attached words read back identically to the registrant's
    np.testing.assert_allclose(
        pool.gather_words(2, np.arange(10)), _vecs(toks), atol=1e-6)
    # freeing the REGISTRANT detaches: no page dies, index survives via seq 2
    assert pool.free(1) == []
    assert all(pool.page_refcount(p) == 1 for p in m.pages)
    assert pool.match_prefix(toks).pages == m.pages
    # freeing the last holder kills the pages and their index entries
    assert sorted(pool.free(2)) == sorted(m.pages)
    assert pool.match_prefix(toks) is None
    assert pool.free_page_count == 8
    assert not pool.refcounts and not pool.page_reg and not pool.prefix_index


def test_attach_requires_fresh_sequence():
    pool = _pool()
    _seed_registered(pool, 1, list(range(8)))
    m = pool.match_prefix(list(range(8)))
    pool.cycle(prefill={"seq": 2, "vectors": _vecs([50, 51])})
    with pytest.raises(ValueError, match="already holds pages"):
        pool.attach_prefix(2, m)


def test_cow_on_append_into_shared_tail():
    """Appending into a refcount>1 partial page copies the live words to a
    fresh page in the same traversal and remaps ONLY the appender; the
    other holder's reads are untouched."""
    pool = _pool()
    toks = list(range(60, 66))                       # 6 tokens: 1.5 pages
    _seed_registered(pool, 1, toks)
    pool.attach_prefix(2, pool.match_prefix(toks))
    shared_tail = pool.tables[2][1]
    pool.cycle(append={"seq": 2, "vectors": _vecs([77])})
    assert pool.cow_copies == 1 and pool.cow_words == 2
    assert pool.tables[2][1] != shared_tail          # remapped
    assert pool.tables[1][1] == shared_tail          # registrant untouched
    assert pool.page_refcount(shared_tail) == 1
    np.testing.assert_allclose(
        pool.gather_words(2, np.arange(7)), _vecs(toks + [77]), atol=1e-6)
    np.testing.assert_allclose(
        pool.gather_words(1, np.arange(6)), _vecs(toks), atol=1e-6)


def test_project_write_pages_carries_the_cow_page():
    """The scheduler's write footprint must contain the PHYSICAL page the
    commit will write — the fresh CoW page, never the shared one."""
    pool = _pool()
    toks = list(range(40, 46))
    _seed_registered(pool, 1, toks)
    pool.attach_prefix(2, pool.match_prefix(toks))
    shared_tail = pool.tables[2][1]
    foot = pool.project_write_pages([(2, 1)])[0]
    assert shared_tail not in foot
    pool.cycle(append={"seq": 2, "vectors": _vecs([88])})
    assert pool.tables[2][1] in foot                 # projection == commit


def test_admission_precheck_subtracts_matched_pages():
    """Satellite 1 (pool half): worst-case demand subtracts the FULLY
    matched pages; the partial tail is offset by its CoW replacement."""
    pool = _pool()
    toks = list(range(8))                            # 2 full pages
    _seed_registered(pool, 1, toks)
    pool.cycle(prefill={"seq": 9, "vectors": _vecs(range(100, 116))})  # 4 pg
    assert pool.free_page_count == 2
    m = pool.match_prefix(toks + [50], limit=8)
    assert m.full_pages == 2
    # worst 12 words -> 3 pages; without the match this cannot fit
    with pytest.raises(PoolCapacityError):
        pool.admission_precheck(2, 12)
    pool.admission_precheck(2, 12, prefix=m)         # 3 - 2 matched: fits
    # partial-tail arithmetic: 7 matched of 8-token prompt, worst 12
    m2 = pool.match_prefix(toks[:7] + [60], limit=7)
    assert m2.tokens == 7 and m2.full_pages == 1
    pool.admission_precheck(3, 12, prefix=m2)        # 3 - 1 = 2 pages: fits


def test_quarantine_refuses_referenced_page():
    pool = _pool()
    _seed_registered(pool, 1, list(range(4)))
    page = pool.tables[1][0]
    # corrupt the books deliberately: a mapped page on the free list
    pool.free_by_shard[0].append(page)
    with pytest.raises(ValueError, match="refcount"):
        pool.quarantine(8)
    pool.free_by_shard[0].remove(page)
    pool.quarantine(8)                               # clean books: fine


def test_pending_cow_counted_in_capacity_check():
    """The transactional capacity check reserves the CoW replacement page,
    so a full pool rejects the append instead of failing mid-copy."""
    pool = _pool()
    toks = list(range(70, 76))                       # 1.5 pages
    _seed_registered(pool, 1, toks)
    pool.attach_prefix(2, pool.match_prefix(toks))
    # BOTH holders would CoW — neither owns the shared tail exclusively
    assert pool.pending_cow_pages(2) == 1 and pool.pending_cow_pages(1) == 1
    pool.cycle(prefill={"seq": 9, "vectors": _vecs(range(100, 124))})  # 6 pg
    assert pool.free_page_count == 0
    with pytest.raises(PoolCapacityError):
        pool.cycle(append={"seq": 2, "vectors": _vecs([77])})
    assert pool.tables[2][1] == pool.tables[1][1]    # nothing moved
    pool.free(9)
    pool.cycle(append={"seq": 2, "vectors": _vecs([77])})
    assert pool.cow_copies == 1


# ---- engine: identity, hit path, over-precheck regression -----------------

@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(params, cfg, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_slots", kw["slots"])
    return MultiPortEngine(params, cfg, max_len=32, seq_tile=8,
                           chunk_tokens=8, page_tokens=8,
                           kernel_mode="reference", **kw)


def _staggered(eng):
    """One registrant decoding while two sharers arrive: b repeats the
    prompt exactly, c diverges after 10 tokens (partial-page match)."""
    prompt = list(range(7, 19))                      # 12 tokens
    a = eng.submit(prompt, max_new=8)
    for _ in range(4):                               # a registers, keeps going
        eng.step()
    b = eng.submit(prompt, max_new=4)
    c = eng.submit(prompt[:10] + [99, 98], max_new=4)
    eng.run()
    return [r.generated for r in (a, b, c)], eng


def test_engine_tokens_bit_identical_and_hits(served):
    cfg, params = served
    t_off, e_off = _staggered(_engine(params, cfg, prefix_cache=False))
    t_on, e_on = _staggered(_engine(params, cfg, prefix_cache=True))
    assert t_on == t_off                             # never a numerics change
    assert e_on.prefix_stats["hits"] >= 2            # b full, c partial
    assert e_on.pool.cow_copies >= 1                 # partial tails diverge
    assert e_on.prefill_tokens < e_off.prefill_tokens
    assert e_off.prefix_stats["hits"] == 0 and e_off.pool.cow_copies == 0
    # full drain: every page home, no refcount/index residue
    for eng in (e_on, e_off):
        assert eng.pool.free_page_count == eng.pool.plan.n_pages
        assert not eng.pool.refcounts and not eng.pool.prefix_index
        check_invariants(eng)


def test_shed_for_capacity_a_prefix_hit_satisfies(served):
    """Satellite 1 (engine half): under a squeeze, the cache-off precheck
    sheds a request whose demand a prefix hit covers; cache-on admits it
    with tokens identical to an unsqueezed oracle."""
    cfg, params = served
    prompt = list(range(20, 28))                     # 8 tokens == 1 page

    def scenario(prefix_cache):
        eng = _engine(params, cfg, prefix_cache=prefix_cache,
                      capacity_retry_limit=2)
        a = eng.submit(prompt, max_new=6)            # worst 13 -> 2 pages
        while eng.pool.lengths.get(a.rid, 0) < 9:    # 2 pages held, 0 reserved
            eng.step()
        assert eng.pool.free_page_count == 6
        eng.pool.quarantine(5)                       # 1 page left
        b = eng.submit(prompt + [40, 41], max_new=2)  # worst 11 -> 2 pages
        eng.run()
        return a, b, eng

    a_off, b_off, e_off = scenario(False)
    assert b_off.shed_reason == "capacity" and not b_off.generated
    a_on, b_on, e_on = scenario(True)
    assert b_on.shed_reason is None and len(b_on.generated) == 2
    assert e_on.pool.prefix_hits >= 1
    assert a_on.generated == a_off.generated
    oracle = _engine(params, cfg)
    ob = oracle.submit(prompt + [40, 41], max_new=2)
    oracle.run()
    assert b_on.generated == ob.generated


# ---- scheduler: shared pages are RAR, CoW pages are write-private ---------

def test_shared_page_reads_co_schedule_as_rar():
    from repro.core.ports import READ, WRITE
    from repro.serve.scheduler import PhaseTxn, PortTxn, conflicts, plan

    a = PhaseTxn(1, "decode-a", (PortTxn(1, READ, frozenset({3})),))
    b = PhaseTxn(2, "decode-b", (PortTxn(2, READ, frozenset({3})),))
    assert conflicts(a, b) is None                   # shared page: RAR
    sched = plan([a, b], mode="ooo")
    assert len(sched.traversals) == 1 and sched.co_scheduled
    # a CoW write goes to the FRESH page, so a writer whose footprint held
    # the shared page would be a WAR split — the pool never produces that
    w = PhaseTxn(3, "append", (PortTxn(0, WRITE, frozenset({3})),))
    assert conflicts(a, w) == "war"
    w_cow = PhaseTxn(3, "append", (PortTxn(0, WRITE, frozenset({7})),))
    assert conflicts(a, w_cow) is None


# ---- chaos: refcount invariants under sharing -----------------------------

def test_check_invariants_catches_refcount_drift(served):
    cfg, params = served
    eng = _engine(params, cfg, prefix_cache=True)
    prompt = list(range(7, 19))
    a = eng.submit(prompt, max_new=8)
    for _ in range(4):
        eng.step()
    b = eng.submit(prompt, max_new=4)
    eng.step()
    assert any(rc > 1 for rc in eng.pool.refcounts.values())
    check_invariants(eng)                            # sharing is consistent
    shared = max(eng.pool.refcounts, key=eng.pool.refcounts.get)
    eng.pool.refcounts[shared] += 1                  # inject drift
    with pytest.raises(InvariantViolation, match="multiplicity"):
        check_invariants(eng)
    eng.pool.refcounts[shared] -= 1
    eng.pool.refcounts[999] = 1                      # rc for unmapped page
    with pytest.raises(InvariantViolation, match="retained"):
        check_invariants(eng)
    del eng.pool.refcounts[999]
    eng.run()
    check_invariants(eng)
    assert a.generated and b.generated


def test_chaos_run_with_prefix_cache(served):
    """A seeded fault plan over shared-prefix traffic: every audit passes
    with refcounted pages live, including squeezes (quarantine vs shared
    pages) and cancels (detach through the normal evict path)."""
    from repro.serve.chaos import ChaosHarness, FaultPlan
    from repro.serve.traffic import drive, poisson_arrivals, scenario_spread

    cfg, params = served
    sp = scenario_spread(shared_prefixes=2, prefix_tokens=8)
    arrivals = poisson_arrivals(
        12, 0.25, seed=11, vocab=cfg.vocab, max_prompt=20,
        max_output=4, min_prompt=10, scenarios=sp)
    eng = _engine(params, cfg, prefix_cache=True)
    harness = ChaosHarness(FaultPlan.generate(5, horizon=60, n_faults=4))
    res = drive(eng, arrivals, on_cycle=harness)
    harness.finalize(eng)
    assert harness.invariant_checks >= 5
    assert res.served + res.shed + res.cancelled == len(arrivals)
    assert eng.pool.prefix_lookups > 0


# ---- 8-shard cross-shard admission (satellite 4) --------------------------

def test_full_home_shard_admits_via_cross_shard_prefix():
    """A full home shard with a matching prefix on another shard admits by
    sharing where the cache-off engine sheds on PoolCapacityError retries —
    and the shared run's tokens match the unsharded, unsqueezed oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax
        from repro.configs import registry
        from repro.launch.mesh import make_kv_mesh
        from repro.models import init_params
        from repro.serve.engine import MultiPortEngine

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        base = list(range(2, 18))                    # 16 tokens = 2 pages
        tail = [99, 98]

        oracle = MultiPortEngine(params, cfg, slots=2, max_slots=2,
                                 max_len=64, seq_tile=8, chunk_tokens=8,
                                 kernel_mode="reference")
        ob = oracle.submit(base + tail, max_new=2)
        oracle.run()

        def sharded(prefix_cache):
            eng = MultiPortEngine(params, cfg, slots=4, max_slots=4,
                                  max_len=64, seq_tile=8, chunk_tokens=8,
                                  kernel_mode="reference",
                                  mesh=make_kv_mesh(8),
                                  prefix_cache=prefix_cache,
                                  capacity_retry_limit=2)
            assert eng.pool.plan.pages_per_shard == 4    # 32 pages
            a = eng.submit(base, max_new=6)              # worst 21 -> 3 pg
            while eng.pool.lengths.get(a.rid, 0) < 17:   # 3 pages, 0 reserved
                eng.step()
            home = eng.pool.home_of(a.rid)
            keep = [0] * 8
            keep[home] = 1
            eng.pool.quarantine(4, keep_free=keep)       # 1 free on home only
            b = eng.submit(base + tail, max_new=2)       # worst 19 -> 3 pg
            eng.run(max_cycles=1000)
            return a, b, eng, home

        a0, b0, e0, _ = sharded(False)
        assert b0.shed_reason == "capacity" and not b0.generated
        a1, b1, e1, home = sharded(True)
        assert b1.shed_reason is None
        assert e1.pool.prefix_hits == 1
        assert b1.generated == ob.generated
        assert a1.generated == a0.generated
        print("PREFIX-SHARD-OK")
    """)], capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "PREFIX-SHARD-OK" in r.stdout


# ---- traffic: shared-prefix pools -----------------------------------------

def test_shared_prefix_pools_seeded_and_roundtrip(tmp_path):
    from repro.serve.traffic import (poisson_arrivals, scenario_spread,
                                     trace_arrivals, write_trace)
    kw = dict(rate=0.5, seed=3, vocab=256, max_prompt=40, max_output=10,
              min_prompt=26)
    base = poisson_arrivals(40, **kw)
    sp = scenario_spread(shared_prefixes=2, prefix_tokens=24)
    on = poisson_arrivals(40, **kw, scenarios=sp)
    assert on == poisson_arrivals(40, **kw, scenarios=sp)    # seeded
    for a, b in zip(base, on):
        # main rng stream untouched: everything but the header identical
        assert (a.arrival_tick, len(a.prompt), a.max_new, a.scenario) == \
               (b.arrival_tick, len(b.prompt), b.max_new, b.scenario)
        assert a.prompt[24:] == b.prompt[24:]
    heads = {}
    for a in on:
        heads[a.prompt[:24]] = heads.get(a.prompt[:24], 0) + 1
    assert sum(c for c in heads.values() if c >= 2) >= len(on) // 2
    assert all(len(a.prompt) > 24 for a in on)       # tail always private
    path = str(tmp_path / "trace.jsonl")
    write_trace(path, on)
    assert trace_arrivals(path, vocab=256) == on     # round-trippable


def test_scenario_prefix_geometry_validated():
    from repro.serve.traffic import Scenario
    with pytest.raises(ValueError, match="both"):
        Scenario("x", 1.0, 1.0, shared_prefixes=2, prefix_tokens=0)
    with pytest.raises(ValueError, match="negative"):
        Scenario("x", 1.0, 1.0, shared_prefixes=-1, prefix_tokens=4)
