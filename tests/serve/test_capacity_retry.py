"""PoolCapacityError recovery at admission: park -> retry -> success/shed.

The engine prechecks the pool BEFORE popping the admission head (worst-case
page demand, ``len(prompt) + max_new - 1`` words, against the free list
minus the pages reserved for in-flight growth). A failed precheck PARKS the
head in place — nothing is popped, no slot is consumed — and retries next
macro-cycle; capacity freed by evictions (or a released quarantine) admits
it with its ``capacity_retries`` stamp intact. Only after
``capacity_retry_limit`` failed attempts is it shed with reason
``"capacity"``. These tests pin both arcs at 1 in-process device and — via
the subprocess pattern from tests/distributed/test_paged_sharding.py — on
an 8-shard pool, where the squeeze is per home shard.

Geometry used throughout (page_tokens == seq_tile == 8):
1 slot * ceil(32/8) = 4 pages, or 2 slots * ceil(32/8) = 8 pages.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine

ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


@pytest.fixture(scope="module")
def served():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(params, cfg, **kw):
    kw.setdefault("slots", 1)
    kw.setdefault("max_slots", kw["slots"])
    return MultiPortEngine(params, cfg, max_len=32, seq_tile=8,
                           chunk_tokens=8, **kw)


def test_park_then_recover_after_quarantine_release(served):
    """A request that cannot fit its worst case parks (not shed, not
    admitted) and is admitted — tokens identical to an unsqueezed run —
    once the squeeze lifts."""
    cfg, params = served
    eng = _engine(params, cfg)
    assert eng.pool.free_page_count == 4
    eng.pool.quarantine(3)                   # 1 page (8 words) left
    req = eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new=3)   # worst 10 -> 2pg
    for _ in range(3):
        eng.step()
    assert req.admit_tick is None and req.slot is None       # parked, alive
    assert req.capacity_retries == 3
    assert eng.capacity_parked_cycles == 3
    assert eng.shed == [] and len(eng.admission) == 1
    eng.pool.release_quarantine()
    done = eng.run()
    assert [r.rid for r in done] == [req.rid]
    assert eng.capacity_recoveries == 1
    assert req.capacity_retries == 3                         # stamp survives
    ref = _engine(params, cfg)
    ref_req = ref.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new=3)
    ref.run()
    assert req.generated == ref_req.generated                # squeeze-free


def test_park_then_recover_after_eviction(served):
    """The eviction-aware arc: the parked request is admitted by the pages
    a FINISHED request's eviction frees, with the quarantine still held."""
    cfg, params = served
    eng = _engine(params, cfg, slots=2)
    assert eng.pool.free_page_count == 8
    eng.pool.quarantine(5)                   # 3 pages free
    a = eng.submit([1, 2, 3, 4, 5, 6, 7, 8], max_new=2)     # worst 9 -> 2pg
    b = eng.submit([8, 7, 6, 5, 4, 3, 2, 1], max_new=2)
    eng.step()                               # a admitted; b parked behind it
    assert a.admit_tick is not None
    assert b.admit_tick is None and b.capacity_retries >= 1
    done = eng.run()
    assert [r.rid for r in done] == [a.rid, b.rid]
    assert eng.capacity_recoveries == 1
    assert a.finish_cycle < b.admit_cycle                    # evict freed it
    assert len(eng.pool.quarantined_pages) == 5              # never released


def test_retry_exhaustion_sheds_with_reason(served):
    cfg, params = served
    eng = _engine(params, cfg, capacity_retry_limit=3)
    eng.pool.quarantine(4)                   # nothing can ever fit
    req = eng.submit([1, 2, 3], max_new=1)
    done = eng.run()
    assert done == [] and req.shed_reason == "capacity"
    assert eng.shed_capacity == 1 and eng.shed == [req]
    assert req.capacity_retries == 3         # parked exactly limit times
    assert req.admit_tick is None and not req.generated
    assert req.rid not in eng.pool.tables    # never touched the pool
    # pool recovers for the next request once the squeeze lifts
    eng.pool.release_quarantine()
    ok = eng.submit([4, 5], max_new=1)
    assert [r.rid for r in eng.run()] == [ok.rid]


def test_capacity_retry_limit_validation(served):
    cfg, params = served
    with pytest.raises(ValueError):
        _engine(params, cfg, capacity_retry_limit=0)


def test_park_and_recover_on_8_shard_pool():
    """The same park -> release -> recover arc on an 8-device sharded pool:
    the squeeze is per HOME shard, and the recovered request's tokens match
    the unsharded, unsqueezed oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax
        from repro.configs import registry
        from repro.launch.mesh import make_kv_mesh
        from repro.models import init_params
        from repro.serve.engine import MultiPortEngine

        cfg = registry.get("tinyllama-1.1b", reduced=True)
        params = init_params(jax.random.PRNGKey(0), cfg)
        prompt = list(range(1, 11))                  # worst 12 -> 2 pages

        oracle = MultiPortEngine(params, cfg, slots=2, max_slots=2,
                                 max_len=64, seq_tile=8, chunk_tokens=8)
        oref = oracle.submit(prompt, max_new=3)
        oracle.run()

        eng = MultiPortEngine(params, cfg, slots=2, max_slots=2,
                              max_len=64, seq_tile=8, chunk_tokens=8,
                              mesh=make_kv_mesh(8))
        assert eng.pool.plan.pages_per_shard == 2    # 16 pages / 8 shards
        eng.pool.quarantine(1)                       # 1 page left per shard
        req = eng.submit(prompt, max_new=3)
        for _ in range(3):
            eng.step()
        assert req.admit_tick is None and req.capacity_retries == 3
        eng.pool.release_quarantine()
        done = eng.run(max_cycles=1000)
        assert [r.rid for r in done] == [req.rid]
        assert eng.capacity_recoveries == 1
        assert req.generated == oref.generated
        print("SHARDED-RETRY-OK")
    """)], capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "SHARDED-RETRY-OK" in r.stdout
