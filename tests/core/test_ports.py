"""PortConfig / priority encoder unit tests (paper §II-A-1, §II-A-3)."""
import jax.numpy as jnp
import pytest

from repro.core import READ, PortConfig, quad_port
from repro.core.ports import WRITE
from repro.core.priority import (complete_priority, encode_dynamic,
                                 encode_static, next_port_dynamic,
                                 order_static)


def test_port_count_encoding():
    # paper: 00 => 1-port ... 11 => 4-port
    for n in range(1, 5):
        cfg = PortConfig(enabled=tuple(i < n for i in range(4)),
                         roles=(READ,) * 4)
        assert cfg.enabled_count == n
        assert cfg.b1b0 == n - 1


def test_all_enable_role_combinations_valid():
    count = 0
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        for roles_bits in range(16):
            roles = tuple(roles_bits >> i & 1 for i in range(4))
            cfg = PortConfig(enabled=enabled, roles=roles)
            order = cfg.service_order()
            assert len(order) == cfg.enabled_count
            count += 1
    assert count == 15 * 16  # every combination constructible (claim C4)


def test_no_enabled_port_rejected():
    with pytest.raises(ValueError):
        PortConfig(enabled=(False,) * 4, roles=(READ,) * 4)


def test_priority_order_default_a_to_d():
    cfg = quad_port()
    assert cfg.service_order() == (0, 1, 2, 3)


def test_priority_permutation_respected():
    cfg = PortConfig(enabled=(True, True, True, True), roles=(READ,) * 4,
                     priority=(3, 1, 0, 2))
    assert cfg.service_order() == (3, 1, 0, 2)


def test_static_vs_dynamic_encoder_agree():
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        for priority in [(0, 1, 2, 3), (3, 2, 1, 0), (2, 0, 3, 1)]:
            st = encode_static(enabled, priority)
            dy = int(encode_dynamic(jnp.array(enabled), jnp.array(priority)))
            assert st == dy, (enabled, priority)


def test_dynamic_fsm_walk_matches_static_order():
    # walking next_port_dynamic from the reset state visits service_order
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        priority = (0, 1, 2, 3)
        order = order_static(enabled, priority)
        cur = encode_dynamic(jnp.array(enabled), jnp.array(priority))
        walked = [int(cur)]
        for _ in range(len(order) - 1):
            cur = next_port_dynamic(cur, jnp.array(enabled), jnp.array(priority))
            walked.append(int(cur))
        assert tuple(walked) == order
        # one more transition wraps to the start (Fig. 2 reset arc)
        cur = next_port_dynamic(cur, jnp.array(enabled), jnp.array(priority))
        assert int(cur) == order[0]


# --------------------------------------------------------------------------
# describe() / parse(): the per-mix histogram key must be unambiguous
# --------------------------------------------------------------------------

def test_describe_three_port_asymmetric_mix_unambiguous():
    """A 3-port 2W+1R configuration renders count, mix AND per-port roles
    in service order — two different 2W+1R wirings must not collide."""
    cfg = PortConfig(enabled=(True, True, True, False),
                     roles=(WRITE, READ, WRITE, READ))
    assert cfg.mix() == "2W+1R"
    assert cfg.describe() == "3-port[2W+1R|A:W > B:R > C:W]"
    other = PortConfig(enabled=(True, True, True, False),
                       roles=(WRITE, WRITE, READ, READ))
    assert other.mix() == "2W+1R"
    assert other.describe() == "3-port[2W+1R|A:W > B:W > C:R]"
    assert cfg.describe() != other.describe()
    # priority permutation shows through too
    swapped = PortConfig(enabled=(True, True, True, False),
                         roles=(WRITE, READ, WRITE, READ),
                         priority=(2, 1, 0, 3))
    assert swapped.describe() == "3-port[2W+1R|C:W > B:R > A:W]"


def test_describe_pure_mixes_omit_absent_role():
    assert quad_port(roles=(WRITE,) * 4).mix() == "4W"
    assert PortConfig(enabled=(False, False, True, False),
                      roles=(READ,) * 4).describe() == "1-port[1R|C:R]"


def test_describe_parse_round_trip_all_mixes():
    """Every 1-4-port enable set x R/W role assignment (80 combinations)
    round-trips describe() -> parse() -> describe() exactly, preserving the
    enabled set, the enabled ports' roles and the service order."""
    count = 0
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        on = [i for i in range(4) if enabled[i]]
        for bits in range(1 << len(on)):
            roles = [READ] * 4
            for k, p in enumerate(on):
                roles[p] = WRITE if bits >> k & 1 else READ
            cfg = PortConfig(enabled=enabled, roles=tuple(roles))
            back = PortConfig.parse(cfg.describe())
            assert back.enabled == cfg.enabled
            assert back.service_order() == cfg.service_order()
            for p in on:
                assert back.roles[p] == cfg.roles[p]
            assert back.describe() == cfg.describe()
            count += 1
    assert count == 80  # sum over masks of 2^popcount


def test_parse_rejects_malformed_and_inconsistent():
    with pytest.raises(ValueError, match="unparseable"):
        PortConfig.parse("not a port description")
    with pytest.raises(ValueError, match="unparseable port entry"):
        PortConfig.parse("1-port[1W|E:W]")
    with pytest.raises(ValueError, match="listed twice"):
        PortConfig.parse("2-port[2W|A:W > A:W]")
    with pytest.raises(ValueError, match="inconsistent"):
        PortConfig.parse("3-port[2W+1R|A:W > B:R]")     # count mismatch
    with pytest.raises(ValueError, match="inconsistent"):
        PortConfig.parse("2-port[2W|A:W > B:R]")        # mix mismatch


def test_complete_priority():
    assert complete_priority(()) == (0, 1, 2, 3)
    assert complete_priority((2,)) == (2, 0, 1, 3)
    assert complete_priority((3, 0, 1)) == (3, 0, 1, 2)
    with pytest.raises(ValueError, match="distinct"):
        complete_priority((1, 1))
    with pytest.raises(ValueError, match="distinct"):
        complete_priority((4,))
