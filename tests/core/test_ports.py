"""PortConfig / priority encoder unit tests (paper §II-A-1, §II-A-3)."""
import jax.numpy as jnp
import pytest

from repro.core import READ, PortConfig, quad_port
from repro.core.priority import (encode_dynamic, encode_static,
                                 next_port_dynamic, order_static)


def test_port_count_encoding():
    # paper: 00 => 1-port ... 11 => 4-port
    for n in range(1, 5):
        cfg = PortConfig(enabled=tuple(i < n for i in range(4)),
                         roles=(READ,) * 4)
        assert cfg.enabled_count == n
        assert cfg.b1b0 == n - 1


def test_all_enable_role_combinations_valid():
    count = 0
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        for roles_bits in range(16):
            roles = tuple(roles_bits >> i & 1 for i in range(4))
            cfg = PortConfig(enabled=enabled, roles=roles)
            order = cfg.service_order()
            assert len(order) == cfg.enabled_count
            count += 1
    assert count == 15 * 16  # every combination constructible (claim C4)


def test_no_enabled_port_rejected():
    with pytest.raises(ValueError):
        PortConfig(enabled=(False,) * 4, roles=(READ,) * 4)


def test_priority_order_default_a_to_d():
    cfg = quad_port()
    assert cfg.service_order() == (0, 1, 2, 3)


def test_priority_permutation_respected():
    cfg = PortConfig(enabled=(True, True, True, True), roles=(READ,) * 4,
                     priority=(3, 1, 0, 2))
    assert cfg.service_order() == (3, 1, 0, 2)


def test_static_vs_dynamic_encoder_agree():
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        for priority in [(0, 1, 2, 3), (3, 2, 1, 0), (2, 0, 3, 1)]:
            st = encode_static(enabled, priority)
            dy = int(encode_dynamic(jnp.array(enabled), jnp.array(priority)))
            assert st == dy, (enabled, priority)


def test_dynamic_fsm_walk_matches_static_order():
    # walking next_port_dynamic from the reset state visits service_order
    for mask in range(1, 16):
        enabled = tuple(bool(mask >> i & 1) for i in range(4))
        priority = (0, 1, 2, 3)
        order = order_static(enabled, priority)
        cur = encode_dynamic(jnp.array(enabled), jnp.array(priority))
        walked = [int(cur)]
        for _ in range(len(order) - 1):
            cur = next_port_dynamic(cur, jnp.array(enabled), jnp.array(priority))
            walked.append(int(cur))
        assert tuple(walked) == order
        # one more transition wraps to the start (Fig. 2 reset arc)
        cur = next_port_dynamic(cur, jnp.array(enabled), jnp.array(priority))
        assert int(cur) == order[0]
