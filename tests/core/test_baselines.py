"""Baseline designs: semantic equivalence + the traffic/footprint accounting
behind the paper's Table I/II comparisons."""
import jax.numpy as jnp
import numpy as np

from repro.core import (MemorySpec, PortConfig, READ, WRITE, PortRequest,
                        reference_step)
from repro.core.baselines import ReplicatedReads, SinglePortNPass, XorCoded

SPEC = MemorySpec(num_words=16, word_width=2, num_banks=4)


def _reqs():
    rng = np.random.default_rng(3)
    out = []
    for _ in range(4):
        addr = rng.integers(0, SPEC.num_words, 5)
        out.append(PortRequest(addr=jnp.asarray(addr, jnp.int32),
                               data=jnp.asarray(rng.normal(size=(5, 2)),
                                                jnp.float32),
                               mask=jnp.asarray(rng.random(5) > 0.3)))
    return out


CFG = PortConfig(enabled=(True, True, True, True),
                 roles=(WRITE, READ, READ, READ))


def test_replicated_reads_semantics():
    base = ReplicatedReads(SPEC, n_read_ports=3)
    reqs = _reqs()
    storage = base.init_storage()
    s, reads = base.step(CFG, storage, reqs)
    ref_s, ref_reads = reference_step(SPEC, CFG, np.zeros((16, 2), np.float32),
                                      reqs)
    for rep in range(3):   # every replica coherent with the reference
        np.testing.assert_allclose(np.asarray(s[rep]), ref_s)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(reads[p]), ref_reads[p])


def test_xor_coded_semantics_and_parity():
    base = XorCoded(SPEC)
    reqs = _reqs()
    (data, parity), reads = base.step(CFG, base.init_storage(), reqs)
    ref_s, ref_reads = reference_step(SPEC, CFG, np.zeros((16, 2), np.float32),
                                      reqs)
    np.testing.assert_allclose(
        np.asarray(data.reshape(16, 2)), ref_s, atol=1e-6)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(reads[p]), ref_reads[p])
    # parity bank == sum over banks (reconstruction invariant)
    np.testing.assert_allclose(np.asarray(parity),
                               np.asarray(data).sum(0), atol=1e-5)


def test_footprint_ratios_match_paper_table():
    """Area analogue: proposed = 1x; replicated-quad ~ the 12T school (2x in
    the paper's normalization -> 4 replicas here, documented deviation);
    XOR-coded = 1 + 1/banks."""
    q = 8
    single = SinglePortNPass(SPEC).counters(CFG, q)
    assert single.footprint_words == SPEC.num_words            # proposed: 1x
    rep = ReplicatedReads(SPEC, 3).counters(CFG, q)
    assert rep.footprint_words == 3 * SPEC.num_words
    xor = XorCoded(SPEC).counters(CFG, q)
    assert xor.footprint_words == SPEC.num_words + SPEC.words_per_bank


def test_bandwidth_traversal_counts():
    """Claim C1 structurally: the bare macro traverses storage once per
    enabled port; the wrapper (kernel) traverses once per macro-cycle."""
    q = 8
    for n in range(1, 5):
        cfg = PortConfig(enabled=tuple(i < n for i in range(4)),
                         roles=(WRITE, READ, READ, READ))
        c = SinglePortNPass(SPEC).counters(cfg, q)
        assert c.storage_traversals == n       # baseline: N passes
    # the proposed kernel: exactly 1 traversal regardless of N (by
    # construction — the grid walks each bank once; asserted in
    # tests/kernels/test_multiport_kernel.py via traffic accounting)
