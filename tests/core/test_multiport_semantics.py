"""Property suite (claims C3/C4): the jnp step, the Pallas kernel, and every
baseline agree with the serial reference simulator across random port
configurations, priorities, addresses and masks."""
import numpy as np
import pytest

hp = pytest.importorskip(
    "hypothesis", reason="property tests need the 'dev' extra")
import hypothesis.strategies as st
import jax.numpy as jnp

from repro.core import (MemorySpec, PortConfig, READ, WRITE, PortRequest,
                        reference_step, step)
from repro.core.baselines import SinglePortNPass
from repro.kernels import ops

SPEC = MemorySpec(num_words=32, word_width=4, num_banks=4)
Q = 6


@st.composite
def port_config(draw):
    enabled = draw(st.lists(st.booleans(), min_size=4, max_size=4)
                   .filter(lambda e: any(e)))
    roles = draw(st.lists(st.sampled_from([READ, WRITE]), min_size=4, max_size=4))
    priority = draw(st.permutations(range(4)))
    return PortConfig(enabled=tuple(enabled), roles=tuple(roles),
                      priority=tuple(priority))


@st.composite
def requests(draw):
    reqs = []
    for _ in range(4):
        addr = draw(st.lists(st.integers(0, SPEC.num_words - 1),
                             min_size=Q, max_size=Q))
        mask = draw(st.lists(st.booleans(), min_size=Q, max_size=Q))
        data = draw(st.lists(st.integers(-8, 8), min_size=Q * 4, max_size=Q * 4))
        reqs.append(PortRequest(
            addr=jnp.array(addr, jnp.int32),
            data=jnp.array(data, jnp.float32).reshape(Q, 4),
            mask=jnp.array(mask)))
    return reqs


@hp.given(cfg=port_config(), reqs=requests())
@hp.settings(max_examples=60, deadline=None)
def test_step_matches_reference(cfg, reqs):
    storage = jnp.arange(SPEC.num_words * 4, dtype=jnp.float32).reshape(-1, 4)
    s_jnp, r_jnp = step(SPEC, cfg, storage, reqs)
    s_ref, r_ref = reference_step(SPEC, cfg, np.asarray(storage), reqs)
    np.testing.assert_allclose(np.asarray(s_jnp), s_ref)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(r_jnp[p]), r_ref[p])


@hp.given(cfg=port_config(), reqs=requests())
@hp.settings(max_examples=25, deadline=None)
def test_kernel_matches_reference(cfg, reqs):
    storage = jnp.arange(SPEC.num_words * 4, dtype=jnp.float32).reshape(-1, 4)
    s_k, r_k = ops.multiport_step(SPEC, cfg, storage, reqs, interpret=True)
    s_ref, r_ref = reference_step(SPEC, cfg, np.asarray(storage), reqs)
    np.testing.assert_allclose(np.asarray(s_k), s_ref)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(r_k[p]), r_ref[p])


@hp.given(cfg=port_config(), reqs=requests())
@hp.settings(max_examples=25, deadline=None)
def test_single_port_baseline_matches_reference(cfg, reqs):
    base = SinglePortNPass(SPEC)
    storage = jnp.zeros((SPEC.num_words, 4), jnp.float32)
    s_b, r_b = base.step(cfg, storage, reqs)
    s_ref, r_ref = reference_step(SPEC, cfg, np.asarray(storage), reqs)
    np.testing.assert_allclose(np.asarray(s_b), s_ref)
    for p in range(4):
        np.testing.assert_allclose(np.asarray(r_b[p]), r_ref[p])


def test_same_cycle_write_read_priority_visibility():
    """A>B priority: port B (read) sees port A's same-cycle write; with the
    priorities swapped it sees the pre-cycle value (contention-free C3)."""
    spec = MemorySpec(num_words=8, word_width=2, num_banks=2)
    storage = jnp.zeros((8, 2), jnp.float32)
    w = PortRequest(addr=jnp.array([3], jnp.int32),
                    data=jnp.full((1, 2), 7.0), mask=jnp.array([True]))
    r = PortRequest(addr=jnp.array([3], jnp.int32),
                    data=jnp.zeros((1, 2)), mask=jnp.array([True]))
    idle = PortRequest(addr=jnp.zeros((1,), jnp.int32),
                       data=jnp.zeros((1, 2)), mask=jnp.array([False]))

    cfg_w_first = PortConfig(enabled=(True, True, False, False),
                             roles=(WRITE, READ, READ, READ),
                             priority=(0, 1, 2, 3))
    _, reads = step(spec, cfg_w_first, storage, [w, r, idle, idle])
    assert float(reads[1][0, 0]) == 7.0

    cfg_r_first = PortConfig(enabled=(True, True, False, False),
                             roles=(WRITE, READ, READ, READ),
                             priority=(1, 0, 2, 3))
    _, reads = step(spec, cfg_r_first, storage, [w, r, idle, idle])
    assert float(reads[1][0, 0]) == 0.0


def test_write_write_priority_last_wins():
    spec = MemorySpec(num_words=8, word_width=2, num_banks=2)
    storage = jnp.zeros((8, 2), jnp.float32)
    wa = PortRequest(addr=jnp.array([5], jnp.int32),
                     data=jnp.full((1, 2), 1.0), mask=jnp.array([True]))
    wb = PortRequest(addr=jnp.array([5], jnp.int32),
                     data=jnp.full((1, 2), 2.0), mask=jnp.array([True]))
    idle = PortRequest(addr=jnp.zeros((1,), jnp.int32),
                       data=jnp.zeros((1, 2)), mask=jnp.array([False]))
    cfg = PortConfig(enabled=(True, True, False, False),
                     roles=(WRITE, WRITE, READ, READ))
    new_s, _ = step(spec, cfg, storage, [wa, wb, idle, idle])
    assert float(new_s[5, 0]) == 2.0   # lower-priority port serviced later


def test_in_queue_duplicate_write_last_wins():
    spec = MemorySpec(num_words=8, word_width=1, num_banks=2)
    storage = jnp.zeros((8, 1), jnp.float32)
    w = PortRequest(addr=jnp.array([2, 2, 2], jnp.int32),
                    data=jnp.array([[1.0], [2.0], [3.0]]),
                    mask=jnp.array([True, True, True]))
    idle = PortRequest(addr=jnp.zeros((3,), jnp.int32),
                       data=jnp.zeros((3, 1)), mask=jnp.zeros((3,), bool))
    cfg = PortConfig(enabled=(True, False, False, False),
                     roles=(WRITE, READ, READ, READ))
    new_s, _ = step(spec, cfg, storage, [w, idle, idle, idle])
    assert float(new_s[2, 0]) == 3.0
