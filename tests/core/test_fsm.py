"""FSM walk sequencers: the single-port rotation's phase-domain contract
and the schedule-driven walk that generalizes the rigid per-config walk."""
import pytest

from repro.core.fsm import PhaseError, rotate_single_port, walk_schedule
from repro.core.ports import READ, WRITE, PortConfig


def test_rotate_negative_phase_raises_named_error():
    with pytest.raises(PhaseError, match="non-negative, got -1"):
        rotate_single_port((0, 1, 2), -1)
    with pytest.raises(PhaseError, match="-7"):
        rotate_single_port((0, 1, 2), -7)
    # PhaseError is a ValueError subclass — existing except-ValueError
    # callers keep working
    assert issubclass(PhaseError, ValueError)


def test_rotate_large_phase_wraps():
    schedule = (3, 1, 0, 2)
    for phase in (0, 1, 4, 5, 4 * 10**6 + 2, 10**12 + 3):
        assert rotate_single_port(schedule, phase) == \
            (schedule[phase % len(schedule)],)


def test_rotate_empty_schedule_rejected():
    with pytest.raises(ValueError, match="empty schedule"):
        rotate_single_port((), 0)


def test_walk_schedule_order_and_payloads():
    """walk_schedule services each (config, payload) pair once, in schedule
    order, handing the service body the traversal's own PortConfig."""
    c1 = PortConfig(enabled=(True, False, False, True),
                    roles=(WRITE, READ, READ, WRITE), priority=(3, 0, 1, 2))
    c2 = PortConfig(enabled=(False, True, False, False),
                    roles=(READ,) * 4)
    seen = walk_schedule(
        [(c1, "evict+decode"), (c2, "status")], [],
        lambda state, payload, cfg: state + [(payload, cfg.service_order())])
    assert seen == [("evict+decode", (3, 0)), ("status", (1,))]


def test_walk_schedule_empty_is_noop():
    assert walk_schedule([], "state", lambda s, p, c: s + "x") == "state"
