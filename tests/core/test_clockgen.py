"""Clock generator invariants from paper Fig. 4: per external CLK cycle,
BACK has N pulses and CLK2 has N-1 pulses for an N-port configuration."""
from repro.core import PortConfig, READ, build_schedule, simulate_waveform
from repro.core.clockgen import effective_access_rate


def _cfg(n, priority=(0, 1, 2, 3)):
    return PortConfig(enabled=tuple(i < n for i in range(4)),
                      roles=(READ,) * 4, priority=priority)


def test_schedule_pulse_counts():
    for n in range(1, 5):
        s = build_schedule(_cfg(n))
        assert s.n_back_pulses == n
        assert s.n_clk2_pulses == n - 1
        assert s.b1b0 == n - 1


def test_waveform_fig4_reproduction():
    # the paper's Fig. 4 simulation: cycles configured 4,3,2,1-port
    configs = [_cfg(4), _cfg(3), _cfg(2), _cfg(1)]
    res = 12
    wf = simulate_waveform(configs, resolution=res)
    for c, n in enumerate([4, 3, 2, 1]):
        seg = slice(c * res, (c + 1) * res)
        assert wf.back[seg].sum() == n
        assert wf.clk2[seg].sum() == n - 1
        assert wf.clkp[seg].sum() == 1


def test_waveform_resets_to_highest_priority():
    # CLKP edge initializes selection to the highest-priority enabled port
    cfg = PortConfig(enabled=(False, True, True, False), roles=(READ,) * 4,
                     priority=(2, 1, 0, 3))
    wf = simulate_waveform([cfg], resolution=8)
    assert wf.selected_port[0] == 2          # port C first under C>B priority


def test_effective_access_rate_4x():
    # Table II: 250 MHz CLK, 4 ports => 1 GHz effective memory access
    assert effective_access_rate(_cfg(4), 250e6) == 1e9
    assert effective_access_rate(_cfg(1), 250e6) == 250e6
