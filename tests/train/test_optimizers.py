"""Optimizer unit tests: AdamW vs 8-bit AdamW parity, adafactor memory,
quantization roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (AdamWConfig, adafactor_init, adafactor_update,
                         adamw8bit_init, adamw8bit_update, adamw_init,
                         adamw_update, warmup_cosine)
from repro.optim.quantized import _dequantize, _quantize


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (32, 16)),
            "b": jnp.zeros((16,)),
            "stack": jax.random.normal(k, (3, 8, 8))}


def _grads(seed=1):
    k = jax.random.PRNGKey(seed)
    return jax.tree_util.tree_map(
        lambda p: jax.random.normal(k, p.shape) * 0.01, _params())


def test_quantize_roundtrip_accuracy():
    """Log-dynamic map: bounded RELATIVE error at every magnitude — including
    elements orders of magnitude below their block max (the case that breaks
    linear absmax int8 for Adam's v)."""
    rng = np.random.default_rng(0)
    # magnitudes spanning ~5.5 decades within shared blocks, all above the
    # 7-decade representable floor
    mant = rng.uniform(0.3, 1.0, 1024) * np.where(rng.random(1024) < 0.5, -1, 1)
    x = jnp.asarray(mant * 10.0 ** rng.integers(-5, 1, 1024), jnp.float32)
    for signed in (True, False):
        xx = x if signed else jnp.abs(x)
        q = _quantize(xx, signed=signed)
        y = _dequantize(q, xx.shape)
        rel = np.abs(np.asarray(y) - np.asarray(xx)) / np.abs(np.asarray(xx))
        tol = 0.085 if signed else 0.045   # half a log-step + rounding
        assert rel.max() < tol, (signed, rel.max())


def test_quantize_exact_zero():
    x = jnp.zeros((130,), jnp.float32)
    for signed in (True, False):
        y = _dequantize(_quantize(x, signed), x.shape)
        assert float(jnp.abs(y).max()) == 0.0


def test_adamw8bit_tracks_adamw():
    cfg = AdamWConfig(weight_decay=0.0)
    p32, p8 = _params(), _params()
    s32, s8 = adamw_init(p32, cfg), adamw8bit_init(p8, cfg)
    for i in range(20):
        g = jax.tree_util.tree_map(
            lambda p: jnp.sin(p * (i + 1)) * 0.01, p32)
        p32, s32, _ = adamw_update(g, s32, p32, 1e-2, cfg)
        p8, s8, _ = adamw8bit_update(g, s8, p8, 1e-2, cfg)
    diff = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree_util.tree_leaves(p32),
                               jax.tree_util.tree_leaves(p8)))
    scale = max(float(jnp.abs(a).max())
                for a in jax.tree_util.tree_leaves(p32))
    assert diff < 0.05 * scale, (diff, scale)


def test_adamw8bit_state_bytes_are_2x_params():
    # last dims >= BLOCK so last-axis blocking has no padding overhead
    # (model weight matrices always satisfy this)
    k = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(k, (64, 128)),
         "b": jnp.zeros((128,)),
         "stack": jax.random.normal(k, (3, 8, 64))}
    s = adamw8bit_init(p, AdamWConfig())
    pbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(p))
    sbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(s))
    # int8 m+v (2 bytes/param) + f32 scales (4/64 bytes/param) + step
    assert sbytes < 0.6 * (2 * pbytes), (sbytes, pbytes)


def test_adafactor_memory_sublinear_and_descends():
    p = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64))}
    s = adafactor_init(p, AdamWConfig(weight_decay=0.0))
    vbytes = sum(x.size * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(s["v"]))
    assert vbytes <= 2 * 64 * 4 + 64  # O(n+m), not O(nm)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - 3.0))
    for _ in range(50):
        g = jax.grad(loss)(p)
        p, s, _ = adafactor_update(g, s, p, 0.1, AdamWConfig(weight_decay=0.0))
    assert float(loss(p)) < 64 * 64 * 9 * 0.05


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 0.11
    assert lrs[99] < 0.2 and all(l >= 0 for l in lrs)
