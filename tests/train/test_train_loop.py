"""E12: the real training loop learns the synthetic 'chain' task (loss
decreases), with checkpointing + restart reproducing bit-identical results."""
import jax
import numpy as np

from repro.configs import registry
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.distributed.fault import FailureInjector, StragglerDetector
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.train.loop import RunnerConfig, TrainingRunner
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def _setup(tmp_path, arch="tinyllama-1.1b", **tkw):
    cfg = registry.get(arch, reduced=True)
    tcfg = TrainConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60,
                       adamw=AdamWConfig(weight_decay=0.0), **tkw)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    loader = ShardedLoader(cfg, DataConfig(seed=7), batch=8, seq=16)
    return cfg, state, step, loader


def test_loss_decreases(tmp_path):
    cfg, state, step, loader = _setup(tmp_path)
    runner = TrainingRunner(step, state, loader.get,
                            RunnerConfig(ckpt_dir=str(tmp_path / "ck"),
                                         ckpt_every=20, async_ckpt=False))
    runner.run(40)
    first = np.mean([h["ce"] for h in runner.history[:5]])
    last = np.mean([h["ce"] for h in runner.history[-5:]])
    assert last < first - 0.5, (first, last)


def test_restart_reproduces_identical_losses(tmp_path):
    """Crash at step 12, restart from ckpt at step 10 — losses from the
    restarted steps must equal an uninterrupted run's exactly."""
    cfg, state, step, loader = _setup(tmp_path)
    rc = RunnerConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5,
                      async_ckpt=False)
    clean = TrainingRunner(step, state, loader.get, rc)
    clean.run(20)
    losses_clean = {h["step"]: h["ce"] for h in clean.history}

    rc2 = RunnerConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5,
                       async_ckpt=False)
    faulty = TrainingRunner(step, state, loader.get, rc2,
                            injector=FailureInjector(fail_at_steps=(12,)))
    faulty.run(20)
    assert faulty.restarts == 1
    losses_faulty = {h["step"]: h["ce"] for h in faulty.history}
    for s in range(13, 20):
        np.testing.assert_allclose(losses_faulty[s], losses_clean[s],
                                   rtol=1e-6)


def test_microbatch_accumulation_matches_full_batch(tmp_path):
    """grad accumulation (4 microbatches) == single big batch, same loss
    trajectory to fp tolerance."""
    cfg, state, step1, loader = _setup(tmp_path, microbatches=1)
    _, state4, step4, _ = _setup(tmp_path, microbatches=4)
    b = loader.get(0)
    s1, m1 = step1(state, b)
    s4, m4 = step4(state4, b)
    np.testing.assert_allclose(float(m1["ce"]), float(m4["ce"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m4["grad_norm"]),
                               rtol=1e-3)


def test_straggler_detection():
    det = StragglerDetector(multiplier=3.0, warmup=2)
    for s in range(6):
        assert not det.record(s, 0.1)
    assert det.record(6, 1.0)          # 10x the EMA -> straggler
    assert det.events and det.events[0]["step"] == 6
    assert not det.record(7, 0.1)      # EMA not poisoned by the outlier
