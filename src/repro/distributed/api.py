"""Activation-sharding hints: a context the launcher installs so model code
can constrain key intermediates (logits, hidden states, MoE buffers) without
depending on the mesh at definition time.

Model code calls ``hint(x, "logits")``; outside any context this is a no-op,
so tests and single-device runs are unaffected.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_SPECS: ContextVar[Optional[dict]] = ContextVar("activation_specs", default=None)


@contextlib.contextmanager
def activation_specs(specs: dict):
    """specs: name -> PartitionSpec (e.g. {"logits": P("data", None, "model")})."""
    token = _SPECS.set(specs)
    try:
        yield
    finally:
        _SPECS.reset(token)


def hint(x: jax.Array, name: str) -> jax.Array:
    specs = _SPECS.get()
    if not specs or name not in specs:
        return x
    spec = specs[name]
    if spec is None:
        return x
    # pad the spec to the array rank (trailing dims unsharded)
    if len(spec) < x.ndim:
        spec = P(*(tuple(spec) + (None,) * (x.ndim - len(spec))))
    return jax.lax.with_sharding_constraint(x, spec)
