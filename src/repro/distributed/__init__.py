"""repro.distributed subpackage."""
