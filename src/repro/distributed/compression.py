"""Gradient compression for the cross-pod reduction: int8 + error feedback.

At 512+ chips the pod-to-pod hop (DCN / long-haul ICI) is the scarce
bandwidth; intra-pod reduce-scatter stays full precision while the pod-axis
all-reduce runs int8. Mechanism (pure auto-SPMD — no manual collectives):

  1. the train step computes PER-POD gradients: the global batch is reshaped
     to [n_pods, local_batch, ...] (leading axis sharded on "pod") and
     ``vmap(grad)`` produces gradient leaves of shape [n_pods, ...];
  2. error feedback adds each pod's residual from the previous step;
  3. blocks of 256 values share one scale, taken as the MAX over pods (one
     tiny f32 all-reduce, 1/256 of gradient volume);
  4. values quantize to int8 with ceil(log2(n_pods)) guard bits so the sum
     over pods cannot overflow int8 — the reduction over the pod-sharded
     axis is then an all-reduce with an int8 operand (4x fewer wire bytes
     than f32, visible in the dry-run HLO);
  5. the residual (pre-quantization minus quantized) becomes the next step's
     error-feedback state (Seide et al. 2014; Karimireddy et al. 2019).

Error feedback keeps the *local* residual on each pod: the ``ef`` state
carries a leading [n_pods] axis sharded on "pod".
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any
BLOCK = 256


def _blockify(x: jax.Array) -> tuple[jax.Array, int]:
    """[P, ...] -> ([P, nblocks, BLOCK], n_elems_per_pod)."""
    p = x.shape[0]
    flat = x.reshape(p, -1).astype(jnp.float32)
    n = flat.shape[1]
    pad = (-n) % BLOCK
    return jnp.pad(flat, ((0, 0), (0, pad))).reshape(p, -1, BLOCK), n


def compressed_mean_pods(g: jax.Array, ef: jax.Array
                         ) -> tuple[jax.Array, jax.Array]:
    """Mean of per-pod gradients with int8 wire format + error feedback.

    Args:
      g:  [n_pods, *shape] per-pod gradients (leading axis pod-sharded).
      ef: [n_pods, *shape] f32 residual state.

    Returns: (mean_grad [*shape] f32, new_ef [n_pods, *shape] f32).
    """
    n_pods = g.shape[0]
    shape = g.shape[1:]
    corrected = g.astype(jnp.float32) + ef
    blocks, n = _blockify(corrected)                     # [P, nb, BLOCK]

    guard = max(0, math.ceil(math.log2(max(n_pods, 1))))
    qmax = 127 >> guard

    local_max = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)   # [P, nb, 1]
    scale = jnp.max(local_max, axis=0, keepdims=True) / qmax       # pod all-reduce (tiny)
    scale = jnp.maximum(scale, 1e-30)

    q = jnp.clip(jnp.round(blocks / scale), -qmax, qmax).astype(jnp.int8)
    total = jnp.sum(q, axis=0)                           # int8 all-reduce over pod
    mean = (total.astype(jnp.float32) * scale[0]) / n_pods
    mean = mean.reshape(-1)[:n].reshape(shape)

    deq_local = q.astype(jnp.float32) * scale            # [P, nb, BLOCK]
    resid = (blocks - deq_local).reshape(n_pods, -1)[:, :n].reshape(g.shape)
    return mean, resid


def compressed_mean_tree(grads: PyTree, ef: PyTree) -> tuple[PyTree, PyTree]:
    """Apply compressed_mean_pods leafwise. grads/ef leaves: [n_pods, ...]."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    outs = [compressed_mean_pods(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in outs]),
            treedef.unflatten([o[1] for o in outs]))


def init_ef_state(params: PyTree, n_pods: int) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
