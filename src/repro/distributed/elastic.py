"""Elastic scaling: move a training/serving state between meshes.

A checkpoint written on one mesh restores onto any other (checkpoint/ckpt.py
device_puts per target sharding); for live resizing without a filesystem
round-trip, ``reshard_tree`` re-places every leaf under the new mesh's
sharding rules. Combined with step-addressable data (data/pipeline.py) this
gives full elastic semantics: kill N pods, rebuild the mesh, reshard, resume
at the same step with identical results (tests/distributed/test_elastic.py).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

PyTree = Any


def reshard_tree(tree: PyTree, mesh: Mesh, pspecs: PyTree) -> PyTree:
    """device_put every leaf to NamedSharding(mesh, spec)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(jax.device_get(x), NamedSharding(mesh, s)),
        tree, pspecs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))


def replicate_tree(tree: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(
            jax.device_get(x),
            NamedSharding(mesh, P(*(None,) * getattr(x, "ndim", 0)))), tree)
