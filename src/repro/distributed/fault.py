"""Fault tolerance primitives: heartbeats, straggler detection, failure
injection (for tests), and restart policy.

The coordinator model is file-based (works on any shared filesystem — the
common denominator on TPU pods): every worker touches
``<dir>/heartbeat_<worker>`` each step; the monitor flags workers whose last
beat is older than ``timeout_s``. Straggler mitigation is deadline-based:
step durations feed an EMA; a step slower than ``multiplier`` x EMA is logged
as a straggler event and (policy "skip") the runner advances to the next
step's data rather than re-issuing — safe because batches are pure functions
of the step index (data/pipeline.py).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional


class InjectedFailure(RuntimeError):
    """Raised by FailureInjector to simulate a worker crash."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given global steps (tests/demos)."""
    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


class Heartbeat:
    def __init__(self, directory: str, worker: str = "w0"):
        self.path = os.path.join(directory, f"heartbeat_{worker}")
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int) -> None:
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")

    @staticmethod
    def stale_workers(directory: str, timeout_s: float) -> list[str]:
        now = time.time()
        stale = []
        if not os.path.isdir(directory):
            return stale
        for name in os.listdir(directory):
            if not name.startswith("heartbeat_"):
                continue
            with open(os.path.join(directory, name)) as f:
                parts = f.read().split()
            if now - float(parts[1]) > timeout_s:
                stale.append(name.removeprefix("heartbeat_"))
        return stale


@dataclasses.dataclass
class StragglerDetector:
    """EMA-based step-time outlier detection."""
    multiplier: float = 3.0
    ema_decay: float = 0.9
    warmup: int = 3
    _ema: Optional[float] = None
    _n: int = 0
    events: list = dataclasses.field(default_factory=list)

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step was a straggler."""
        self._n += 1
        if self._ema is None:
            self._ema = duration_s
            return False
        is_straggler = (self._n > self.warmup
                        and duration_s > self.multiplier * self._ema)
        if is_straggler:
            self.events.append({"step": step, "duration": duration_s,
                                "ema": self._ema})
        else:  # stragglers don't poison the EMA
            self._ema = (self.ema_decay * self._ema
                         + (1 - self.ema_decay) * duration_s)
        return is_straggler
