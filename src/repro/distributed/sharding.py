"""PartitionSpec rules: FSDP x TP x EP over the production mesh.

Parameters are sharded 2-D (Megatron TP on the ``model`` axis + FSDP on the
``data`` axis, optionally ("pod","data") for >=100B models); the stack axis
added by layer-scanning is never sharded. Every rule is divisibility-guarded:
a dimension that does not divide by its mesh axis falls back to replication
(e.g. 40 attention heads on a 16-way model axis -> the head matmul columns
shard, the per-head activations replicate; XLA inserts the reshard).

Batch specs are computed per shape cell (``batch_spec``): the largest subset
of data axes whose product divides the global batch is used — long_500k with
global_batch=1 therefore replicates batch and shards the KV-cache sequence
dim instead (``kv_cache_spec``).

Serving adds a third spec family: the paged KV POOL (``kv_pool_spec``) —
the physical word-addressable pool that backs the multi-port serving
engine. Its word axis IS the sequence/page axis (word ``w`` belongs to page
``w // page_tokens``), and it shards across the ``kv`` mesh axis with
PAGE-ALIGNED boundaries: every shard holds a whole number of pages, so a
page never straddles devices and the page tables (host-side python ints)
stay replicated control plane. ``kv_shard_plan`` is the validated geometry
(shards, pages/words per shard) both the pool's device-aware allocator and
the launchers consume.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any


def compat_shard_map(fn, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` (moved out of ``jax.experimental``
    in newer JAX). ``check_rep=False`` everywhere: the mapped bodies launch
    Pallas calls / psums whose replication the checker cannot see through.
    """
    try:
        from jax.experimental.shard_map import shard_map as _sm
        return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    except ImportError:
        from jax import shard_map as _sm          # >= 0.7 stable API
        try:
            return _sm(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
        except TypeError:                         # kwarg renamed over time
            return _sm(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs)


@dataclasses.dataclass(frozen=True)
class Rules:
    """Axis assignment for one run."""
    tp: str = "model"                       # tensor/expert-parallel axis
    fsdp: tuple[str, ...] = ("data",)       # parameter/optimizer sharding axes
    dp: tuple[str, ...] = ("data",)         # batch axes (pod included if present)

    @staticmethod
    def for_mesh(mesh: Mesh, *, fsdp_over_pod: bool = False) -> "Rules":
        axes = mesh.axis_names
        if "pod" in axes:
            return Rules(tp="model",
                         fsdp=("pod", "data") if fsdp_over_pod else ("data",),
                         dp=("pod", "data"))
        return Rules()


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, axes, dim: int):
    """axes if dim divides by their product, else None (replicate)."""
    if axes is None:
        return None
    size = _axsize(mesh, axes)
    if size > 1 and dim % size == 0:
        return axes if isinstance(axes, str) else tuple(axes)
    # try shrinking a tuple of axes from the left (drop 'pod' first)
    if not isinstance(axes, str) and len(axes) > 1:
        return _fit(mesh, axes[1:], dim)
    return None


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

_COL = re.compile(r"(attn/(wq|wk|wv)|ffn/(w_gate|w_up)|shared/(w_gate|w_up)|"
                  r"tm/(wv|wg)|cm/wk|in_z|in_x)/w$")
_ROW = re.compile(r"(attn/wo|ffn/w_down|shared/w_down|tm/wo|cm/wv|out_proj)/w$")
_REP_OUT = re.compile(r"(tm/(wr|wk)|cm/wr|in_B|in_C|in_dt)/w$")
_MOE_COL = re.compile(r"moe/(w_gate|w_up)$")
_MOE_ROW = re.compile(r"moe/w_down$")


def _n_stack(path: str) -> int:
    if path.startswith("groups/"):
        return 2
    if path.startswith(("layers/", "tail/")):
        return 1
    return 0


def _base_spec(path: str, shape, mesh: Mesh, r: Rules):
    nd = len(shape)
    if path == "embed/w":                       # [V, d]: d-sharded lookup
        return (_fit(mesh, r.fsdp, shape[0]), _fit(mesh, r.tp, shape[1]))
    if path == "lm_head/w":                     # [d, V]: column-parallel
        return (_fit(mesh, r.fsdp, shape[0]), _fit(mesh, r.tp, shape[1]))
    if _MOE_COL.search(path):                   # [E, d, f]
        return (_fit(mesh, r.tp, shape[0]), _fit(mesh, r.fsdp, shape[1]), None)
    if _MOE_ROW.search(path):                   # [E, f, d]
        return (_fit(mesh, r.tp, shape[0]), None, _fit(mesh, r.fsdp, shape[2]))
    if path.endswith("router/w"):               # [d, E]
        return (_fit(mesh, r.fsdp, shape[0]), None)
    if _COL.search(path):                       # [d, out]: column-parallel
        return (_fit(mesh, r.fsdp, shape[0]), _fit(mesh, r.tp, shape[1]))
    if _ROW.search(path):                       # [in, d]: row-parallel
        return (_fit(mesh, r.tp, shape[0]), _fit(mesh, r.fsdp, shape[1]))
    if _REP_OUT.search(path):                   # [d, small]: fsdp rows only
        return (_fit(mesh, r.fsdp, shape[0]), None)
    if path.endswith(("/b",)):                  # column biases [out]
        return (_fit(mesh, r.tp, shape[0]),)
    if path.endswith("w_lora_a"):
        return (_fit(mesh, r.fsdp, shape[0]), None)
    if path.endswith("w_lora_b"):
        return (None, _fit(mesh, r.fsdp, shape[1]))
    if path.endswith("conv_x/w"):               # [K, d_in]
        return (None, _fit(mesh, r.tp, shape[1]))
    if path.endswith(("dt_bias", "a_log", "d_skip")):
        return (_fit(mesh, r.tp, shape[0]),)
    if path.endswith("mamba/norm/scale"):         # mamba inner norm [d_in]
        return (_fit(mesh, r.tp, shape[0]),)
    return (None,) * nd                          # replicate smalls


_ATTN_PROJ = re.compile(r"attn/(wq|wk|wv)/(w|b)$")


def _head_aligned(sub: str, spec, mesh: Mesh, r: Rules,
                  cfg: Optional[ArchConfig]):
    """Drop tp from attention K/V projections that would split a head.

    Megatron-style TP must shard q/k/v on the HEAD boundary: a tp axis that
    does not divide the head count would slice inside a single head's
    ``head_dim`` columns, which breaks RoPE's half-dim pairing (and, on some
    XLA versions, miscompiles under the layer scan). The GQA-standard
    fallback — K/V columns replicate while Q still shards — applies when
    ``n_heads`` divides the tp axis but ``n_kv_heads`` does not
    (tp > n_kv_heads with grouped queries), exactly how ``kv_cache_spec``
    already guards the cached heads.

    When even the QUERY heads cannot shard (``n_heads % tp != 0``), the old
    behavior silently replicated ALL q/k/v columns — attention ran with no
    tensor parallelism at all, and the only symptom was a quietly flat
    memory-per-device curve. That mesh/head mismatch is now a hard error;
    a head-group resharding rule for it stays a ROADMAP item.
    """
    if cfg is None:
        return spec
    m = _ATTN_PROJ.search(sub)
    if not m:
        return spec
    tp_size = max(_axsize(mesh, r.tp), 1)
    if cfg.n_heads % tp_size != 0:
        raise ValueError(
            f"attention TP mesh/head mismatch for {sub!r}: tp axes "
            f"{tuple(_flat_axes(r.tp))} (size {tp_size}) do not divide "
            f"n_heads={cfg.n_heads} (n_kv_heads={cfg.n_kv_heads}) — every "
            f"q/k/v column would silently replicate, disabling attention "
            f"tensor parallelism. Shrink the tp axis to a divisor of "
            f"n_heads, or wait for the head-group resharding rule "
            f"(ROADMAP: attention TP for tp > head count).")
    heads = cfg.n_heads if m.group(1) == "wq" else cfg.n_kv_heads
    if heads % tp_size == 0:
        return spec
    tp_axes = set(_flat_axes(r.tp))

    def strip(axes):
        if axes is None:
            return None
        kept = tuple(a for a in _flat_axes(axes) if a not in tp_axes)
        return kept[0] if len(kept) == 1 else (kept or None)

    # only the output-column dim (last) carries tp for these projections
    return tuple(spec[:-1]) + (strip(spec[-1]),)


def param_pspecs(params: PyTree, mesh: Mesh, rules: Optional[Rules] = None,
                 cfg: Optional[ArchConfig] = None) -> PyTree:
    """PartitionSpec tree mirroring ``params`` (works on ShapeDtypeStructs).

    ``cfg``, when provided, enables head-aligned attention TP (see
    :func:`_head_aligned`); without it the raw divisibility guards apply.
    """
    r = rules or Rules.for_mesh(mesh)

    def assign(path_tuple, leaf):
        path = "/".join(_key_str(k) for k in path_tuple)
        n = _n_stack(path)
        # strip the stack prefix components from the rule path
        sub = "/".join(path.split("/")[n:]) if n else path
        base = _base_spec(sub, leaf.shape[n:], mesh, r)
        base = _head_aligned(sub, tuple(base), mesh, r, cfg)
        return P(*((None,) * n + tuple(base)))

    return jax.tree_util.tree_map_with_path(assign, params)


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return str(k.idx)
    return str(getattr(k, "name", k))


# ---------------------------------------------------------------------------
# activation / batch / cache specs
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, rules: Rules, global_batch: int):
    """Largest subset of dp axes whose product divides global_batch."""
    return _fit(mesh, rules.dp, global_batch)


def batch_specs(cfg: ArchConfig, mesh: Mesh, rules: Rules, *, global_batch: int,
                with_positions: bool = True) -> dict:
    """Input shardings for a train/prefill batch dict."""
    ba = batch_axes(mesh, rules, global_batch)
    specs = {"labels": P(ba, None)}
    if cfg.input_mode == "tokens":
        specs["inputs"] = P(ba, None)
    else:
        specs["inputs"] = P(ba, None, None)
    if cfg.pos_embed == "mrope" and with_positions:
        specs["positions"] = P(ba, None, None)
    return specs


def _flat_axes(axes) -> tuple[str, ...]:
    if axes is None:
        return ()
    if isinstance(axes, str):
        return (axes,)
    return tuple(axes)


def kv_cache_spec(cfg: ArchConfig, mesh: Mesh, rules: Rules, *,
                  batch: int, n_stack: int = 1) -> P:
    """Spec for a stacked KV cache [stack.., B, S, Hkv, hd].

    Heads shard on tp when divisible; otherwise the sequence dim takes tp.
    Batch takes dp when divisible; otherwise sequence also absorbs dp.
    """
    ba = batch_axes(mesh, rules, batch)
    tp_on_heads = _fit(mesh, rules.tp, cfg.n_kv_heads)
    seq_axes: list[str] = []
    if ba is None:
        seq_axes.extend(_flat_axes(rules.dp))
    if tp_on_heads is None:
        seq_axes.extend(a for a in _flat_axes(rules.tp)
                        if a not in seq_axes)
    else:
        seq_axes.extend(a for a in _flat_axes(rules.tp)
                        if a not in _flat_axes(tp_on_heads)
                        and a not in seq_axes)
    seq = tuple(seq_axes) if seq_axes else None
    lead = (None,) * n_stack
    return P(*lead, ba, seq, tp_on_heads, None)


@dataclasses.dataclass(frozen=True)
class KVShardPlan:
    """Validated page-aligned sharding geometry for the paged KV pool.

    The pool's word axis is its sequence/page axis: word ``w`` belongs to
    page ``w // page_tokens`` and shard ``w // words_per_shard``. The plan
    guarantees every shard boundary is a page boundary, so a page (and
    therefore every word of a token's KV) lives on exactly one device and
    the host-side page tables stay replicated control plane.
    """
    n_shards: int
    n_pages: int
    page_tokens: int

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.n_pages % self.n_shards:
            raise ValueError(
                f"kv sharding is page-aligned: {self.n_pages} pages do not "
                f"divide across {self.n_shards} shards — round the pool up "
                f"to a whole number of pages per shard")

    @property
    def pages_per_shard(self) -> int:
        return self.n_pages // self.n_shards

    @property
    def words_per_shard(self) -> int:
        return self.pages_per_shard * self.page_tokens

    @property
    def num_words(self) -> int:
        return self.n_pages * self.page_tokens

    def shard_of_page(self, page: int) -> int:
        return page // self.pages_per_shard

    def shard_of_word(self, word: int) -> int:
        return word // self.words_per_shard


def kv_shard_plan(n_shards: int, *, n_pages: int,
                  page_tokens: int) -> KVShardPlan:
    """Page-aligned shard plan, rounding the pool UP to a whole number of
    pages per shard (extra capacity is harmless; a straddling page is not)."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    pages = -(-n_pages // n_shards) * n_shards
    return KVShardPlan(n_shards=n_shards, n_pages=pages,
                       page_tokens=page_tokens)


def shard_of_pages(plan: KVShardPlan, pages) -> int:
    """The ONE shard a page set lives on, raising when it spans several.

    Refcounted prefix sharing leans on this: shared pages pin to the shard
    where they were first written, a prefix chain therefore never crosses
    shards (each extension is carved from the attacher's home — the chain's
    shard — by construction), and an attaching sequence validates its
    adopted pages here before its home follows them. A multi-shard set is a
    bookkeeping corruption, not a capacity condition, hence ValueError
    rather than PoolCapacityError."""
    pages = list(pages)
    if not pages:
        raise ValueError("empty page set has no shard")
    shards = {plan.shard_of_page(int(p)) for p in pages}
    if len(shards) != 1:
        raise ValueError(
            f"page set {sorted(int(p) for p in pages)} spans shards "
            f"{sorted(shards)} — shared prefix pages must stay device-local")
    return shards.pop()


def kv_pool_spec(mesh: Mesh, *, num_words: int, page_tokens: int,
                 axis: str = "kv") -> P:
    """Spec for the paged pool storage ``[num_words, word_width]``: the word
    (= sequence/page) axis shards across ``axis`` with page-aligned
    boundaries. Raises when a shard boundary would straddle a page."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {axis!r} axis")
    n = int(mesh.shape[axis])
    if num_words % n:
        raise ValueError(
            f"pool of {num_words} words does not divide across the "
            f"{n}-way {axis!r} axis")
    if (num_words // n) % page_tokens:
        raise ValueError(
            f"shard boundary straddles a page: {num_words // n} words per "
            f"shard is not a multiple of page_tokens={page_tokens}")
    return P(axis, None)


def decode_state_pspecs(cfg: ArchConfig, mesh: Mesh, rules: Optional[Rules],
                        state: PyTree, *, batch: int) -> PyTree:
    """Spec tree for a decode state pytree (matches init_decode_state)."""
    r = rules or Rules.for_mesh(mesh)
    ba = batch_axes(mesh, r, batch)

    def assign(path_tuple, leaf):
        path = "/".join(_key_str(k) for k in path_tuple)
        nd = leaf.ndim
        if path == "len":
            return P(ba)
        if path in ("cache_k", "cache_v"):
            return kv_cache_spec(cfg, mesh, r, batch=batch, n_stack=1)
        if path in ("attn_k", "attn_v"):
            return kv_cache_spec(cfg, mesh, r, batch=batch, n_stack=1)
        if path.startswith(("tm_shift", "cm_shift")):    # [L, B, d]
            return P(None, ba, _fit(mesh, r.tp, leaf.shape[-1]))
        if path.startswith("tm_state"):                  # [L, B, H, K, V]
            return P(None, ba, _fit(mesh, r.tp, leaf.shape[2]), None, None)
        if path.startswith("conv/") or path.startswith("tail_conv/"):
            # [..., B, K-1, C]
            lead = nd - 3
            return P(*(None,) * lead, ba, None,
                     _fit(mesh, r.tp, leaf.shape[-1]))
        if path in ("ssm", "tail_ssm"):                  # [..., B, H, N, Phd]
            lead = nd - 4
            return P(*(None,) * lead, ba,
                     _fit(mesh, r.tp, leaf.shape[lead + 1]), None, None)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(assign, state)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
