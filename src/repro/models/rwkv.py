"""RWKV6 (Finch) block: time-mix with data-dependent decay + channel-mix.

Faithful to the Finch recurrence (per-channel data-dependent decay w_t and
current-token bonus u), executed by the shared chunked linear-attention
engine. Simplification vs the released model (noted in DESIGN.md): the
token-shift interpolation uses static per-channel mix coefficients
(RWKV5-style lerp) rather than the data-dependent ddlerp; the decay itself
keeps the full data-dependent LoRA, which is the architectural hallmark.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RwkvConfig
from repro.models import layers as L
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step


def time_mix_init(key, d: int, cfg: RwkvConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 9)
    n_heads = d // cfg.head_dim
    return {
        "mix": L.normal_init(ks[0], (5, d), dtype, 0.02),      # r,k,v,w,g lerps
        "wr": L.linear_init(ks[1], d, d, dtype=dtype),
        "wk": L.linear_init(ks[2], d, d, dtype=dtype),
        "wv": L.linear_init(ks[3], d, d, dtype=dtype),
        "wg": L.linear_init(ks[4], d, d, dtype=dtype),
        "wo": L.linear_init(ks[5], d, d, dtype=dtype),
        "w0": L.normal_init(ks[6], (d,), dtype, 0.5) - 6.0,    # decay bias
        "w_lora_a": L.fan_in_init(ks[7], (d, cfg.lora_dim), dtype),
        "w_lora_b": L.normal_init(ks[8], (cfg.lora_dim, d), dtype, 0.02),
        "u": L.normal_init(ks[0], (n_heads, cfg.head_dim), dtype, 0.02),
        "ln_scale": jnp.ones((n_heads, cfg.head_dim), dtype),  # per-head norm
    }


def _shift(x: jax.Array, prev: jax.Array | None) -> jax.Array:
    """Token shift: y_t = x_{t-1}; position 0 takes ``prev`` (or zeros)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _mix(x, xx, m):
    return x + (xx - x) * m.astype(x.dtype)


def _log_decay(p, xw):
    """Data-dependent per-channel log decay, <= 0 (Finch)."""
    f32 = jnp.float32
    lora = jnp.tanh(xw.astype(f32) @ p["w_lora_a"].astype(f32)) @ p["w_lora_b"].astype(f32)
    return -jnp.exp(p["w0"].astype(f32) + lora)            # [B,T,d] (or [B,d])


def time_mix_apply(p: dict, x: jax.Array, cfg: RwkvConfig, *, la_chunk: int = 64,
                   compute_dtype=None, shift_state=None, ssm_state=None,
                   return_state: bool = False):
    """x: [B, T, d]. Optional decode-style carried states."""
    b, t, d = x.shape
    h, hd = d // cfg.head_dim, cfg.head_dim
    xx = _shift(x, shift_state)
    xr, xk, xv, xw, xg = (_mix(x, xx, p["mix"][i]) for i in range(5))

    r = L.linear(p["wr"], xr, compute_dtype).reshape(b, t, h, hd)
    k = L.linear(p["wk"], xk, compute_dtype).reshape(b, t, h, hd)
    v = L.linear(p["wv"], xv, compute_dtype).reshape(b, t, h, hd)
    g = L.linear(p["wg"], xg, compute_dtype)
    lw = _log_decay(p, xw).reshape(b, t, h, hd)

    y, final_state = chunked_linear_attention(
        r, k, v, lw, chunk=la_chunk, bonus_u=p["u"], initial_state=ssm_state)

    # per-head normalization (GroupNorm analogue)
    f32 = jnp.float32
    yf = y.astype(f32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * p["ln_scale"].astype(f32)
    y = (yf.reshape(b, t, d) * L.silu(g).astype(f32)).astype(x.dtype)
    out = L.linear(p["wo"], y, compute_dtype)
    if return_state:
        return out, x[:, -1], final_state
    return out


def time_mix_step(p: dict, x: jax.Array, cfg: RwkvConfig, *, shift_state,
                  ssm_state, compute_dtype=None):
    """One token. x: [B, 1, d]; shift_state: [B, d]; ssm_state: [B,H,K,V]."""
    b, _, d = x.shape
    h, hd = d // cfg.head_dim, cfg.head_dim
    x0 = x[:, 0]
    xx = shift_state.astype(x0.dtype)
    xr, xk, xv, xw, xg = (_mix(x0, xx, p["mix"][i]) for i in range(5))

    r = L.linear(p["wr"], xr, compute_dtype).reshape(b, h, hd)
    k = L.linear(p["wk"], xk, compute_dtype).reshape(b, h, hd)
    v = L.linear(p["wv"], xv, compute_dtype).reshape(b, h, hd)
    g = L.linear(p["wg"], xg, compute_dtype)
    lw = _log_decay(p, xw).reshape(b, h, hd)

    y, new_state = linear_attention_step(r, k, v, lw, ssm_state, bonus_u=p["u"])
    f32 = jnp.float32
    yf = y.astype(f32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * p["ln_scale"].astype(f32)
    y = (yf.reshape(b, d) * L.silu(g).astype(f32)).astype(x.dtype)
    out = L.linear(p["wo"], y, compute_dtype)[:, None]
    return out, x0, new_state


def channel_mix_init(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "mix": L.normal_init(ks[0], (2, d), dtype, 0.02),
        "wk": L.linear_init(ks[1], d, d_ff, dtype=dtype),
        "wv": L.linear_init(ks[2], d_ff, d, dtype=dtype),
        "wr": L.linear_init(ks[0], d, d, dtype=dtype),
    }


def channel_mix_apply(p: dict, x: jax.Array, *, compute_dtype=None,
                      shift_state=None, return_state: bool = False):
    xx = _shift(x, shift_state)
    xk = _mix(x, xx, p["mix"][0])
    xr = _mix(x, xx, p["mix"][1])
    k = jnp.square(jax.nn.relu(L.linear(p["wk"], xk, compute_dtype)))
    out = jax.nn.sigmoid(L.linear(p["wr"], xr, compute_dtype)) * \
        L.linear(p["wv"], k, compute_dtype)
    if return_state:
        return out, x[:, -1]
    return out


def channel_mix_step(p: dict, x: jax.Array, *, shift_state, compute_dtype=None):
    x0 = x[:, 0]
    xx = shift_state.astype(x0.dtype)
    xk = _mix(x0, xx, p["mix"][0])
    xr = _mix(x0, xx, p["mix"][1])
    k = jnp.square(jax.nn.relu(L.linear(p["wk"], xk, compute_dtype)))
    out = jax.nn.sigmoid(L.linear(p["wr"], xr, compute_dtype)) * \
        L.linear(p["wv"], k, compute_dtype)
    return out[:, None], x0
