"""Chunked decayed linear attention — the shared engine for Mamba2 and RWKV6.

Both SSM families obey the same recurrence over a matrix state S in R^{K x V}:

    S_t = diag(w_t) @ S_{t-1} + k_t v_t^T                  (w_t in (0,1]^K)
    y_t = q_t^T @ S_t                      (Mamba2: q=C, k=dt*B, v=x, w=exp(dt*A) per head)
    y_t = q_t^T @ (S_{t-1} + diag(u) k_t v_t^T)   (RWKV6: q=r, bonus u, per-channel decay)

A per-timestep scan is MXU-hostile; the TPU-native form processes chunks of Q
steps with intra-chunk matmuls and carries the matrix state across chunks (the
SSD block decomposition of Dao & Gu, generalized to per-channel decay so one
routine serves both architectures).

Numerical note: the intra-chunk pairwise decay exp(cum_i - cum_j) is computed
directly (masked to i >= j where it is <= 1) — exact and overflow-free, unlike
the exp(cum)*exp(-cum) factorization. Its [Q, Q, H, K] footprint is bounded by
keeping the chunk inside the inter-chunk ``lax.scan`` body, so peak memory is
one chunk's tensor, not the whole sequence's.

Shapes: q, k, log_w: [B, T, H, K]; v: [B, T, H, V]. Returns y: [B, T, H, V]
and the final state [B, H, K, V]. ``log_w`` is log-decay (<= 0), applied to
the state *before* absorbing step t's outer product.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             log_w: jax.Array, *, chunk: int = 64,
                             bonus_u: jax.Array | None = None,
                             initial_state: jax.Array | None = None,
                             scalar_decay: bool = False,
                             ) -> tuple[jax.Array, jax.Array]:
    """Run the decayed linear-attention recurrence in chunked form.

    Args:
      q, k: [B, T, H, K]; v: [B, T, H, V]; log_w: [B, T, H, K], or [B, T, H]
        when ``scalar_decay`` (one decay per head per step — Mamba2/SSD).
      chunk: intra-chunk length (MXU tile-friendly; 32-128).
      bonus_u: optional [H, K] RWKV-style current-token bonus. When given,
        y_t reads S_{t-1} plus diag(u) k_t v_t^T (RWKV6 semantics: strictly
        causal intra-chunk, j < i); when None, y_t reads S_t (Mamba2, j <= i).
      initial_state: optional [B, H, K, V].
      scalar_decay: per-head scalar decay fast path (§Perf iteration 1):
        the intra-chunk pairwise-decay tensor is [B, Q, Q, H] instead of
        [B, Q, Q, H, K] and the score contraction is a single K-contraction
        matmul — K-fold less traffic for Mamba2's K = state_dim = 64.

    Returns:
      (y [B, T, H, V], final_state [B, H, K, V])
    """
    if scalar_decay:
        return _chunked_scalar_decay(q, k, v, log_w, chunk=chunk,
                                     initial_state=initial_state)
    b, t, h, kdim = q.shape
    vdim = v.shape[-1]
    out_dtype = v.dtype
    orig_t = t
    if t % chunk:
        pad = chunk - t % chunk
        zpad = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v, log_w = zpad(q), zpad(k), zpad(v), zpad(log_w)
        t = q.shape[1]
    n_chunks = t // chunk

    f32 = jnp.float32
    # [N, B, Q, H, *] — chunk axis leads for the scan. Operands keep their
    # input dtype (bf16 in production); only decay math runs in f32 and
    # matmuls accumulate f32 via preferred_element_type (§Perf).
    def to_chunks(x, last, dt=None):
        r = jnp.moveaxis(x.reshape(b, n_chunks, chunk, h, last), 1, 0)
        return r.astype(dt) if dt is not None else r

    qc, kc = to_chunks(q, kdim), to_chunks(k, kdim)
    vc = to_chunks(v, vdim)
    lw = to_chunks(log_w, kdim, f32)

    idx = jnp.arange(chunk)
    strict = bonus_u is not None
    causal = (idx[:, None] > idx[None, :]) if strict else (idx[:, None] >= idx[None, :])
    u = None if bonus_u is None else bonus_u.astype(f32)

    if initial_state is None:
        init = jnp.zeros((b, h, kdim, vdim), f32)
    else:
        init = initial_state.astype(f32)

    def body(state, xs):
        qn, kn, vn, lwn = xs                           # [B,Q,H,K]/[B,Q,H,V]
        dt = qn.dtype
        cum = jnp.cumsum(lwn, axis=1)                  # [B,Q,H,K] inclusive of i
        total = cum[:, -1]                             # [B,H,K]
        # Read-side exponent: Mamba2 reads S_i (inclusive decay); RWKV6 reads
        # S_{i-1}, i.e. the exclusive cumsum (one fewer decay factor).
        cum_read = cum - lwn if strict else cum

        # inter-chunk: y_i += (q_i * exp(cum_read_i)) @ S_prev
        # (qd promotes to f32; the big f32 state is consumed untouched)
        qd = qn * jnp.exp(cum_read)
        y_inter = jnp.einsum("bihk,bhkv->bihv", qd, state,
                             preferred_element_type=f32)

        # intra-chunk: s_ij = sum_K q_i k_j exp(cum_read_i - cum_j), i (>=|>) j
        diff = cum_read[:, :, None] - cum[:, None, :]  # [B,Qi,Qj,H,K]
        diff = jnp.where(causal[None, :, :, None, None], diff, -jnp.inf)
        s = jnp.einsum("bihk,bijhk,bjhk->bijh", qn.astype(f32),
                       jnp.exp(diff), kn.astype(f32))
        y_intra = jnp.einsum("bijh,bjhv->bihv", s.astype(dt), vn,
                             preferred_element_type=f32)
        if u is not None:
            yb = jnp.einsum("bihk,hk,bihk->bih", qn.astype(f32), u,
                            kn.astype(f32))
            y_intra = y_intra + yb[..., None] * vn.astype(f32)

        # chunk summary: S_chunk = sum_j diag(exp(total - cum_j)) k_j v_j^T
        kdec = kn * jnp.exp(total[:, None] - cum)              # f32 [B,Q,H,K]
        s_chunk = jnp.einsum("bjhk,bjhv->bhkv", kdec, vn,
                             preferred_element_type=f32)

        new_state = state * jnp.exp(total)[..., None] + s_chunk
        return new_state, (y_intra + y_inter).astype(out_dtype)

    final_state, ys = jax.lax.scan(body, init, (qc, kc, vc, lw))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, vdim)
    return y[:, :orig_t], final_state


def _chunked_scalar_decay(q: jax.Array, k: jax.Array, v: jax.Array,
                          log_w: jax.Array, *, chunk: int,
                          initial_state: jax.Array | None
                          ) -> tuple[jax.Array, jax.Array]:
    """SSD fast path: decay is scalar per (step, head); log_w: [B, T, H]."""
    b, t, h, kdim = q.shape
    vdim = v.shape[-1]
    out_dtype = v.dtype
    orig_t = t
    if t % chunk:
        pad = chunk - t % chunk
        zpad4 = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zpad4(q), zpad4(k), zpad4(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0)))
        t = q.shape[1]
    n_chunks = t // chunk

    f32 = jnp.float32
    def to_chunks(x, last):
        return jnp.moveaxis(x.reshape(b, n_chunks, chunk, h, last), 1, 0)
    qc, kc = to_chunks(q, kdim), to_chunks(k, kdim)
    vc = to_chunks(v, vdim)
    lw = jnp.moveaxis(log_w.reshape(b, n_chunks, chunk, h), 1, 0).astype(f32)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]

    init = (jnp.zeros((b, h, kdim, vdim), f32) if initial_state is None
            else initial_state.astype(f32))

    def body(state, xs):
        qn, kn, vn, lwn = xs                           # [B,Q,H,*] / [B,Q,H]
        dt = qn.dtype
        cum = jnp.cumsum(lwn, axis=1)                  # [B,Q,H]
        total = cum[:, -1]                             # [B,H]

        # inter-chunk: y_i += (q_i * exp(cum_i)) @ S_prev
        qd = qn * jnp.exp(cum)[..., None]                      # promotes f32
        y_inter = jnp.einsum("bihk,bhkv->bihv", qd, state,
                             preferred_element_type=f32)

        # intra-chunk: s_ij = (q_i . k_j) * exp(cum_i - cum_j), i >= j
        dots = jnp.einsum("bihk,bjhk->bijh", qn, kn,
                          preferred_element_type=f32)  # one K-contraction
        diff = cum[:, :, None] - cum[:, None, :]       # [B,Qi,Qj,H]
        diff = jnp.where(causal[None, :, :, None], diff, -jnp.inf)
        s = (dots * jnp.exp(diff)).astype(dt)
        y_intra = jnp.einsum("bijh,bjhv->bihv", s, vn,
                             preferred_element_type=f32)

        # chunk summary + state update
        kdec = kn * jnp.exp(total[:, None] - cum)[..., None]   # f32
        s_chunk = jnp.einsum("bjhk,bjhv->bhkv", kdec, vn,
                             preferred_element_type=f32)
        new_state = state * jnp.exp(total)[..., None, None] + s_chunk
        return new_state, (y_intra + y_inter).astype(out_dtype)

    final_state, ys = jax.lax.scan(body, init, (qc, kc, vc, lw))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, vdim)
    return y[:, :orig_t], final_state


def linear_attention_step(q: jax.Array, k: jax.Array, v: jax.Array,
                          log_w: jax.Array, state: jax.Array, *,
                          bonus_u: jax.Array | None = None
                          ) -> tuple[jax.Array, jax.Array]:
    """Single decode step of the same recurrence.

    q, k, log_w: [B, H, K]; v: [B, H, V]; state: [B, H, K, V].
    Returns (y [B, H, V], new_state [B, H, K, V] in f32).
    """
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    w = jnp.exp(log_w.astype(f32))
    outer = kf[..., :, None] * vf[..., None, :]        # [B,H,K,V]
    if bonus_u is not None:
        read = state + bonus_u.astype(f32)[..., :, None] * outer
        new_state = state * w[..., None] + outer
    else:
        new_state = state * w[..., None] + outer
        read = new_state
    y = jnp.einsum("bhk,bhkv->bhv", qf, read)
    return y.astype(v.dtype), new_state
