"""Shared neural-net layers (no external NN library; pure functional pytrees).

Every layer is an (init, apply) pair: ``init`` returns a nested-dict pytree of
arrays, ``apply`` is pure. Parameter dtype and compute dtype are decoupled
(params usually f32 on CPU tests, bf16 in production configs).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev: float):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def fan_in_init(key, shape, dtype, fan_in: int | None = None):
    fan = fan_in if fan_in is not None else shape[0]
    return normal_init(key, shape, dtype, 1.0 / math.sqrt(fan))


# --------------------------------------------------------------------------
# linear / embedding / norm
# --------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32) -> dict:
    p = {"w": fan_in_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: dict, x: jax.Array, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
        x = x.astype(compute_dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"w": normal_init(key, (vocab, d), dtype, 1.0)}


def embedding_lookup(p: dict, ids: jax.Array, compute_dtype=None) -> jax.Array:
    w = p["w"]
    if compute_dtype is not None:
        w = w.astype(compute_dtype)
    return jnp.take(w, ids, axis=0)


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL multimodal M-RoPE)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """[head_dim//2] inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_apply(x: jax.Array, positions: jax.Array, theta: float = 10000.0
               ) -> jax.Array:
    """Apply rotary embedding.

    x: [..., S, n_heads, head_dim]; positions: broadcastable to [..., S].
    """
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                   # [half]
    ang = positions[..., None].astype(jnp.float32) * freqs         # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]                               # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def mrope_apply(x: jax.Array, positions: jax.Array,
                sections: Sequence[int], theta: float = 10000.0) -> jax.Array:
    """Qwen2-VL M-RoPE: the rotary pairs are split into (t, h, w) sections,
    each driven by its own position stream.

    x: [..., S, n_heads, head_dim]; positions: [..., S, 3] (t, h, w indices);
    sections: pair counts per stream, sum == head_dim // 2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)                   # [half]
    # Build the per-pair position by section.
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)  # [half]
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),                              # [..., S, 3]
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1)                                                    # [..., S, half]
    ang = pos * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int,
                         offset: jax.Array | int = 0) -> jax.Array:
    """MusicGen-style absolute sinusoidal position embeddings [S, d]."""
    pos = (jnp.arange(seq_len) + offset)[:, None].astype(jnp.float32)
    half = d_model // 2
    div = jnp.exp(jnp.arange(half, dtype=jnp.float32) * (-math.log(10000.0) / half))
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return silu(gate) * up
