"""Model zoo: scan-based decoder families (dense/GQA, MoE, Mamba2 hybrid,
RWKV6) with train / prefill / decode entry points in model.py."""
from repro.models.model import (decode_step, forward, init_decode_state,
                                init_params, loss_fn, prefill, prefill_chunk)

__all__ = ["init_params", "forward", "loss_fn", "init_decode_state",
           "decode_step", "prefill", "prefill_chunk"]
