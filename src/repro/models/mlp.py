"""Feed-forward blocks: SwiGLU (llama family) and the MoE layer.

MoE uses sort-based token dispatch (Megablocks-style): tokens are sorted by
destination expert, scattered into per-expert capacity slots, run through a
batched expert matmul, and combined back with router weights. This is the
scalable formulation — the [tokens, experts, capacity] one-hot dispatch tensor
of GShard never materializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers as L


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": L.linear_init(ks[0], d_model, d_ff, dtype=dtype),
        "w_up": L.linear_init(ks[1], d_model, d_ff, dtype=dtype),
        "w_down": L.linear_init(ks[2], d_ff, d_model, dtype=dtype),
    }


def swiglu_apply(p: dict, x: jax.Array, compute_dtype=None) -> jax.Array:
    g = L.linear(p["w_gate"], x, compute_dtype)
    u = L.linear(p["w_up"], x, compute_dtype)
    return L.linear(p["w_down"], L.swiglu(g, u), compute_dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, f = cfg.n_experts, cfg.d_expert
    p = {
        "router": {"w": L.normal_init(ks[0], (d_model, e), dtype, 0.02)},
        "w_gate": L.fan_in_init(ks[1], (e, d_model, f), dtype, fan_in=d_model),
        "w_up": L.fan_in_init(ks[2], (e, d_model, f), dtype, fan_in=d_model),
        "w_down": L.fan_in_init(ks[3], (e, f, d_model), dtype, fan_in=f),
    }
    if cfg.n_shared:
        p["shared"] = swiglu_init(ks[4], d_model, cfg.n_shared * f, dtype=dtype)
    return p


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, compute_dtype=None
              ) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], router aux loss scalar).

    Dispatch is ROW-LOCAL (§Perf iteration 5): sorting/position-ranking and
    the staging scatter all happen within each batch row, so token tensors
    never cross data-parallel shards — the only dispatch collective left is
    the canonical token->expert all-to-all that materializes the staging
    buffer [B, E, C, d] with E on the model axis (hinted "moe_buf").
    """
    from repro.distributed.api import hint

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = s * k                                                  # slots per row
    xf = x
    if compute_dtype is not None:
        xf = xf.astype(compute_dtype)

    # --- routing (row-local) -------------------------------------------------
    logits = L.linear(p["router"], xf, jnp.float32)            # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, k)                   # [B, S, k]
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=(0, 1))                               # [E]
    onehot_counts = jnp.sum(
        jax.nn.one_hot(gate_e, e, dtype=jnp.float32), axis=(0, 1, 2))
    ce = onehot_counts / (b * n)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)

    # --- row-local sort-based dispatch ----------------------------------------
    capacity = int(max(1, round(n / e * cfg.capacity_factor)))
    fe = gate_e.reshape(b, n)                                  # expert per slot
    ft = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(n)
    fw = gate_w.reshape(b, n)

    order = jnp.argsort(fe, axis=-1, stable=True)              # per-row sort
    se = jnp.take_along_axis(fe, order, axis=-1)               # [B, n]
    st = ft[order]                                             # [B, n]
    sw = jnp.take_along_axis(fw, order, axis=-1)
    # rank within the row's expert group via exclusive running counts
    counts = jnp.sum(jax.nn.one_hot(se, e, dtype=jnp.int32), axis=1)  # [B, E]
    start = jnp.cumsum(counts, axis=-1) - counts               # [B, E]
    pos = jnp.arange(n)[None, :] - jnp.take_along_axis(start, se, axis=-1)
    keep = pos < capacity

    rows = jnp.arange(b)[:, None]
    e_idx = jnp.where(keep, se, e)                             # OOB drop
    p_idx = jnp.where(keep, pos, 0)
    tok = jnp.take_along_axis(xf, st[..., None], axis=1)       # [B, n, d]

    buf = jnp.zeros((b, e, capacity, d), xf.dtype)
    buf = buf.at[rows, e_idx, p_idx].set(tok, mode="drop")
    buf = hint(buf, "moe_buf")                                 # [B, E(model), C, d]

    # --- batched expert FFN ----------------------------------------------------
    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if compute_dtype is not None:
        wg, wu, wd = (w.astype(compute_dtype) for w in (wg, wu, wd))
    hg = jnp.einsum("becd,edf->becf", buf, wg)
    hu = jnp.einsum("becd,edf->becf", buf, wu)
    h = L.swiglu(hg, hu)
    out_buf = jnp.einsum("becf,efd->becd", h, wd)              # [B, E, C, d]
    # de-shard before the combine gather: a gather INTO a model-sharded dim
    # differentiates into a scatter-add that XLA lowers densely (refuted
    # variant in §Perf); replicating out_buf costs one all-gather and keeps
    # both the gather and its backward local.
    out_buf = hint(out_buf, "moe_buf")

    # --- combine (the MoE "read port", row-local) -----------------------------
    y_sorted = out_buf[rows, e_idx, p_idx]                     # [B, n, d]
    y_sorted = jnp.where(keep[..., None], y_sorted, 0)
    y_sorted = y_sorted * sw[..., None].astype(y_sorted.dtype)
    inv = jnp.argsort(order, axis=-1)                          # undo the sort
    y = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)  # slot order
    out = y.reshape(b, s, k, d).sum(axis=2)                    # k experts/token

    if "shared" in p:
        out = out + swiglu_apply(p["shared"], xf, compute_dtype)
    return out.astype(x.dtype), aux
