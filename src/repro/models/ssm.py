"""Mamba2 block (SSD form) — used standalone and inside the Zamba2 hybrid.

Structure (faithful to Mamba2, n_groups=1):
  projections: d -> z (d_in), x (d_in), B (N), C (N), dt (nheads)
  depthwise causal conv (kernel 4) over x, B, C channels
  SSD recurrence with scalar-per-head decay a_t = exp(-dt * exp(A_log)),
  executed by the shared chunked linear-attention engine (linear_scan.py)
  skip: y += D * x;  gate: y = rmsnorm(y * silu(z));  out_proj: d_in -> d

Sharding note: the reference implementation fuses z|x|B|C|dt into one
in_proj and one conv; we keep them as separate parameters so each can carry
its own PartitionSpec (x tensor-parallel, B/C replicated) — slicing a
TP-sharded concat would force a reshard at every layer (DESIGN.md §Perf).
Functionally identical.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import layers as L
from repro.models.linear_scan import chunked_linear_attention, linear_attention_step


def _dims(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    nheads = d_in // cfg.head_dim
    return d_in, nheads


def mamba2_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    d_in, nheads = _dims(d_model, cfg)
    ks = jax.random.split(key, 8)
    u = jax.random.uniform(ks[6], (nheads,))
    dt = jnp.exp(u * (math.log(cfg.dt_max) - math.log(cfg.dt_min))
                 + math.log(cfg.dt_min))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))        # softplus^-1(dt)
    ck = cfg.conv_kernel
    conv_scale = 1.0 / math.sqrt(ck)
    return {
        "in_z": L.linear_init(ks[0], d_model, d_in, dtype=dtype),
        "in_x": L.linear_init(ks[1], d_model, d_in, dtype=dtype),
        "in_B": L.linear_init(ks[2], d_model, cfg.state_dim, dtype=dtype),
        "in_C": L.linear_init(ks[3], d_model, cfg.state_dim, dtype=dtype),
        "in_dt": L.linear_init(ks[4], d_model, nheads, dtype=dtype),
        "conv_x": {"w": L.normal_init(ks[5], (ck, d_in), dtype, conv_scale),
                   "b": jnp.zeros((d_in,), dtype)},
        "conv_B": {"w": L.normal_init(ks[7], (ck, cfg.state_dim), dtype, conv_scale),
                   "b": jnp.zeros((cfg.state_dim,), dtype)},
        "conv_C": {"w": L.normal_init(ks[6], (ck, cfg.state_dim), dtype, conv_scale),
                   "b": jnp.zeros((cfg.state_dim,), dtype)},
        "dt_bias": dt_bias.astype(dtype),
        "a_log": jnp.zeros((nheads,), dtype),      # A = -exp(a_log) = -1
        "d_skip": jnp.ones((nheads,), dtype),
        "norm": L.rmsnorm_init(d_in, dtype),
        "out_proj": L.linear_init(ks[4], d_in, d_model, dtype=dtype),
    }


def _conv(p: dict, x: jax.Array, state: jax.Array | None):
    """Depthwise causal conv. x: [B, T, C]; p['w']: [K, C].
    Returns (silu(conv(x)) [B,T,C], new_state [B,K-1,C])."""
    w = p["w"].astype(x.dtype)
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # [B, T+K-1, C]
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(k))
    y = y + p["b"].astype(x.dtype)
    return L.silu(y), xp[:, -(k - 1):]


def mamba2_apply(p: dict, x: jax.Array, d_model: int, cfg: SSMConfig, *,
                 la_chunk: int = 64, compute_dtype=None,
                 conv_state: dict | None = None,
                 ssm_state: jax.Array | None = None,
                 return_state: bool = False):
    """Full-sequence Mamba2. x: [B, T, d]. conv_state: {"x","B","C"} or None."""
    b, t, _ = x.shape
    d_in, nheads = _dims(d_model, cfg)
    z = L.linear(p["in_z"], x, compute_dtype)
    xi = L.linear(p["in_x"], x, compute_dtype)
    bi = L.linear(p["in_B"], x, compute_dtype)
    ci = L.linear(p["in_C"], x, compute_dtype)
    dt = L.linear(p["in_dt"], x, compute_dtype)

    cs = conv_state or {"x": None, "B": None, "C": None}
    xi, ncx = _conv(p["conv_x"], xi, cs["x"])
    bi, ncb = _conv(p["conv_B"], bi, cs["B"])
    ci, ncc = _conv(p["conv_C"], ci, cs["C"])

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,T,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # [H]
    log_w = dt * a[None, None, :]                                 # [B,T,H]

    xh = xi.reshape(b, t, nheads, cfg.head_dim)
    v = xh * dt[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(bi[:, :, None, :], (b, t, nheads, cfg.state_dim))
    q = jnp.broadcast_to(ci[:, :, None, :], (b, t, nheads, cfg.state_dim))

    y, final_state = chunked_linear_attention(
        q, k, v, log_w, chunk=la_chunk, initial_state=ssm_state,
        scalar_decay=True)
    y = y + xh * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, t, d_in)
    y = L.rmsnorm(p["norm"], y * L.silu(z))
    out = L.linear(p["out_proj"], y, compute_dtype)
    if return_state:
        return out, {"x": ncx, "B": ncb, "C": ncc}, final_state
    return out


def mamba2_decode_step(p: dict, x: jax.Array, d_model: int, cfg: SSMConfig, *,
                       conv_state: dict, ssm_state: jax.Array,
                       compute_dtype=None):
    """One token. x: [B, 1, d]; conv_state: {"x","B","C"} each [B, K-1, C];
    ssm_state: [B, H, N, P]. Returns (out [B,1,d], conv_state', ssm_state')."""
    b = x.shape[0]
    d_in, nheads = _dims(d_model, cfg)
    z = L.linear(p["in_z"], x, compute_dtype)
    xi = L.linear(p["in_x"], x, compute_dtype)
    bi = L.linear(p["in_B"], x, compute_dtype)
    ci = L.linear(p["in_C"], x, compute_dtype)
    dt = L.linear(p["in_dt"], x, compute_dtype)

    xi, ncx = _conv(p["conv_x"], xi, conv_state["x"])
    bi, ncb = _conv(p["conv_B"], bi, conv_state["B"])
    ci, ncc = _conv(p["conv_C"], ci, conv_state["C"])
    xi, bi, ci = xi[:, 0], bi[:, 0], ci[:, 0]

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # [B,H]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    log_w = jnp.broadcast_to((dt * a[None, :])[..., None],
                             (b, nheads, cfg.state_dim))

    xh = xi.reshape(b, nheads, cfg.head_dim)
    v = xh * dt[..., None].astype(xh.dtype)
    k = jnp.broadcast_to(bi[:, None, :], (b, nheads, cfg.state_dim))
    q = jnp.broadcast_to(ci[:, None, :], (b, nheads, cfg.state_dim))

    y, new_ssm = linear_attention_step(q, k, v, log_w, ssm_state)
    y = y + xh * p["d_skip"].astype(y.dtype)[None, :, None]
    y = y.reshape(b, 1, d_in)
    y = L.rmsnorm(p["norm"], y * L.silu(z))
    out = L.linear(p["out_proj"], y, compute_dtype)
    return out, {"x": ncx, "B": ncb, "C": ncc}, new_ssm
