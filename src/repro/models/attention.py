"""GQA attention: training (q-chunked causal), prefill (cache write) and
decode (multi-port fused append+attend or two-pass baseline).

The decode path is where the paper's technique lands end-to-end: the KV cache
is a multi-port memory; ``decode_step`` services the write port (append) and
the read port (attend) in one logical traversal. ``kernel_mode`` selects:

  * "reference"  — two-pass jnp (the single-port baseline; always shardable)
  * "multiport"  — the fused Pallas kernel (TPU target; interpret on CPU)
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.models import layers as L


def attention_init(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, *, qkv_bias: bool = False,
                   dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": L.linear_init(ks[0], d_model, n_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wk": L.linear_init(ks[1], d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wv": L.linear_init(ks[2], d_model, n_kv_heads * head_dim, bias=qkv_bias, dtype=dtype),
        "wo": L.linear_init(ks[3], n_heads * head_dim, d_model, bias=False, dtype=dtype),
    }


def _project_qkv(p: dict, x: jax.Array, n_heads: int, n_kv_heads: int,
                 head_dim: int, compute_dtype):
    b, s, _ = x.shape
    q = L.linear(p["wq"], x, compute_dtype).reshape(b, s, n_heads, head_dim)
    k = L.linear(p["wk"], x, compute_dtype).reshape(b, s, n_kv_heads, head_dim)
    v = L.linear(p["wv"], x, compute_dtype).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def _apply_pos(q, k, positions, pos_embed: str, rope_theta: float,
               mrope_sections):
    if pos_embed == "rope":
        q = L.rope_apply(q, positions, rope_theta)
        k = L.rope_apply(k, positions, rope_theta)
    elif pos_embed == "mrope":
        q = L.mrope_apply(q, positions, mrope_sections, rope_theta)
        k = L.mrope_apply(k, positions, mrope_sections, rope_theta)
    # "none"/"sinusoidal": absolute embeddings are added at the stem.
    return q, k


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             *, q_chunk: int = 1024) -> jax.Array:
    """Causal GQA attention, scanned over query chunks.

    Memory is O(B * H * q_chunk * S) instead of O(B * H * S^2); FLOPs are
    unchanged. q: [B, S, H, D]; k, v: [B, S, Hkv, D]. Returns [B, S, H, D].
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    q_chunk = min(q_chunk, s)
    assert s % q_chunk == 0, (s, q_chunk)
    n = s // q_chunk
    scale = 1.0 / (d ** 0.5)

    # bf16 operands + f32 accumulation (MXU-native): no f32 copies of K/V
    # are materialized (§Perf: halves the attention read traffic vs casting).
    f32 = jnp.float32
    qg = jnp.moveaxis(q.reshape(b, n, q_chunk, hkv, g, d), 1, 0)     # [N,B,C,Hkv,G,D]
    kpos = jnp.arange(s)

    def body(_, xs):
        qc, idx = xs                                   # [B,C,Hkv,G,D], scalar
        qpos = idx * q_chunk + jnp.arange(q_chunk)
        sc = jnp.einsum("bchgd,bshd->bchgs", qc, k,
                        preferred_element_type=f32) * scale
        mask = (qpos[:, None] >= kpos[None, :])[None, :, None, None, :]
        sc = jnp.where(mask, sc, -jnp.inf)
        pr = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        oc = jnp.einsum("bchgs,bshd->bchgd", pr, v,
                        preferred_element_type=f32)
        return None, oc.astype(q.dtype)

    _, out = jax.lax.scan(body, None, (qg, jnp.arange(n)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, hkv, g, d)
    return out.reshape(b, s, h, d)


def attention_train(p: dict, x: jax.Array, positions: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    pos_embed: str = "rope", rope_theta: float = 10000.0,
                    mrope_sections=(16, 24, 24), q_chunk: int = 1024,
                    compute_dtype=None) -> jax.Array:
    """Full-sequence causal attention (training / prefill compute)."""
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, compute_dtype)
    q, k = _apply_pos(q, k, positions, pos_embed, rope_theta, mrope_sections)
    out = chunked_causal_attention(q, k, v, q_chunk=q_chunk)
    b, s = x.shape[:2]
    return L.linear(p["wo"], out.reshape(b, s, n_heads * head_dim), compute_dtype)


def attention_prefill(p: dict, x: jax.Array, positions: jax.Array,
                      cache_k: jax.Array, cache_v: jax.Array, *,
                      n_heads: int, n_kv_heads: int, head_dim: int,
                      pos_embed: str = "rope", rope_theta: float = 10000.0,
                      mrope_sections=(16, 24, 24), q_chunk: int = 1024,
                      compute_dtype=None):
    """Prefill: attend causally over the prompt AND populate the KV cache.

    cache_k/v: [B, S_max, Hkv, D] with S_max >= S. Returns (out, k', v').
    """
    b, s = x.shape[:2]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, compute_dtype)
    q, k = _apply_pos(q, k, positions, pos_embed, rope_theta, mrope_sections)
    out = chunked_causal_attention(q, k, v, q_chunk=q_chunk)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, 0, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, 0, 0, 0))
    out = L.linear(p["wo"], out.reshape(b, s, n_heads * head_dim), compute_dtype)
    return out, cache_k, cache_v


def attention_prefill_chunk(p: dict, x: jax.Array, offset: jax.Array,
                            chunk_len: jax.Array, cache_k: jax.Array,
                            cache_v: jax.Array, *,
                            n_heads: int, n_kv_heads: int, head_dim: int,
                            pos_embed: str = "rope",
                            rope_theta: float = 10000.0,
                            mrope_sections=(16, 24, 24),
                            kernel_mode: Literal["reference", "multiport"] = "reference",
                            seq_tile: int = 128,
                            dynamic_grid: bool = False,
                            interpret: bool = True,
                            mesh=None, mesh_axis: str = "kv",
                            port_mix: str = "wr",
                            compute_dtype=None):
    """One fixed-size prompt chunk per sequence, mid-prefill.

    The chunked-prefill analogue of the multi-port decode step: the cache is
    serviced as a 2-port memory — the W port scatters the chunk's K,V at
    positions [offset, offset+chunk_len) and the R port attends causally over
    everything cached so far INCLUDING the just-written chunk (same-cycle
    W->R visibility, exactly the FSM's priority order). ``kernel_mode``
    selects the fused length-bounded Pallas traversal (``"multiport"``, tiles
    [0, ceil((offset+chunk_len)/seq_tile)) only) or the two-pass jnp oracle
    (``"reference"``, an O(S_max) dense read per chunk).

    x: [B, C, d] chunk activations (rows >= chunk_len are padding);
    offset/chunk_len: [B] int32 per-sequence cache offset / valid-row count;
    cache_k/v: [B, S_max, Hkv, D]. Returns (out [B, C, d], k', v').
    Padded rows produce garbage outputs — callers gather row chunk_len-1.
    """
    b, c = x.shape[:2]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, compute_dtype)
    rel = jnp.arange(c)
    positions = offset[:, None] + rel[None, :]                    # [B, C]
    if pos_embed == "mrope":
        pos3 = jnp.broadcast_to(positions[..., None], (b, c, 3))
        q = L.mrope_apply(q, pos3, mrope_sections, rope_theta)
        k = L.mrope_apply(k, pos3, mrope_sections, rope_theta)
    elif pos_embed == "rope":
        q = L.rope_apply(q, positions, rope_theta)
        k = L.rope_apply(k, positions, rope_theta)

    new_k = k.astype(cache_k.dtype)
    new_v = v.astype(cache_v.dtype)
    if kernel_mode == "multiport":
        from repro.kernels import ops
        out, cache_k, cache_v = ops.fused_prefill_chunk_attention(
            q, cache_k, cache_v, new_k, new_v, offset, chunk_len,
            seq_tile=seq_tile, dynamic_grid=dynamic_grid, interpret=interpret,
            mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix)
    else:
        from repro.kernels import ref
        out, cache_k, cache_v = ref.prefill_chunk_attention_ref(
            q, cache_k, cache_v, new_k, new_v, offset, chunk_len)
    out = out.reshape(b, c, n_heads * head_dim)
    return L.linear(p["wo"], out, compute_dtype), cache_k, cache_v


def attention_decode(p: dict, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, cache_len: jax.Array, *,
                     n_heads: int, n_kv_heads: int, head_dim: int,
                     pos_embed: str = "rope", rope_theta: float = 10000.0,
                     mrope_sections=(16, 24, 24),
                     kernel_mode: Literal["reference", "multiport"] = "reference",
                     seq_tile: int = 128, length_mask: bool = True,
                     dynamic_grid: bool = False, num_kv_splits: int = 1,
                     interpret: bool = True,
                     mesh=None, mesh_axis: str = "kv",
                     port_mix: str = "wr",
                     compute_dtype=None):
    """One decode step. x: [B, 1, d]; cache_k/v: [B, S_max, Hkv, D];
    cache_len: [B] current lengths. Returns (out [B,1,d], k', v').

    The multiport path traverses ``seq_tile``-sized cache tiles and, under
    ``length_mask``, skips tiles past each sequence's live length — callers
    additionally bound S_max itself by staging a bucketed live prefix.
    ``num_kv_splits > 1`` breaks each sequence's traversal into that many
    grid-parallel partial-attention chains (split-KV flash-decode; 1 is
    the serial oracle). ``mesh`` runs the fused traversal under
    ``shard_map`` over the batch axis (data-parallel KV: each device's
    kernel sees only its own sequences' SMEM scalars and live-tile bound).
    """
    b = x.shape[0]
    q, k, v = _project_qkv(p, x, n_heads, n_kv_heads, head_dim, compute_dtype)
    if pos_embed == "mrope":
        # text-only decode advances all three streams together
        pos3 = jnp.broadcast_to(cache_len[:, None, None], (b, 1, 3))
        q = L.mrope_apply(q, pos3, mrope_sections, rope_theta)
        k = L.mrope_apply(k, pos3, mrope_sections, rope_theta)
    elif pos_embed == "rope":
        pos = cache_len[:, None]
        q = L.rope_apply(q, pos, rope_theta)
        k = L.rope_apply(k, pos, rope_theta)

    q1 = q[:, 0]                                       # [B, H, D]
    new_k = k[:, 0].astype(cache_k.dtype)
    new_v = v[:, 0].astype(cache_v.dtype)

    if kernel_mode == "multiport":
        from repro.kernels import ops
        out, cache_k, cache_v = ops.fused_decode_attention(
            q1, cache_k, cache_v, new_k, new_v, cache_len,
            seq_tile=seq_tile, length_mask=length_mask,
            dynamic_grid=dynamic_grid, num_kv_splits=num_kv_splits,
            interpret=interpret,
            mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix)
    else:
        from repro.kernels import ref
        out, cache_k, cache_v = ref.decode_attention_ref(
            q1, cache_k, cache_v, new_k, new_v, cache_len)
    out = L.linear(p["wo"], out.reshape(b, 1, n_heads * head_dim)[..., :],
                   compute_dtype)
    return out, cache_k, cache_v
