"""Per-family layer blocks: init + train-apply + decode-step triples.

Block params are plain dicts; stacks are built by vmapping init over layer
keys so every leaf gains a leading [n_layers] axis for ``lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mlp as M
from repro.models import rwkv as R
from repro.models import ssm as S


# --------------------------------------------------------------------------
# transformer block (dense / moe / vlm / audio)
# --------------------------------------------------------------------------

def transformer_block_init(key, cfg: ArchConfig, *, d_ff: int | None = None) -> dict:
    ks = jax.random.split(key, 2)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
        "attn": A.attention_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.head_dim_, qkv_bias=cfg.qkv_bias,
                                 dtype=cfg.pdtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
    }
    if cfg.moe is not None and d_ff is None:
        p["moe"] = M.moe_init(ks[1], cfg.d_model, cfg.moe, dtype=cfg.pdtype)
    else:
        p["ffn"] = M.swiglu_init(ks[1], cfg.d_model, d_ff or cfg.d_ff,
                                 dtype=cfg.pdtype)
    return p


def transformer_block_apply(p: dict, x: jax.Array, positions: jax.Array,
                            cfg: ArchConfig) -> tuple[jax.Array, jax.Array]:
    """Training/prefill-compute body. Returns (x', moe_aux)."""
    h = A.attention_train(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        pos_embed=cfg.pos_embed, rope_theta=cfg.rope_theta,
        mrope_sections=tuple(cfg.mrope_sections), q_chunk=cfg.q_chunk,
        compute_dtype=cfg.cdtype)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        h, aux = M.moe_apply(p["moe"], y, cfg.moe, compute_dtype=cfg.cdtype)
    else:
        h = M.swiglu_apply(p["ffn"], y, compute_dtype=cfg.cdtype)
    return x + h, aux


def transformer_block_prefill(p: dict, x, positions, cache_k, cache_v,
                              cfg: ArchConfig):
    h, ck, cv = A.attention_prefill(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
        cache_k, cache_v,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        pos_embed=cfg.pos_embed, rope_theta=cfg.rope_theta,
        mrope_sections=tuple(cfg.mrope_sections), q_chunk=cfg.q_chunk,
        compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        h, _ = M.moe_apply(p["moe"], y, cfg.moe, compute_dtype=cfg.cdtype)
    else:
        h = M.swiglu_apply(p["ffn"], y, compute_dtype=cfg.cdtype)
    return x + h, ck, cv


def transformer_block_prefill_chunk(p: dict, x, offset, chunk_len,
                                    cache_k, cache_v, cfg: ArchConfig,
                                    kernel_mode: str = "reference",
                                    seq_tile: int = 128,
                                    dynamic_grid: bool = False,
                                    interpret: bool = True,
                                    mesh=None, mesh_axis: str = "kv",
                                    port_mix: str = "wr"):
    h, ck, cv = A.attention_prefill_chunk(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), offset, chunk_len,
        cache_k, cache_v,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        pos_embed=cfg.pos_embed, rope_theta=cfg.rope_theta,
        mrope_sections=tuple(cfg.mrope_sections), kernel_mode=kernel_mode,
        seq_tile=seq_tile, dynamic_grid=dynamic_grid, interpret=interpret,
        mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix,
        compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        h, _ = M.moe_apply(p["moe"], y, cfg.moe, compute_dtype=cfg.cdtype)
    else:
        h = M.swiglu_apply(p["ffn"], y, compute_dtype=cfg.cdtype)
    return x + h, ck, cv


def transformer_block_decode(p: dict, x, cache_k, cache_v, cache_len,
                             cfg: ArchConfig, kernel_mode: str = "reference",
                             seq_tile: int = 128, length_mask: bool = True,
                             dynamic_grid: bool = False,
                             num_kv_splits: int = 1,
                             interpret: bool = True,
                             mesh=None, mesh_axis: str = "kv",
                             port_mix: str = "wr"):
    h, ck, cv = A.attention_decode(
        p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps), cache_k, cache_v,
        cache_len,
        n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim_,
        pos_embed=cfg.pos_embed, rope_theta=cfg.rope_theta,
        mrope_sections=tuple(cfg.mrope_sections), kernel_mode=kernel_mode,
        seq_tile=seq_tile, length_mask=length_mask,
        dynamic_grid=dynamic_grid, num_kv_splits=num_kv_splits,
        interpret=interpret,
        mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix,
        compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        h, _ = M.moe_apply(p["moe"], y, cfg.moe, compute_dtype=cfg.cdtype)
    else:
        h = M.swiglu_apply(p["ffn"], y, compute_dtype=cfg.cdtype)
    return x + h, ck, cv


# --------------------------------------------------------------------------
# mamba2 block (hybrid)
# --------------------------------------------------------------------------

def mamba_block_init(key, cfg: ArchConfig) -> dict:
    return {
        "ln": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
        "mamba": S.mamba2_init(key, cfg.d_model, cfg.ssm, dtype=cfg.pdtype),
    }


def mamba_block_apply(p: dict, x: jax.Array, cfg: ArchConfig,
                      conv_state=None, ssm_state=None, return_state=False):
    y = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    if return_state:
        h, cs, ss = S.mamba2_apply(p["mamba"], y, cfg.d_model, cfg.ssm,
                                   la_chunk=cfg.la_chunk, compute_dtype=cfg.cdtype,
                                   conv_state=conv_state, ssm_state=ssm_state,
                                   return_state=True)
        return x + h, cs, ss
    h = S.mamba2_apply(p["mamba"], y, cfg.d_model, cfg.ssm,
                       la_chunk=cfg.la_chunk, compute_dtype=cfg.cdtype)
    return x + h


def mamba_block_decode(p: dict, x, cfg: ArchConfig, conv_state, ssm_state):
    y = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    h, cs, ss = S.mamba2_decode_step(p["mamba"], y, cfg.d_model, cfg.ssm,
                                     conv_state=conv_state, ssm_state=ssm_state,
                                     compute_dtype=cfg.cdtype)
    return x + h, cs, ss


# --------------------------------------------------------------------------
# rwkv block
# --------------------------------------------------------------------------

def rwkv_block_init(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
        "tm": R.time_mix_init(ks[0], cfg.d_model, cfg.rwkv, dtype=cfg.pdtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.pdtype),
        "cm": R.channel_mix_init(ks[1], cfg.d_model, cfg.d_ff, dtype=cfg.pdtype),
    }


def rwkv_block_apply(p: dict, x: jax.Array, cfg: ArchConfig,
                     states=None, return_state=False):
    """states: (tm_shift, tm_state, cm_shift) or None."""
    tm_shift = tm_state = cm_shift = None
    if states is not None:
        tm_shift, tm_state, cm_shift = states
    y = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if return_state:
        h, new_tm_shift, new_tm_state = R.time_mix_apply(
            p["tm"], y, cfg.rwkv, la_chunk=cfg.la_chunk,
            compute_dtype=cfg.cdtype, shift_state=tm_shift,
            ssm_state=tm_state, return_state=True)
        x = x + h
        y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
        h, new_cm_shift = R.channel_mix_apply(
            p["cm"], y, compute_dtype=cfg.cdtype, shift_state=cm_shift,
            return_state=True)
        return x + h, (new_tm_shift, new_tm_state, new_cm_shift)
    h = R.time_mix_apply(p["tm"], y, cfg.rwkv, la_chunk=cfg.la_chunk,
                         compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + R.channel_mix_apply(p["cm"], y, compute_dtype=cfg.cdtype)


def rwkv_block_decode(p: dict, x, cfg: ArchConfig, states):
    tm_shift, tm_state, cm_shift = states
    y = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    h, new_tm_shift, new_tm_state = R.time_mix_step(
        p["tm"], y, cfg.rwkv, shift_state=tm_shift, ssm_state=tm_state,
        compute_dtype=cfg.cdtype)
    x = x + h
    y = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    h, new_cm_shift = R.channel_mix_step(p["cm"], y, shift_state=cm_shift,
                                         compute_dtype=cfg.cdtype)
    return x + h, (new_tm_shift, new_tm_state, new_cm_shift)
