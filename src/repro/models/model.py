"""Model assembly: init / forward / loss / prefill / decode for all families.

Layer stacks are scanned (``lax.scan`` over params stacked on a leading
[n_layers] axis) so the HLO is O(1) in depth — essential for compiling the
126-layer llama3-405b dry-run. Remat wraps the scan body (``cfg.remat``).

Batch dict convention:
  train/prefill: {"inputs": ids[B,S] | embeds[B,S,d], "labels": ids[B,S],
                  "positions": optional ([B,S] rope / [B,S,3] mrope)}
  decode:        {"inputs": ids[B,1] | embeds[B,1,d]}

Decode state (per family) is a dict pytree with a shared "len": [B] field.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.api import hint
from repro.models import blocks as B
from repro.models import layers as L

PyTree = Any


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _hybrid_counts(cfg: ArchConfig) -> tuple[int, int, int]:
    g = cfg.n_layers // cfg.hybrid.group_size
    m = cfg.hybrid.group_size
    tail = cfg.n_layers - g * m
    return g, m, tail


def init_params(key, cfg: ArchConfig) -> PyTree:
    ks = jax.random.split(key, 6)
    p: dict = {}
    if cfg.input_mode == "tokens":
        p["embed"] = L.embedding_init(ks[0], cfg.vocab, cfg.d_model, cfg.pdtype)
    p["final_norm"] = L.rmsnorm_init(cfg.d_model, cfg.pdtype)
    p["lm_head"] = L.linear_init(ks[1], cfg.d_model, cfg.vocab, dtype=cfg.pdtype)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        p["layers"] = _stacked_init(
            lambda k: B.transformer_block_init(k, cfg), ks[2], cfg.n_layers)
    elif cfg.family == "ssm":
        p["layers"] = _stacked_init(
            lambda k: B.rwkv_block_init(k, cfg), ks[2], cfg.n_layers)
    elif cfg.family == "hybrid":
        g, m, tail = _hybrid_counts(cfg)
        p["shared_attn"] = B.transformer_block_init(
            ks[3], cfg, d_ff=cfg.hybrid.attn_d_ff)
        p["groups"] = jax.vmap(
            lambda k: _stacked_init(lambda kk: B.mamba_block_init(kk, cfg), k, m)
        )(jax.random.split(ks[2], g))
        if tail:
            p["tail"] = _stacked_init(
                lambda k: B.mamba_block_init(k, cfg), ks[4], tail)
    else:
        raise ValueError(cfg.family)
    return p


# --------------------------------------------------------------------------
# stem & head
# --------------------------------------------------------------------------

def _stem(params: PyTree, cfg: ArchConfig, inputs: jax.Array,
          offset: jax.Array | int = 0) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = L.embedding_lookup(params["embed"], inputs, cfg.cdtype)
    else:
        x = inputs.astype(cfg.cdtype)
    if cfg.pos_embed == "sinusoidal":
        s = x.shape[1]
        if isinstance(offset, int):
            pe = L.sinusoidal_positions(s, cfg.d_model, offset)[None]
        else:  # per-sample offsets (decode)
            pe = jax.vmap(lambda o: L.sinusoidal_positions(s, cfg.d_model, o))(offset)
        x = x + pe.astype(x.dtype)
    return hint(x, "hidden")


def _default_positions(cfg: ArchConfig, batch: dict, b: int, s: int) -> jax.Array:
    pos = batch.get("positions")
    if pos is not None:
        return pos
    base = jnp.arange(s)[None]
    if cfg.pos_embed == "mrope":
        return jnp.broadcast_to(base[..., None], (b, s, 3))
    return jnp.broadcast_to(base, (b, s))


def _head(params: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hint(L.linear(params["lm_head"], x, cfg.cdtype), "logits")


# --------------------------------------------------------------------------
# forward (training compute)
# --------------------------------------------------------------------------

def forward(params: PyTree, cfg: ArchConfig, batch: dict
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], moe_aux_loss scalar)."""
    inputs = batch["inputs"]
    bsz = inputs.shape[0]
    seq = inputs.shape[1]
    x = _stem(params, cfg, inputs)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        positions = _default_positions(cfg, batch, bsz, seq)

        def body(carry, pl):
            h, aux = carry
            h, a = B.transformer_block_apply(pl, h, positions, cfg)
            return (h, aux + a), None
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["layers"])

    elif cfg.family == "ssm":
        def body(h, pl):
            return B.rwkv_block_apply(pl, h, cfg), None
        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        aux = aux0

    elif cfg.family == "hybrid":
        positions = _default_positions(cfg, batch, bsz, seq)
        shared = params["shared_attn"]

        def group_body(h, pg):
            h, _ = B.transformer_block_apply(shared, h, positions, cfg)

            def inner(hh, pl):
                return B.mamba_block_apply(pl, hh, cfg), None
            h, _ = jax.lax.scan(inner, h, pg)
            return h, None
        if cfg.remat == "block":
            group_body = jax.checkpoint(group_body)
        x, _ = jax.lax.scan(group_body, x, params["groups"])
        if "tail" in params:
            def tail_body(h, pl):
                return B.mamba_block_apply(pl, h, cfg), None
            if cfg.remat == "block":
                tail_body = jax.checkpoint(tail_body)
            x, _ = jax.lax.scan(tail_body, x, params["tail"])
        aux = aux0
    else:
        raise ValueError(cfg.family)

    return _head(params, cfg, x), aux


def loss_fn(params: PyTree, cfg: ArchConfig, batch: dict
            ) -> tuple[jax.Array, dict]:
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    mask = (labels >= 0)
    labels = jnp.maximum(labels, 0)
    lf = logits.astype(jnp.float32)
    # Vocab-sharding-friendly CE: every vocab-axis op is a reduction (the
    # gold logit is a one-hot contraction, not a gather), so a tensor-parallel
    # vocab stays sharded through fwd+bwd — no [B,S,V] all-gather.
    m = jax.lax.stop_gradient(lf.max(axis=-1))
    lse = m + jnp.log(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1))
    onehot = (labels[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, lf.shape[-1:], 0))
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = (lse - gold) * mask
    count = jnp.maximum(mask.sum(), 1)
    ce = nll.sum() / count
    zl = cfg.z_loss * ((lse * mask) ** 2).sum() / count
    loss = ce + zl + aux
    acc = ((lf.argmax(-1) == labels) * mask).sum() / count
    return loss, {"loss": loss, "ce": ce, "z_loss": zl, "moe_aux": aux,
                  "accuracy": acc, "tokens": count}


# --------------------------------------------------------------------------
# decode state
# --------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch_size: int, max_len: int) -> PyTree:
    cdt = cfg.cdtype
    hd = cfg.head_dim_
    state: dict = {"len": jnp.zeros((batch_size,), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        kv = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, hd)
        state["cache_k"] = jnp.zeros(kv, cdt)
        state["cache_v"] = jnp.zeros(kv, cdt)
    elif cfg.family == "ssm":
        h = cfg.d_model // cfg.rwkv.head_dim
        k = cfg.rwkv.head_dim
        lshape = (cfg.n_layers, batch_size)
        state["tm_shift"] = jnp.zeros(lshape + (cfg.d_model,), cdt)
        state["tm_state"] = jnp.zeros(lshape + (h, k, k), jnp.float32)
        state["cm_shift"] = jnp.zeros(lshape + (cfg.d_model,), cdt)
    elif cfg.family == "hybrid":
        g, m, tail = _hybrid_counts(cfg)
        ssm = cfg.ssm
        d_in = ssm.expand * cfg.d_model
        nheads = d_in // ssm.head_dim
        kv = (g, batch_size, max_len, cfg.n_kv_heads, hd)
        state["attn_k"] = jnp.zeros(kv, cdt)
        state["attn_v"] = jnp.zeros(kv, cdt)

        def conv_states(*lead):
            ck = ssm.conv_kernel - 1
            return {"x": jnp.zeros(lead + (batch_size, ck, d_in), cdt),
                    "B": jnp.zeros(lead + (batch_size, ck, ssm.state_dim), cdt),
                    "C": jnp.zeros(lead + (batch_size, ck, ssm.state_dim), cdt)}
        state["conv"] = conv_states(g, m)
        state["ssm"] = jnp.zeros((g, m, batch_size, nheads, ssm.state_dim,
                                  ssm.head_dim), jnp.float32)
        if tail:
            state["tail_conv"] = conv_states(tail)
            state["tail_ssm"] = jnp.zeros((tail, batch_size, nheads, ssm.state_dim,
                                           ssm.head_dim), jnp.float32)
    return state


# --------------------------------------------------------------------------
# decode step (one new token; KV caches serviced as multi-port memory)
# --------------------------------------------------------------------------

def decode_step(params: PyTree, cfg: ArchConfig, state: PyTree, batch: dict,
                *, kernel_mode: str = "reference", seq_tile: int = 128,
                length_mask: bool = True, dynamic_grid: bool = False,
                num_kv_splits: int = 1,
                interpret: bool = True, mesh=None,
                mesh_axis: str = "kv",
                port_mix: str = "wr") -> tuple[PyTree, jax.Array]:
    """Returns (state', logits [B, V]).

    ``seq_tile``/``length_mask`` bound the multiport kernel's traversal to
    live cache tiles; callers bound the allocated length itself by passing a
    state whose caches hold a bucketed live prefix (the engine does both).
    ``num_kv_splits > 1`` runs each attention layer's traversal as split-KV
    flash-decode (grid-parallel partials + LSE combine; 1 = serial oracle).
    ``mesh`` (data-parallel KV) runs the fused traversal under ``shard_map``
    over the batch axis — per-device SMEM scalars and live-tile bounds.
    """
    inputs = batch["inputs"]
    x = _stem(params, cfg, inputs, offset=state["len"])

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        def body(h, xs):
            pl, ck, cv = xs
            h, ck, cv = B.transformer_block_decode(
                pl, h, ck, cv, state["len"], cfg, kernel_mode=kernel_mode,
                seq_tile=seq_tile, length_mask=length_mask,
                dynamic_grid=dynamic_grid, num_kv_splits=num_kv_splits,
                interpret=interpret,
                mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix)
            return h, (ck, cv)
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], state["cache_k"], state["cache_v"]))
        state = dict(state, cache_k=ck, cache_v=cv)

    elif cfg.family == "ssm":
        def body(h, xs):
            pl, tms, tmst, cms = xs
            h, (tms, tmst, cms) = B.rwkv_block_decode(pl, h, cfg, (tms, tmst, cms))
            return h, (tms, tmst, cms)
        x, (tms, tmst, cms) = jax.lax.scan(
            body, x, (params["layers"], state["tm_shift"], state["tm_state"],
                      state["cm_shift"]))
        state = dict(state, tm_shift=tms, tm_state=tmst, cm_shift=cms)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(h, xs):
            pg, ck, cv, conv, ssm_s = xs
            h, ck, cv = B.transformer_block_decode(
                shared, h, ck, cv, state["len"], cfg, kernel_mode=kernel_mode,
                seq_tile=seq_tile, length_mask=length_mask,
                dynamic_grid=dynamic_grid, num_kv_splits=num_kv_splits,
                interpret=interpret,
                mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix)

            def inner(hh, ys):
                pl, cs, ss = ys
                hh, cs, ss = B.mamba_block_decode(pl, hh, cfg, cs, ss)
                return hh, (cs, ss)
            h, (conv, ssm_s) = jax.lax.scan(inner, h, (pg, conv, ssm_s))
            return h, (ck, cv, conv, ssm_s)

        x, (ck, cv, conv, ssm_s) = jax.lax.scan(
            group_body, x, (params["groups"], state["attn_k"], state["attn_v"],
                            state["conv"], state["ssm"]))
        state = dict(state, attn_k=ck, attn_v=cv, conv=conv, ssm=ssm_s)
        if "tail" in params:
            def tail_body(h, ys):
                pl, cs, ss = ys
                h, cs, ss = B.mamba_block_decode(pl, h, cfg, cs, ss)
                return h, (cs, ss)
            x, (tcs, tss) = jax.lax.scan(
                tail_body, x, (params["tail"], state["tail_conv"],
                               state["tail_ssm"]))
            state = dict(state, tail_conv=tcs, tail_ssm=tss)
    else:
        raise ValueError(cfg.family)

    logits = _head(params, cfg, x)[:, 0]
    state = dict(state, len=state["len"] + 1)
    return state, logits


# --------------------------------------------------------------------------
# prefill (populate caches from a prompt)
# --------------------------------------------------------------------------

def prefill(params: PyTree, cfg: ArchConfig, state: PyTree, batch: dict
            ) -> tuple[PyTree, jax.Array]:
    """Process a prompt of length S, filling caches. Returns (state', logits
    of the last position [B, V])."""
    inputs = batch["inputs"]
    bsz, seq = inputs.shape[0], inputs.shape[1]
    x = _stem(params, cfg, inputs)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        positions = _default_positions(cfg, batch, bsz, seq)

        def body(h, xs):
            pl, ck, cv = xs
            h, ck, cv = B.transformer_block_prefill(pl, h, positions, ck, cv, cfg)
            return h, (ck, cv)
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["layers"], state["cache_k"], state["cache_v"]))
        state = dict(state, cache_k=ck, cache_v=cv)

    elif cfg.family == "ssm":
        def body(h, xs):
            pl, tms, tmst, cms = xs
            h, st = B.rwkv_block_apply(pl, h, cfg, states=(None, tmst, None),
                                       return_state=True)
            return h, st
        x, (tms, tmst, cms) = jax.lax.scan(
            body, x, (params["layers"], state["tm_shift"], state["tm_state"],
                      state["cm_shift"]))
        state = dict(state, tm_shift=tms, tm_state=tmst, cm_shift=cms)

    elif cfg.family == "hybrid":
        positions = _default_positions(cfg, batch, bsz, seq)
        shared = params["shared_attn"]

        def group_body(h, xs):
            pg, ck, cv, conv, ssm_s = xs
            h, ck, cv = B.transformer_block_prefill(shared, h, positions, ck, cv, cfg)

            def inner(hh, ys):
                pl, cs, ss = ys
                hh, cs, ss = B.mamba_block_apply(pl, hh, cfg, conv_state=None,
                                                 ssm_state=ss, return_state=True)
                return hh, (cs, ss)
            h, (conv, ssm_s) = jax.lax.scan(inner, h, (pg, conv, ssm_s))
            return h, (ck, cv, conv, ssm_s)

        x, (ck, cv, conv, ssm_s) = jax.lax.scan(
            group_body, x, (params["groups"], state["attn_k"], state["attn_v"],
                            state["conv"], state["ssm"]))
        state = dict(state, attn_k=ck, attn_v=cv, conv=conv, ssm=ssm_s)
        if "tail" in params:
            def tail_body(h, ys):
                pl, cs, ss = ys
                h, cs, ss = B.mamba_block_apply(pl, h, cfg, conv_state=None,
                                                ssm_state=ss, return_state=True)
                return h, (cs, ss)
            x, (tcs, tss) = jax.lax.scan(
                tail_body, x, (params["tail"], state["tail_conv"],
                               state["tail_ssm"]))
            state = dict(state, tail_conv=tcs, tail_ssm=tss)

    logits = _head(params, cfg, x[:, -1:])[:, 0]
    state = dict(state, len=state["len"] + seq)
    return state, logits


# --------------------------------------------------------------------------
# chunked prefill (populate caches one fixed-size chunk per macro-cycle)
# --------------------------------------------------------------------------

def prefill_chunk(params: PyTree, cfg: ArchConfig, state: PyTree, batch: dict,
                  *, kernel_mode: str = "reference", seq_tile: int = 128,
                  dynamic_grid: bool = False, interpret: bool = True,
                  mesh=None, mesh_axis: str = "kv", port_mix: str = "wr"
                  ) -> tuple[PyTree, jax.Array]:
    """Process ONE fixed-size prompt chunk for a batch of sequences.

    The continuous-batching prefill step: each sequence contributes its next
    ``C`` prompt tokens (rows past ``chunk_len`` are padding), chunks from
    different sequences are stacked into one padded batch, and every chunk's
    K,V is written into the cache at [len, len+chunk_len) while attention
    reads back over everything cached so far — the cache serviced as a
    2-port (1W+1R) memory, same as decode. Under
    ``kernel_mode="multiport"`` both ports run through the fused Pallas
    traversal bounded to live ``seq_tile``-tiles; ``"reference"`` keeps the
    two-pass jnp oracle and its O(S_max) dense read.

    batch: {"inputs": ids [B, C], "chunk_len": [B] valid rows per sequence}.
    Returns (state', logits [B, V]) where the logits row for each sequence is
    taken at its LAST VALID chunk position — when the chunk completes a
    prompt these are the prefill logits that seed the first generated token.
    """
    if cfg.family not in ("dense", "moe", "vlm", "audio"):
        raise NotImplementedError("chunked prefill serves KV-cache families")
    inputs = batch["inputs"]
    c = inputs.shape[1]
    chunk_len = jnp.asarray(batch["chunk_len"], jnp.int32)
    offset = state["len"]
    x = _stem(params, cfg, inputs, offset=offset)

    def body(h, xs):
        pl, ck, cv = xs
        h, ck, cv = B.transformer_block_prefill_chunk(
            pl, h, offset, chunk_len, ck, cv, cfg, kernel_mode=kernel_mode,
            seq_tile=seq_tile, dynamic_grid=dynamic_grid, interpret=interpret,
            mesh=mesh, mesh_axis=mesh_axis, port_mix=port_mix)
        return h, (ck, cv)
    x, (ck, cv) = jax.lax.scan(
        body, x, (params["layers"], state["cache_k"], state["cache_v"]))

    last = jnp.clip(chunk_len - 1, 0, c - 1)
    xl = jnp.take_along_axis(x, last[:, None, None], axis=1)      # [B, 1, d]
    logits = _head(params, cfg, xl)[:, 0]
    state = dict(state, cache_k=ck, cache_v=cv, len=offset + chunk_len)
    return state, logits
