"""AdamW with global-norm clipping — hand-rolled pytree optimizer.

States are kept in f32 regardless of parameter dtype (mixed-precision
training: bf16 params, f32 master states is available via ``master_weights``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_weights: bool = False  # keep an f32 copy of bf16 params


def global_norm(tree: PyTree) -> jax.Array:
    sq = jax.tree_util.tree_reduce(
        lambda a, x: a + jnp.sum(jnp.square(x.astype(jnp.float32))), tree, 0.0)
    return jnp.sqrt(sq)


def clip_by_global_norm(grads: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params: PyTree, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
    }
    if cfg.master_weights:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(grads: PyTree, state: dict, params: PyTree, lr,
                 cfg: AdamWConfig) -> tuple[PyTree, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    src = state.get("master", params)

    def upd(g, m, v, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m / c1, v / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return m, v, pf

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(src)
    outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in outs])
    new_v = treedef.unflatten([o[1] for o in outs])
    new_f32 = treedef.unflatten([o[2] for o in outs])

    new_params = jax.tree_util.tree_map(
        lambda pf, p: pf.astype(p.dtype), new_f32, params)
    new_state = dict(state, step=step, m=new_m, v=new_v)
    if "master" in state:
        new_state["master"] = new_f32
    return new_params, new_state, {"grad_norm": gnorm}
