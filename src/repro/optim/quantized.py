"""8-bit AdamW: block-wise DYNAMIC (log-scale) quantized moments.

The distributed-optimization trick that makes llama3-405b trainable on v5e
HBM (EXPERIMENTS.md §Dry-run): fp32 m+v cost 8 bytes/param (3.2 TB at 405B);
8-bit states cost 2 bytes/param + 1/16 block-scale overhead.

Linear absmax int8 is catastrophically wrong for Adam's second moment: an
element whose v is 100x below its block's max quantizes to 0 and the next
update divides by sqrt(0)+eps. Following Dettmers et al. (8-bit optimizers),
moments use a block-wise *dynamic* map — here a log-uniform code covering 7
decades, so every element keeps <= ~6.5% (m, signed, 127 levels) / ~3.2%
(v, unsigned, 255 levels) relative error regardless of its magnitude within
the block. Code 0 represents exact zero.

Layout per tensor: q (int8/uint8 [nblocks, 64]) + scale (f32 [nblocks, 1]).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, clip_by_global_norm

PyTree = Any
BLOCK = 64
_DECADES = 7.0


def _blockify(x: jax.Array) -> jax.Array:
    """Block along the LAST axis: [..., n] -> [..., ceil(n/B), B].

    Layout-aligned with the parameter: the quantized state keeps the
    parameter's leading-dim sharding, so dequantize/requantize never
    reshards (a flat-blocked layout forces XLA into involuntary full
    rematerialization of f32 states — 1.5 TB/chip at 405B)."""
    *lead, n = x.shape
    pad = (-n) % BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    return x.reshape(*lead, -1, BLOCK)


def _quantize(x: jax.Array, signed: bool) -> dict:
    """Log-dynamic block quantization. x: any shape, f32."""
    if x.ndim == 0:
        x = x[None]
    blocks = _blockify(x.astype(jnp.float32))
    levels = 127.0 if signed else 255.0
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True)
    a = jnp.abs(blocks) / jnp.maximum(scale, 1e-30)          # in [0, 1]
    qmag = jnp.round((jnp.log10(jnp.maximum(a, 10.0 ** -_DECADES))
                      + _DECADES) / _DECADES * levels)
    qmag = jnp.where(a < 10.0 ** -_DECADES, 0.0, jnp.maximum(qmag, 1.0))
    if signed:
        q = (jnp.sign(blocks) * qmag).astype(jnp.int8)
    else:
        q = qmag.astype(jnp.uint8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize(qd: dict, shape) -> jax.Array:
    q = qd["q"]
    signed = q.dtype == jnp.int8
    levels = 127.0 if signed else 255.0
    qf = q.astype(jnp.float32)
    mag = 10.0 ** (jnp.abs(qf) / levels * _DECADES - _DECADES)
    val = jnp.where(qf == 0, 0.0, mag) * (jnp.sign(qf) if signed else 1.0)
    val = (val * qd["scale"]).reshape(*q.shape[:-2], -1)
    n_last = shape[-1] if shape else 1
    return val[..., :n_last].reshape(shape)


def adamw8bit_init(params: PyTree, cfg: AdamWConfig) -> dict:
    def qzero(p, signed):
        return _quantize(jnp.zeros(p.shape, jnp.float32), signed)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(lambda p: qzero(p, True), params),
        "v": jax.tree_util.tree_map(lambda p: qzero(p, False), params),
    }


def adamw8bit_update(grads: PyTree, state: dict, params: PyTree, lr,
                     cfg: AdamWConfig) -> tuple[PyTree, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, mq, vq, p):
        m = _dequantize(mq, p.shape)
        v = _dequantize(vq, p.shape)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m / c1, v / c2
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return _quantize(m, True), _quantize(v, False), pf.astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_state = dict(state, step=step,
                     m=treedef.unflatten([o[0] for o in outs]),
                     v=treedef.unflatten([o[1] for o in outs]))
    new_params = treedef.unflatten([o[2] for o in outs])
    return new_params, new_state, {"grad_norm": gnorm}
