"""Optimizers: AdamW (f32 states), 8-bit AdamW (int8 block-quantized states),
Adafactor (factored states). Selected by name via ``make_optimizer``."""
from __future__ import annotations

from typing import Callable

from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.optim.quantized import adamw8bit_init, adamw8bit_update
from repro.optim.schedules import constant, warmup_cosine

_OPTS = {
    "adamw": (adamw_init, adamw_update),
    "adamw8bit": (adamw8bit_init, adamw8bit_update),
    "adafactor": (adafactor_init, adafactor_update),
}


def make_optimizer(name: str, cfg: AdamWConfig | None = None
                   ) -> tuple[Callable, Callable, AdamWConfig]:
    """Returns (init_fn(params), update_fn(grads, state, params, lr), cfg)."""
    cfg = cfg or AdamWConfig()
    init, update = _OPTS[name]
    return (lambda p: init(p, cfg),
            lambda g, s, p, lr: update(g, s, p, lr, cfg),
            cfg)


__all__ = ["AdamWConfig", "make_optimizer", "global_norm", "warmup_cosine",
           "constant", "adamw_init", "adamw_update", "adamw8bit_init",
           "adamw8bit_update", "adafactor_init", "adafactor_update"]
