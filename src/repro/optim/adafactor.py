"""Adafactor (factored second moments) — the sub-linear-memory alternative.

Matrices store row/column second-moment factors only (O(n+m) instead of
O(nm)); vectors fall back to full second moments. No first moment (momentum-
free, per the paper's recommended configuration), relative step sizes off —
the external schedule drives lr.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, clip_by_global_norm

PyTree = Any


def adafactor_init(params: PyTree, cfg: AdamWConfig) -> dict:
    def factors(p):
        if p.ndim >= 2:
            rows = p.shape[:-1]
            return {"vr": jnp.zeros(rows, jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"step": jnp.zeros((), jnp.int32),
            "v": jax.tree_util.tree_map(factors, params,
                                        is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(grads: PyTree, state: dict, params: PyTree, lr,
                     cfg: AdamWConfig) -> tuple[PyTree, dict, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8                     # adafactor beta2 schedule
    eps = 1e-30

    def upd(g, v, p):
        g2 = jnp.square(g) + eps
        if p.ndim >= 2:
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (vr[..., None] / jnp.maximum(
                vr.mean(axis=-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            nv = {"vr": vr, "vc": vc}
        else:
            nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
            u = g * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
        # update clipping (adafactor d=1.0)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (u + cfg.weight_decay * pf)
        return nv, pf.astype(p.dtype)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
    new_state = dict(state, step=step,
                     v=treedef.unflatten([o[0] for o in outs]))
    new_params = treedef.unflatten([o[1] for o in outs])
    return new_params, new_state, {"grad_norm": gnorm}
