"""Core: the paper's configurable multi-port memory wrapper, TPU-adapted.

Public API:
  PortConfig / PortRequest / READ / WRITE  — port bundles (ports.py)
  MemorySpec / step / step_banked          — the memory + its semantics (multiport.py)
  build_schedule / simulate_waveform       — clock-generator analogue (clockgen.py)
  baselines                                — single-port / replicated / coded designs
"""
from repro.core.clockgen import Schedule, build_schedule, effective_access_rate, simulate_waveform
from repro.core.multiport import MemorySpec, reference_step, step, step_banked
from repro.core.ports import (MAX_PORTS, READ, WRITE, PortConfig, PortRequest,
                              empty_request, quad_port, read_request, single_port,
                              write_request)

__all__ = [
    "MAX_PORTS", "READ", "WRITE", "PortConfig", "PortRequest",
    "empty_request", "quad_port", "read_request", "single_port", "write_request",
    "MemorySpec", "step", "step_banked", "reference_step",
    "Schedule", "build_schedule", "simulate_waveform", "effective_access_rate",
]
