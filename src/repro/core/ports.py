"""Port bundles for the configurable multi-port memory wrapper.

Paper mapping (Fig. 1): each external port carries ``port_en`` (enable), ``w/rb``
(write / read-bar role), ``addr`` (address lines) and ``w_data`` (write data).
On TPU a port is *vectorized*: one macro-cycle carries a queue of ``Q`` word
requests per port (a 65nm SRAM port moves one word per cycle; a TPU lane-vector
moves many — see DESIGN.md §2, assumption delta 1).

``PortConfig`` is the static part (jit-specialization boundary): which ports are
enabled, each port's R/W role, and the priority permutation. ``PortRequest`` is
the traced part: addresses, data, and a per-lane validity mask.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

MAX_PORTS = 4  # the paper's wrapper exposes four external ports

READ = 0
WRITE = 1


@dataclasses.dataclass(frozen=True)
class PortConfig:
    """Static configuration of the wrapper (the ``port_en`` / ``w/rb`` wires).

    Attributes:
      enabled:  per-port enable bits (``port_en``).
      roles:    per-port READ/WRITE role (``w/rb``); ignored for disabled ports.
      priority: permutation of range(MAX_PORTS); lower position = higher priority
                (paper default A > B > C > D == identity permutation).
    """

    enabled: tuple[bool, ...]
    roles: tuple[int, ...]
    priority: tuple[int, ...] = tuple(range(MAX_PORTS))

    def __post_init__(self) -> None:
        if len(self.enabled) != MAX_PORTS or len(self.roles) != MAX_PORTS:
            raise ValueError(f"PortConfig requires exactly {MAX_PORTS} port slots")
        if sorted(self.priority) != list(range(MAX_PORTS)):
            raise ValueError(f"priority must be a permutation of 0..{MAX_PORTS-1}")
        if not any(self.enabled):
            raise ValueError("at least one port must be enabled")
        for r in self.roles:
            if r not in (READ, WRITE):
                raise ValueError("roles must be READ (0) or WRITE (1)")

    # --- the "N ports en" block -------------------------------------------------
    @property
    def enabled_count(self) -> int:
        """Number of enabled ports (the block that drives B1B0)."""
        return sum(self.enabled)

    @property
    def b1b0(self) -> int:
        """The 2-bit enabled-port count fed to the clock generator.

        Paper encoding: 00 => 1-port, 01 => 2-port, 10 => 3-port, 11 => 4-port.
        """
        return self.enabled_count - 1

    # --- priority encoder output -------------------------------------------------
    def service_order(self) -> tuple[int, ...]:
        """Enabled port indices in service order (highest priority first).

        This is the composition of the priority encoder and the FSM walk of
        Fig. 2: the FSM starts at the highest-priority enabled port and visits
        each enabled port once per macro-cycle.
        """
        return tuple(p for p in self.priority if self.enabled[p])

    def read_ports(self) -> tuple[int, ...]:
        return tuple(p for p in range(MAX_PORTS) if self.enabled[p] and self.roles[p] == READ)

    def write_ports(self) -> tuple[int, ...]:
        return tuple(p for p in range(MAX_PORTS) if self.enabled[p] and self.roles[p] == WRITE)

    def mix(self) -> str:
        """The R/W mix of the enabled ports, e.g. ``"2W+1R"`` for a 3-port
        asymmetric configuration (``"2W"`` / ``"1R"`` when one role is
        absent). This is the label the pool's per-mix traversal histogram
        keys on."""
        n_w = len(self.write_ports())
        n_r = len(self.read_ports())
        parts = ([f"{n_w}W"] if n_w else []) + ([f"{n_r}R"] if n_r else [])
        return "+".join(parts)

    def describe(self) -> str:
        """Unambiguous rendering: port count, R/W mix, and the per-port
        roles in service order — ``"3-port[2W+1R|A:W > B:W > C:R]"``.
        :meth:`parse` round-trips this back to a canonical PortConfig."""
        names = "ABCD"
        parts = []
        for p in self.priority:
            if self.enabled[p]:
                parts.append(f"{names[p]}:{'W' if self.roles[p] == WRITE else 'R'}")
        return f"{self.enabled_count}-port[{self.mix()}|{' > '.join(parts)}]"

    @classmethod
    def parse(cls, text: str) -> "PortConfig":
        """Reconstruct a canonical PortConfig from :meth:`describe` output.

        Canonical means: disabled ports get the READ role, and the priority
        permutation is the listed service order followed by the remaining
        port ids in ascending order — enabled set, enabled roles and
        ``service_order()`` all round-trip exactly.
        """
        import re
        names = "ABCD"
        m = re.fullmatch(r"(\d+)-port\[([^|\]]+)\|([^\]]+)\]", text)
        if not m:
            raise ValueError(f"unparseable port description: {text!r}")
        count, mix, order_txt = int(m.group(1)), m.group(2), m.group(3)
        enabled = [False] * MAX_PORTS
        roles = [READ] * MAX_PORTS
        order = []
        for part in order_txt.split(" > "):
            pm = re.fullmatch(r"([ABCD]):([RW])", part.strip())
            if not pm:
                raise ValueError(f"unparseable port entry {part!r} in {text!r}")
            p = names.index(pm.group(1))
            if enabled[p]:
                raise ValueError(f"port {pm.group(1)} listed twice in {text!r}")
            enabled[p] = True
            roles[p] = WRITE if pm.group(2) == "W" else READ
            order.append(p)
        priority = tuple(order) + tuple(p for p in range(MAX_PORTS)
                                        if p not in order)
        cfg = cls(enabled=tuple(enabled), roles=tuple(roles),
                  priority=priority)
        if cfg.enabled_count != count or cfg.mix() != mix:
            raise ValueError(
                f"inconsistent description {text!r}: lists "
                f"{cfg.enabled_count} port(s) with mix {cfg.mix()}")
        return cfg


def quad_port(roles: Sequence[int] = (WRITE, WRITE, READ, READ)) -> PortConfig:
    """All four ports enabled (the paper's flagship 4-port mode)."""
    return PortConfig(enabled=(True,) * 4, roles=tuple(roles))


def single_port(role: int = READ) -> PortConfig:
    """Degenerate 1-port mode — behaves exactly like the bare SRAM macro."""
    return PortConfig(enabled=(True, False, False, False), roles=(role, READ, READ, READ))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PortRequest:
    """One macro-cycle of traffic for one port.

    Attributes:
      addr: int32[Q]  word addresses.
      data: dtype[Q, W]  write payload (ignored for read ports; zeros by convention).
      mask: bool[Q]   lane validity (a disabled lane issues no transaction).
    """

    addr: jax.Array
    data: jax.Array
    mask: jax.Array

    def tree_flatten(self):
        return (self.addr, self.data, self.mask), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def queue_len(self) -> int:
        return self.addr.shape[-1]


def empty_request(queue_len: int, word_width: int, dtype=jnp.float32) -> PortRequest:
    """An all-invalid request bundle (for disabled ports)."""
    return PortRequest(
        addr=jnp.zeros((queue_len,), jnp.int32),
        data=jnp.zeros((queue_len, word_width), dtype),
        mask=jnp.zeros((queue_len,), bool),
    )


def read_request(addr: jax.Array, word_width: int, dtype=jnp.float32,
                 mask: jax.Array | None = None) -> PortRequest:
    addr = jnp.asarray(addr, jnp.int32)
    if mask is None:
        mask = jnp.ones(addr.shape, bool)
    return PortRequest(addr=addr, data=jnp.zeros((*addr.shape, word_width), dtype), mask=mask)


def write_request(addr: jax.Array, data: jax.Array, mask: jax.Array | None = None) -> PortRequest:
    addr = jnp.asarray(addr, jnp.int32)
    if mask is None:
        mask = jnp.ones(addr.shape, bool)
    return PortRequest(addr=addr, data=data, mask=mask)
