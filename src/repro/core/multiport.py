"""MultiPortMemory — the paper's wrapper + SRAM macro, adapted to TPU.

Semantics (the contract all kernels/baselines are tested against):

* Storage is a word-addressable array ``[num_words, word_width]`` (the 6T SRAM
  macro). It may be viewed as ``[num_banks, words_per_bank, word_width]`` by
  kernels; banking is an implementation detail invisible to the semantics.
* One ``step`` is one macro-cycle (one external CLK period). Each of the up-to-4
  ports presents a queue of Q word transactions (addr, data, mask).
* Ports are serviced **strictly sequentially in priority order** (contention
  freedom, paper §II-A-3/4): a read port observes every write issued by
  higher-priority ports in the same macro-cycle, and none from lower-priority
  ports. Two write ports hitting the same word resolve to the lower-priority
  (later-serviced) port's value.
* Within one write port's queue, duplicate addresses resolve in queue order
  (last valid lane wins) — the vectorized extension of "one word per internal
  clock" (DESIGN.md §2 delta 1).
* Masked-off lanes issue no transaction; reads of masked lanes return 0.

``step`` below is the executable specification in pure jnp (also the oracle for
the Pallas kernel in ``repro.kernels.multiport_sram``). ``step_banked`` is the
performance path that dispatches to the Pallas kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fsm
from repro.core.ports import (MAX_PORTS, WRITE, PortConfig, PortRequest,
                              empty_request)


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    """Static geometry of the physical macro."""

    num_words: int
    word_width: int
    dtype: jnp.dtype = jnp.float32
    num_banks: int = 8

    def __post_init__(self):
        if self.num_words % self.num_banks:
            raise ValueError("num_words must divide evenly into banks")

    @property
    def words_per_bank(self) -> int:
        return self.num_words // self.num_banks

    def init_storage(self, value: float = 0.0) -> jax.Array:
        return jnp.full((self.num_words, self.word_width), value, self.dtype)

    def nbytes(self) -> int:
        return self.num_words * self.word_width * jnp.dtype(self.dtype).itemsize


def _dedup_last_wins(addr: jax.Array, mask: jax.Array) -> jax.Array:
    """Keep only the last valid occurrence of each address (queue order)."""
    # has_later[i] = exists j > i with addr[j] == addr[i] and mask[j]
    same = (addr[None, :] == addr[:, None]) & mask[None, :]
    later = jnp.triu(same, k=1)                     # j > i
    has_later = later.any(axis=1)
    return mask & ~has_later


def _service_write(storage: jax.Array, req: PortRequest, num_words: int) -> jax.Array:
    eff_mask = _dedup_last_wins(req.addr, req.mask)
    # Out-of-range address == dropped transaction: masked lanes are routed OOB.
    addr_eff = jnp.where(eff_mask, req.addr, num_words)
    return storage.at[addr_eff].set(req.data.astype(storage.dtype), mode="drop")


def _service_read(storage: jax.Array, req: PortRequest, num_words: int) -> jax.Array:
    addr_eff = jnp.where(req.mask, req.addr, num_words)
    out = storage.at[addr_eff].get(mode="fill", fill_value=0)
    return out


def step(spec: MemorySpec, config: PortConfig, storage: jax.Array,
         requests: Sequence[PortRequest]) -> tuple[jax.Array, list[jax.Array]]:
    """One macro-cycle: service all enabled ports in priority order.

    Args:
      spec: memory geometry.
      config: static port configuration.
      storage: ``[num_words, word_width]``.
      requests: MAX_PORTS request bundles (disabled ports' entries ignored).

    Returns:
      (new_storage, reads) where reads[p] is ``[Q, word_width]`` for read
      ports and zeros for write/disabled ports.
    """
    if len(requests) != MAX_PORTS:
        raise ValueError(f"expected {MAX_PORTS} request bundles")
    q = requests[0].queue_len
    reads = [jnp.zeros((q, spec.word_width), spec.dtype) for _ in range(MAX_PORTS)]

    def service(state, port):
        storage, reads = state
        req = requests[port]
        if config.roles[port] == WRITE:
            storage = _service_write(storage, req, spec.num_words)
        else:
            reads = list(reads)
            reads[port] = _service_read(storage, req, spec.num_words)
        return (storage, reads)

    storage, reads = fsm.walk_static(config, (storage, reads), service)
    return storage, list(reads)


def step_banked(spec: MemorySpec, config: PortConfig, storage: jax.Array,
                requests: Sequence[PortRequest], *, interpret: bool = True
                ) -> tuple[jax.Array, list[jax.Array]]:
    """Performance path: one physical traversal services all ports (Pallas)."""
    from repro.kernels import ops  # local import: kernels depend on core

    return ops.multiport_step(spec, config, storage, list(requests),
                              interpret=interpret)


def pack_requests(config: PortConfig, queue_len: int, spec: MemorySpec,
                  **per_port: PortRequest) -> list[PortRequest]:
    """Build the MAX_PORTS request list from keyword ports 'a'..'d'."""
    names = "abcd"
    out = []
    for i in range(MAX_PORTS):
        req = per_port.get(names[i])
        if req is None:
            req = empty_request(queue_len, spec.word_width, spec.dtype)
        out.append(req)
    return out


# ---------------------------------------------------------------------------
# Reference simulator (plain Python/numpy) — the ground truth for property
# tests. Deliberately scalar and boring: services ports in priority order,
# lanes in queue order, exactly like the hardware walks internal clock slots.
# ---------------------------------------------------------------------------

def reference_step(spec: MemorySpec, config: PortConfig, storage: np.ndarray,
                   requests: Sequence[PortRequest]) -> tuple[np.ndarray, list[np.ndarray]]:
    storage = np.array(storage, copy=True)
    q = int(np.asarray(requests[0].addr).shape[0])
    reads = [np.zeros((q, spec.word_width), storage.dtype) for _ in range(MAX_PORTS)]
    for port in config.service_order():
        req = requests[port]
        addr = np.asarray(req.addr)
        data = np.asarray(req.data)
        mask = np.asarray(req.mask)
        if config.roles[port] == WRITE:
            for lane in range(q):                      # queue order: last wins
                if mask[lane] and 0 <= addr[lane] < spec.num_words:
                    storage[addr[lane]] = data[lane].astype(storage.dtype)
        else:
            for lane in range(q):
                if mask[lane] and 0 <= addr[lane] < spec.num_words:
                    reads[port][lane] = storage[addr[lane]]
    return storage, reads
