"""Baseline multi-port memory designs the paper compares against.

Software analogues of the comparison rows in Tables I/II (see DESIGN.md §2 for
the area-mapping caveats — transistor sharing inside an 8T/12T bitcell has no
software analogue, so footprints are reported as measured, next to the paper's
bitcell-area column):

* ``SinglePortNPass``  — the bare 6T macro without the wrapper: each enabled
  port is serviced by its own full storage traversal (N passes, 1x footprint).
  This is the *bandwidth* baseline for claim C1.
* ``ReplicatedReads``  — the classic bitcell-widening school ([4]-[9]): each
  extra read port is bought with a full storage replica kept coherent on every
  write (all replicas written). R read ports cost (1 + R - 1)x footprint; this
  is the *area* baseline for claim C2 (8T dual-port ~ 2 copies for 1R1W
  concurrency, 12T quad ~ 2x area in the paper's normalization).
* ``XorCoded``        — paper ref [11] (coding techniques): banks + one XOR
  parity bank provide one extra effective read port at 1 + 1/num_banks
  footprint; writes must update data + parity (write amplification 2x).

All three implement the same ``step`` contract as ``multiport.step`` so the
property suite can check semantic equivalence, while the benchmark harness
counts traversals/bytes for the bandwidth and footprint tables.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.multiport import (MemorySpec, _dedup_last_wins, _service_read,
                                  _service_write)
from repro.core.ports import MAX_PORTS, READ, WRITE, PortConfig, PortRequest


@dataclasses.dataclass(frozen=True)
class TrafficCounters:
    """Accounting used by benchmarks: physical traversals & bytes touched."""

    storage_traversals: int      # full HBM passes over the storage
    words_read: int              # gather lanes issued
    words_written: int           # scatter lanes issued (incl. replication/parity)
    footprint_words: int         # physical words allocated for the logical capacity


class SinglePortNPass:
    """Bare single-port macro: one traversal per enabled port (no wrapper)."""

    def __init__(self, spec: MemorySpec):
        self.spec = spec

    def init_storage(self) -> jax.Array:
        return self.spec.init_storage()

    def step(self, config: PortConfig, storage: jax.Array,
             requests: Sequence[PortRequest]) -> tuple[jax.Array, list[jax.Array]]:
        q = requests[0].queue_len
        reads = [jnp.zeros((q, self.spec.word_width), self.spec.dtype)
                 for _ in range(MAX_PORTS)]
        for port in config.service_order():
            req = requests[port]
            if config.roles[port] == WRITE:
                storage = _service_write(storage, req, self.spec.num_words)
            else:
                reads[port] = _service_read(storage, req, self.spec.num_words)
        return storage, reads

    def counters(self, config: PortConfig, queue_len: int) -> TrafficCounters:
        n = config.enabled_count
        nw = len(config.write_ports()) * queue_len
        nr = len(config.read_ports()) * queue_len
        return TrafficCounters(storage_traversals=n, words_read=nr,
                               words_written=nw,
                               footprint_words=self.spec.num_words)


class ReplicatedReads:
    """Bitcell-widening analogue: one replica per concurrent read port."""

    def __init__(self, spec: MemorySpec, n_read_ports: int):
        self.spec = spec
        self.n_replicas = max(1, n_read_ports)

    def init_storage(self) -> jax.Array:
        return jnp.stack([self.spec.init_storage()] * self.n_replicas)

    def step(self, config: PortConfig, storage: jax.Array,
             requests: Sequence[PortRequest]) -> tuple[jax.Array, list[jax.Array]]:
        q = requests[0].queue_len
        reads = [jnp.zeros((q, self.spec.word_width), self.spec.dtype)
                 for _ in range(MAX_PORTS)]
        read_ports = [p for p in config.service_order() if config.roles[p] == READ]
        replica_of = {p: i % self.n_replicas for i, p in enumerate(read_ports)}
        for port in config.service_order():
            req = requests[port]
            if config.roles[port] == WRITE:
                # Coherence: every replica takes the write.
                storage = jax.vmap(
                    lambda rep: _service_write(rep, req, self.spec.num_words)
                )(storage)
            else:
                reads[port] = _service_read(storage[replica_of[port]], req,
                                            self.spec.num_words)
        return storage, reads

    def counters(self, config: PortConfig, queue_len: int) -> TrafficCounters:
        nw = len(config.write_ports()) * queue_len * self.n_replicas
        nr = len(config.read_ports()) * queue_len
        return TrafficCounters(
            storage_traversals=1,  # replicas are "concurrent" hardware ports
            words_read=nr, words_written=nw,
            footprint_words=self.spec.num_words * self.n_replicas)


class XorCoded:
    """Coding-based multi-port (paper ref [11], simplified XOR-bank scheme).

    Storage is split into ``num_banks`` data banks plus one parity bank holding
    the XOR of the data banks (over bit patterns; we emulate with float add in
    a dedicated int view-free way by keeping parity = sum of banks, which has
    the same traffic/footprint profile). A second simultaneous read to a busy
    bank b is served by reading the other banks + parity and reconstructing.
    """

    def __init__(self, spec: MemorySpec):
        self.spec = spec
        self.num_banks = spec.num_banks

    def init_storage(self) -> jax.Array:
        wpb = self.spec.words_per_bank
        data = jnp.zeros((self.num_banks, wpb, self.spec.word_width), self.spec.dtype)
        parity = jnp.zeros((wpb, self.spec.word_width), self.spec.dtype)
        return (data, parity)

    def _flat(self, data: jax.Array) -> jax.Array:
        return data.reshape(self.spec.num_words, self.spec.word_width)

    def step(self, config: PortConfig, storage, requests):
        data, parity = storage
        q = requests[0].queue_len
        reads = [jnp.zeros((q, self.spec.word_width), self.spec.dtype)
                 for _ in range(MAX_PORTS)]
        wpb = self.spec.words_per_bank
        for port in config.service_order():
            req = requests[port]
            if config.roles[port] == WRITE:
                # duplicate in-queue addresses: only the last lane lands, so
                # the parity delta must telescope to (v_last - old)
                eff = _dedup_last_wins(req.addr, req.mask)
                flat = self._flat(data)
                old = flat.at[jnp.where(eff, req.addr, self.spec.num_words)].get(
                    mode="fill", fill_value=0)
                flat = _service_write(flat, req, self.spec.num_words)
                data = flat.reshape(self.num_banks, wpb, self.spec.word_width)
                # parity update: remove old contribution, add new (2x write traffic)
                delta = jnp.where(eff[:, None],
                                  req.data.astype(self.spec.dtype) - old, 0)
                offs = jnp.where(eff, req.addr % wpb, wpb)
                parity = parity.at[offs].add(delta, mode="drop")
            else:
                reads[port] = _service_read(self._flat(data), req, self.spec.num_words)
        return (data, parity), reads

    def counters(self, config: PortConfig, queue_len: int) -> TrafficCounters:
        nw = len(config.write_ports()) * queue_len * 2        # data + parity
        nr = len(config.read_ports()) * queue_len
        return TrafficCounters(
            storage_traversals=2,  # banked: ~2 effective concurrent ports
            words_read=nr, words_written=nw,
            footprint_words=self.spec.num_words + self.spec.words_per_bank)


def footprint_ratio(baseline_counters: TrafficCounters,
                    proposed_words: int) -> float:
    """Area-analogue ratio for Table II: baseline footprint / proposed."""
    return baseline_counters.footprint_words / proposed_words
