"""Clock generator — schedule builder for the multi-port wrapper.

Paper mapping (Fig. 3/4, §II-A-5): the clock generator divides the external
clock CLK into N internal slots based on the enabled-port count B1B0. Per
external cycle it emits:

  * ``BACK``  — N pulses: one memory access (SRAM macro strobe) per enabled port;
  * ``CLK2``  — N-1 pulses: the FSM state transitions between consecutive slots;
  * ``CLKP``  — 1 pulse at the CLK posedge: latches port inputs and async-resets
                 the FSM to the highest-priority enabled port.

On TPU there is no clock to divide (DESIGN.md §2, delta 3): the "internal
slots" become the sequential service slots inside one kernel traversal. This
module builds that schedule and also provides a cycle-accurate waveform
simulator used by tests to check the paper's Fig. 4 invariants.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.ports import PortConfig


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One macro-cycle's service schedule (the BACK/CLK2 analogue)."""

    slots: tuple[int, ...]        # port id serviced in each internal slot
    n_back_pulses: int            # == len(slots) == N
    n_clk2_pulses: int            # == N - 1 (state transitions between slots)
    b1b0: int                     # 2-bit enabled count encoding (N - 1)

    @property
    def n_ports(self) -> int:
        return self.n_back_pulses


def build_schedule(config: PortConfig) -> Schedule:
    """Expand a PortConfig into the per-macro-cycle service schedule."""
    slots = config.service_order()
    n = len(slots)
    return Schedule(slots=slots, n_back_pulses=n, n_clk2_pulses=n - 1, b1b0=n - 1)


@dataclasses.dataclass
class Waveform:
    """Discrete waveform over internal time steps (numpy, test-only).

    Each external CLK cycle is divided into ``resolution`` internal steps; we
    record pulse trains as 0/1 arrays, mirroring the paper's Fig. 4 signals.
    """

    clk: np.ndarray
    clkp: np.ndarray
    back: np.ndarray
    clk2: np.ndarray
    selected_port: np.ndarray  # port id driving the macro at each internal step


def simulate_waveform(configs: Sequence[PortConfig], resolution: int = 8) -> Waveform:
    """Simulate the clock generator over one external CLK cycle per config.

    Mirrors the paper's Fig. 4 experiment, where successive CLK cycles are
    configured as 4-port, 3-port, 2-port and 1-port.
    """
    n_cycles = len(configs)
    t = n_cycles * resolution
    clk = np.zeros(t, np.int8)
    clkp = np.zeros(t, np.int8)
    back = np.zeros(t, np.int8)
    clk2 = np.zeros(t, np.int8)
    sel = np.full(t, -1, np.int32)

    for c, cfg in enumerate(configs):
        base = c * resolution
        clk[base: base + resolution // 2] = 1          # high half of external clock
        clkp[base] = 1                                  # posedge spike
        sched = build_schedule(cfg)
        n = sched.n_back_pulses
        # N equal internal slots inside this cycle; BACK pulses at each slot
        # start; CLK2 pulses at each slot boundary (N-1 of them).
        slot_starts = [base + (k * resolution) // n for k in range(n)]
        for k, s in enumerate(slot_starts):
            back[s] = 1
            if k > 0:
                clk2[s] = 1
            end = base + ((k + 1) * resolution) // n if k + 1 < n else base + resolution
            sel[s:end] = sched.slots[k]
    return Waveform(clk=clk, clkp=clkp, back=back, clk2=clk2, selected_port=sel)


def effective_access_rate(config: PortConfig, external_clock_hz: float) -> float:
    """The paper's headline metric: memory-access frequency seen by the macro.

    4 enabled ports at CLK=250 MHz => 1 GHz effective access rate (Table II).
    """
    return external_clock_hz * build_schedule(config).n_back_pulses
