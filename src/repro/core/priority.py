"""Priority encoder — static (trace-time) and dynamic (in-graph) variants.

Paper mapping (Fig. 1, §II-A-3): the priority encoder assigns a fixed priority
(default A > B > C > D) to the enabled ports; its output asynchronously loads
the FSM back to the highest-priority enabled port at every external-clock edge.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.core.ports import MAX_PORTS


def encode_static(enabled: Sequence[bool], priority: Sequence[int]) -> int:
    """Index of the highest-priority enabled port (trace-time)."""
    for p in priority:
        if enabled[p]:
            return p
    raise ValueError("no port enabled")


def order_static(enabled: Sequence[bool], priority: Sequence[int]) -> tuple[int, ...]:
    """All enabled ports, highest priority first (trace-time)."""
    return tuple(p for p in priority if enabled[p])


def complete_priority(order: Sequence[int], n: int = MAX_PORTS) -> tuple[int, ...]:
    """Extend a service order over a subset of ports to a full priority
    permutation of ``range(n)``: the listed ports keep their relative order
    (highest priority first) and the remaining ids follow in ascending order.
    This is how the scheduler turns a traversal's program-order port list
    into a :class:`~repro.core.ports.PortConfig` priority field."""
    order = tuple(order)
    if len(set(order)) != len(order) or any(p < 0 or p >= n for p in order):
        raise ValueError(f"order must be distinct port ids in 0..{n-1}: {order}")
    return order + tuple(p for p in range(n) if p not in order)


def encode_dynamic(enabled_mask: jnp.ndarray, priority: jnp.ndarray) -> jnp.ndarray:
    """In-graph priority encoder.

    Args:
      enabled_mask: bool[MAX_PORTS], indexed by port id.
      priority: int32[MAX_PORTS] permutation; priority[k] = port id with rank k.

    Returns:
      int32 scalar: highest-priority enabled port id. If nothing is enabled
      (cannot happen through PortConfig) returns priority[-1].
    """
    ranked_enabled = enabled_mask[priority]                     # bool, rank-indexed
    rank = jnp.argmax(ranked_enabled)                           # first True rank
    return priority[rank].astype(jnp.int32)


def rank_of(priority: jnp.ndarray) -> jnp.ndarray:
    """Inverse permutation: rank_of(priority)[port] = rank of that port."""
    inv = jnp.zeros((MAX_PORTS,), jnp.int32)
    return inv.at[priority].set(jnp.arange(MAX_PORTS, dtype=jnp.int32))


def next_port_dynamic(current: jnp.ndarray, enabled_mask: jnp.ndarray,
                      priority: jnp.ndarray) -> jnp.ndarray:
    """In-graph FSM transition: next enabled port after ``current`` in priority
    order, wrapping to the highest-priority enabled port (Fig. 2)."""
    ranks = rank_of(priority)
    cur_rank = ranks[current]
    ranked_enabled = enabled_mask[priority]
    idx = jnp.arange(MAX_PORTS)
    # Candidate ranks strictly after the current rank.
    later = ranked_enabled & (idx > cur_rank)
    has_later = jnp.any(later)
    next_rank_later = jnp.argmax(later)          # first True among later ranks
    first_rank = jnp.argmax(ranked_enabled)      # wrap target
    nxt_rank = jnp.where(has_later, next_rank_later, first_rank)
    return priority[nxt_rank].astype(jnp.int32)
