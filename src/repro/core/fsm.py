"""Finite state machine — the port-walk sequencer.

Paper mapping (Fig. 2, §II-A-4): the FSM transitions between enabled ports in
priority order, one SRAM access per internal slot, and is asynchronously reset
to the highest-priority enabled port at each external CLK posedge.

Two realizations:

* ``walk_static``   — trace-time unrolled walk (used by ``multiport.step``; the
  port count is <= 4 so unrolling is free and lets XLA fuse the slot bodies).
* ``walk_dynamic``  — in-graph walk via ``lax.scan`` over MAX_PORTS slots with a
  dynamic enable mask; used where the port configuration is itself traced
  (e.g. the serving engine reconfigures ports per request batch without
  retracing).
"""
from __future__ import annotations

from typing import Callable, Sequence, TypeVar

import jax
import jax.numpy as jnp

from repro.core import priority as prio
from repro.core.ports import MAX_PORTS, PortConfig

S = TypeVar("S")
P = TypeVar("P")


class PhaseError(ValueError):
    """Raised when a phase counter is driven outside its legal domain.

    The external phase counter counts CLK posedges since the engine started,
    so it is monotonically non-negative; a negative value indicates the
    caller's cycle accounting went backwards, which Python's modulo would
    silently mask (``-1 % 4 == 3``)."""


def walk_static(config: PortConfig, state: S,
                service: Callable[[S, int], S]) -> S:
    """Visit each enabled port once, in priority order (one macro-cycle).

    Args:
      config: static port configuration.
      state: carried state (e.g. (storage, read_outputs)).
      service: slot body; called as service(state, port_id) for each slot.
    """
    for port in config.service_order():
        state = service(state, port)
    return state


def rotate_single_port(schedule: tuple[int, ...], phase: int
                       ) -> tuple[int, ...]:
    """Bare-macro degradation of a macro-cycle schedule: service ONE slot per
    external CLK, round-robin over the enabled ports (the paper's 1-port
    baseline — the FSM never advances past its reset state within a cycle).

    ``schedule`` is a :func:`~repro.core.clockgen.build_schedule` slot tuple;
    ``phase`` counts external cycles since the engine started. Phases beyond
    ``len(schedule)`` wrap (round-robin); negative phases raise
    :class:`PhaseError` rather than leaning on Python's modulo semantics.
    """
    if not schedule:
        raise ValueError("cannot rotate an empty schedule")
    if phase < 0:
        raise PhaseError(f"phase counter must be non-negative, got {phase}")
    return (schedule[phase % len(schedule)],)


def walk_schedule(schedule: Sequence[tuple[PortConfig, P]], state: S,
                  service: Callable[[S, P, PortConfig], S]) -> S:
    """Drive a macro-cycle from a *schedule* instead of one fixed config.

    Generalization of :func:`walk_static`: a schedule is an ordered sequence
    of pool traversals, each carrying its own :class:`PortConfig` (the
    per-cycle enabled-port set, R/W roles and priority chosen by the
    dependency scheduler) plus an opaque payload (the transactions to issue
    on those ports). ``service(state, payload, config)`` is called once per
    traversal, in schedule order — program order between hazarding
    traversals is therefore preserved by construction.
    """
    for config, payload in schedule:
        state = service(state, payload, config)
    return state


def walk_dynamic(enabled_mask: jax.Array, priority_perm: jax.Array, state: S,
                 service: Callable[[S, jax.Array, jax.Array], S]) -> S:
    """In-graph walk: always runs MAX_PORTS slots; disabled slots are no-ops.

    ``service(state, port_id, active)`` must be a no-op when ``active`` is
    False (the caller typically masks its scatter/gather with ``active``).

    The slot->port mapping is computed exactly as the hardware does it: slot k
    services the k-th enabled port in priority order; trailing slots (k >= N)
    are idle (active=False).
    """
    ranked_enabled = enabled_mask[priority_perm]                    # bool by rank
    # slot k -> rank of k-th enabled rank; stable order of enabled ranks first.
    order = jnp.argsort(~ranked_enabled, stable=True)               # enabled ranks first
    slot_ports = priority_perm[order]                               # port ids per slot
    slot_active = ranked_enabled[order]                             # validity per slot

    def body(carry, slot):
        port_id, active = slot
        return service(carry, port_id, active), None

    state, _ = jax.lax.scan(body, state, (slot_ports, slot_active))
    return state


def reset_state(enabled_mask: jax.Array, priority_perm: jax.Array) -> jax.Array:
    """CLKP posedge behaviour: async load of the highest-priority enabled port."""
    return prio.encode_dynamic(enabled_mask, priority_perm)


def transition(current: jax.Array, enabled_mask: jax.Array,
               priority_perm: jax.Array) -> jax.Array:
    """CLK2 posedge behaviour: advance to the next enabled port (Fig. 2)."""
    return prio.next_port_dynamic(current, enabled_mask, priority_perm)


def walk_order_dynamic(enabled_mask: jax.Array, priority_perm: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Return (slot_ports int32[MAX_PORTS], slot_active bool[MAX_PORTS]).

    Convenience used by kernels that need the schedule as arrays.
    """
    ranked_enabled = enabled_mask[priority_perm]
    order = jnp.argsort(~ranked_enabled, stable=True)
    return priority_perm[order].astype(jnp.int32), ranked_enabled[order]
