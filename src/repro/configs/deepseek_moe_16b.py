"""deepseek-moe-16b — fine-grained MoE: 2 shared + 64 routed top-6.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ArchConfig, MoEConfig

ARCH_ID = "deepseek-moe-16b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=102400, rope_theta=10000.0,
        moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                      capacity_factor=1.25),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        notes="Fine-grained experts (d_expert = d_ff = 1408).",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2,
                      capacity_factor=2.0),
        q_chunk=16, la_chunk=8,
    )
