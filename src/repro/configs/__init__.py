"""Architecture configs (10 assigned + reduced smoke variants) and shapes."""
from repro.configs.base import (ArchConfig, HybridConfig, LM_SHAPES, MoEConfig,
                                RwkvConfig, ShapeCell, SSMConfig,
                                applicable_shapes)

__all__ = ["ArchConfig", "HybridConfig", "LM_SHAPES", "MoEConfig",
           "RwkvConfig", "ShapeCell", "SSMConfig", "applicable_shapes"]
