"""qwen2-0.5b — dense GQA with QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ArchConfig

ARCH_ID = "qwen2-0.5b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151936, qkv_bias=True, head_dim=64,
        rope_theta=1000000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
        d_ff=128, vocab=256, qkv_bias=True, head_dim=8,
        q_chunk=16, la_chunk=8,
    )
