"""llama3-405b — dense GQA frontier model. [arXiv:2407.21783; unverified]

Memory note (EXPERIMENTS.md §Dry-run): AdamW fp32 states alone are 3.24 TB;
the training config therefore defaults to the int8 quantized optimizer
(optim.quantized) and FSDP over ("pod", "data")."""
from repro.configs.base import ArchConfig

ARCH_ID = "llama3-405b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab=128256, rope_theta=500000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="dense",
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=192, vocab=256,
        q_chunk=16, la_chunk=8,
    )
