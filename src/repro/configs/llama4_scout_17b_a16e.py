"""llama4-scout-17b-a16e — MoE, 16 routed experts top-1 + 1 shared.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
Early-fusion multimodality is a frontend concern; the text backbone below is
what trains/serves (DESIGN.md §5)."""
from repro.configs.base import ArchConfig, MoEConfig

ARCH_ID = "llama4-scout-17b-a16e"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, rope_theta=500000.0,
        moe=MoEConfig(n_experts=16, top_k=1, d_expert=8192, n_shared=1,
                      capacity_factor=1.25),
        param_dtype="bfloat16", compute_dtype="bfloat16",
        notes="MoE every layer; 1 shared + top-1 of 16 routed (HF config).",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256,
        moe=MoEConfig(n_experts=4, top_k=1, d_expert=32, n_shared=1,
                      capacity_factor=2.0),
        q_chunk=16, la_chunk=8,
    )
