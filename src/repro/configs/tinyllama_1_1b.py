"""tinyllama-1.1b — llama2-architecture small model. [arXiv:2401.02385; hf]"""
from repro.configs.base import ArchConfig

ARCH_ID = "tinyllama-1.1b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab=32000, rope_theta=10000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=128, vocab=256,
        q_chunk=16, la_chunk=8,
    )
