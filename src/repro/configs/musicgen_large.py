"""musicgen-large — decoder-only over EnCodec tokens; the audio frontend
(EnCodec + codebook interleaving) is a stub supplying frame embeddings.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ArchConfig

ARCH_ID = "musicgen-large"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=2048, pos_embed="sinusoidal",
        input_mode="embeddings",
        param_dtype="bfloat16", compute_dtype="bfloat16",
        notes="MHA (kv == heads); sinusoidal absolute positions.",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, pos_embed="sinusoidal",
        input_mode="embeddings",
        q_chunk=16, la_chunk=8,
    )
