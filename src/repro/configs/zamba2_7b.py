"""zamba2-7b — hybrid: Mamba2 backbone + one SHARED attention block applied
before every group of 6 Mamba2 layers. [arXiv:2411.15242; unverified]

81 layer slots = 13 groups x 6 Mamba2 + 3 tail Mamba2; the shared
transformer block (attn + MLP) is applied 13 times with one parameter set
(see DESIGN.md §5 for deviations from the released checkpoint)."""
from repro.configs.base import ArchConfig, HybridConfig, SSMConfig

ARCH_ID = "zamba2-7b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab=32000, rope_theta=10000.0,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4),
        hybrid=HybridConfig(group_size=6, attn_d_ff=14336),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="hybrid",
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        ssm=SSMConfig(state_dim=16, head_dim=16),
        hybrid=HybridConfig(group_size=2, attn_d_ff=128),
        q_chunk=16, la_chunk=8,
    )
