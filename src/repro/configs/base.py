"""Architecture & shape configuration system.

``ArchConfig`` fully describes a model; ``ShapeCell`` describes one
(seq_len, global_batch, kind) workload cell. The 10 assigned architectures
live in sibling modules, registered in ``registry.py``; each provides both the
full published config and a ``reduced()`` variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence

import jax.numpy as jnp

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN width
    n_shared: int = 0             # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 geometry."""
    state_dim: int = 64           # N
    head_dim: int = 64            # P
    expand: int = 2
    conv_kernel: int = 4
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class RwkvConfig:
    """RWKV6 (Finch) geometry."""
    head_dim: int = 64
    lora_dim: int = 64            # data-dependent decay LoRA rank


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: one SHARED attention block applied before every
    group of ``group_size`` Mamba2 layers (plus leftover Mamba2 layers)."""
    group_size: int = 6
    attn_d_ff: int = 14336        # the shared block's MLP width


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    head_dim: Optional[int] = None              # default d_model // n_heads
    pos_embed: Literal["rope", "mrope", "sinusoidal"] = "rope"
    rope_theta: float = 10000.0
    mrope_sections: Sequence[int] = (16, 24, 24)
    input_mode: Literal["tokens", "embeddings"] = "tokens"
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RwkvConfig] = None
    hybrid: Optional[HybridConfig] = None
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    q_chunk: int = 1024                          # attention query chunking
    la_chunk: int = 64                           # linear-attention chunk
    remat: Literal["none", "block"] = "block"
    z_loss: float = 1e-4
    notes: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (SSM/hybrid/linear-attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND roofline terms)."""
        d, v = self.d_model, self.vocab
        total = v * d                                     # lm_head
        if self.input_mode == "tokens":
            total += v * d                                # embed table
        hd = self.head_dim_
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.family == "ssm":                          # rwkv6
            tm = 5 * d * d                                # r,k,v,g,o
            lora = 2 * self.rwkv.lora_dim * d + d         # decay lora + w0
            cm = 2 * d * self.d_ff + d * d                # wk, wv, wr
            total += self.n_layers * (tm + lora + cm + 4 * d)
            return total
        if self.family == "hybrid":
            ssm = self.ssm
            d_in = ssm.expand * d
            conv_dim = d_in + 2 * ssm.state_dim
            nheads = d_in // ssm.head_dim
            in_proj = d * (2 * d_in + 2 * ssm.state_dim + nheads)
            mamba = in_proj + conv_dim * ssm.conv_kernel + d_in * d + 2 * nheads + d_in
            total += self.n_layers * (mamba + 2 * d)
            shared = attn + 3 * d * self.hybrid.attn_d_ff + 2 * d
            total += shared                               # shared block counted once
            return total
        ffn = 3 * d * self.d_ff
        if self.moe is not None:
            m = self.moe
            ffn = (m.n_experts * 3 * d * m.d_expert + d * m.n_experts
                   + (3 * d * m.n_shared * m.d_expert if m.n_shared else 0))
        total += self.n_layers * (attn + ffn + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k); == param_count for dense."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        full_ffn = m.n_experts * 3 * d * m.d_expert
        active_ffn = m.top_k * 3 * d * m.d_expert
        return (self.param_count()
                - self.n_layers * (full_ffn - active_ffn))


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


LM_SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def applicable_shapes(cfg: ArchConfig) -> list[ShapeCell]:
    """Shape cells this arch actually runs (long_500k needs sub-quadratic)."""
    out = []
    for cell in LM_SHAPES:
        if cell.name == "long_500k" and not cfg.sub_quadratic:
            continue  # skip noted in DESIGN.md §5
        out.append(cell)
    return out
