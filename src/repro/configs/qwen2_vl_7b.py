"""qwen2-vl-7b — VLM backbone with M-RoPE; the vision frontend is a stub
(input_specs supplies precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ArchConfig

ARCH_ID = "qwen2-vl-7b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="vlm",
        n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
        d_ff=18944, vocab=152064, qkv_bias=True, head_dim=128,
        pos_embed="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1000000.0, input_mode="embeddings",
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, qkv_bias=True, head_dim=16,
        pos_embed="mrope", mrope_sections=(4, 2, 2),
        input_mode="embeddings",
        q_chunk=16, la_chunk=8,
    )
