"""rwkv6-3b (Finch) — attention-free RNN with data-dependent decay.
[arXiv:2404.05892; hf]

n_heads/n_kv_heads are nominal (d_model / rwkv.head_dim); there is no
attention. The paper's KV-cache technique is inapplicable here (O(1) state,
one reader + one writer) — see DESIGN.md §5."""
from repro.configs.base import ArchConfig, RwkvConfig

ARCH_ID = "rwkv6-3b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab=65536,
        rwkv=RwkvConfig(head_dim=64, lora_dim=64),
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256,
        rwkv=RwkvConfig(head_dim=16, lora_dim=8),
        q_chunk=16, la_chunk=8,
    )
