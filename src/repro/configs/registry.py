"""Registry of the 10 assigned architectures: ``get(arch_id, reduced=...)``."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

_MODULES = {
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "llama3-405b": "repro.configs.llama3_405b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str, *, reduced: bool = False) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {sorted(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.reduced() if reduced else mod.full()


def all_configs(*, reduced: bool = False) -> dict[str, ArchConfig]:
    return {a: get(a, reduced=reduced) for a in ARCH_IDS}
