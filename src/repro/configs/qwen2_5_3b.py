"""qwen2.5-3b — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-3B; hf]"""
from repro.configs.base import ArchConfig

ARCH_ID = "qwen2.5-3b"


def full() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID, family="dense",
        n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2,
        d_ff=11008, vocab=151936, qkv_bias=True, rope_theta=1000000.0,
        param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        arch_id=ARCH_ID + "-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, qkv_bias=True,
        q_chunk=16, la_chunk=8,
    )
