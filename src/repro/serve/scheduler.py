"""Dependency-tracked macro-cycle port scheduler.

The paper's headline is port CONFIGURABILITY: 1-4 ports in any R/W mix,
chosen per cycle to match the traffic. The engine's old control plane kept
the mix rigid — every macro-cycle walked EVICT > PREFILL > DECODE in a
fixed phase order and the pool contract assumed 1W+1R. This module makes
the mix a per-cycle DECISION: each engine phase becomes a transaction
bundle with a page-granular footprint, and :func:`plan` packs
non-hazarding phases into shared pool traversals, emitting a
:class:`PortSchedule` whose every traversal carries its own
:class:`~repro.core.ports.PortConfig` (enabled set, roles, and a priority
permutation equal to program order).

Hazard rules, at page granularity, between a program-earlier phase ``a``
and a later phase ``b``:

* **port collision** — both phases need the same physical port: split.
* **RAW** (``a`` writes a page ``b`` reads) and **WAR** (``a`` reads a
  page ``b`` writes): NEVER co-scheduled. Same-page prefill-then-decode
  must stay two traversals even though in-traversal service order would
  happen to read-after-write correctly — the conservative split is the
  architectural contract (and what the hazard tests pin down).
* **WAW** (both write an overlapping page) — co-schedulable: the
  traversal's priority is program order, so the later phase's words
  land last. This is also a bug fix over the old fixed pool priority
  (APPEND serviced before SCRUB), under which a decode append landing on
  a page freed in the SAME cycle was zeroed by that page's scrub.
* Intra-phase pairs are exempt by construction (a phase's own append+read
  stay one :class:`PhaseTxn`; the traversal service order — writes before
  reads in program order — IS the fused kernel's same-cycle W->R
  contract).

**Refcounted page sharing (PR 9).** With copy-on-write prefix sharing a
page can appear in MANY sequences' tables, so the same physical page now
shows up in several phases' READ footprints in one cycle — that is RAR,
co-schedulable by the rules above, and exactly the point: N decodes
attending over one shared system-prompt page ride one traversal. The
hazard analysis needs NO special case because shared pages are
read-shared / write-private by construction upstream: the pool never
lets a write land on a refcount>1 page — the appender's footprint
(``project_write_pages``) carries the FRESH CoW page it will remap to,
and the CoW copy itself is extra W-port lanes inside that same phase's
write transaction (same traversal, same port, same commit). A write
footprint therefore only ever contains write-private pages, and the
RAW/WAR rules keep doing their job against the readers unchanged.

``mode="static"`` keeps the old rigid walk as the oracle: one traversal
per phase, program order, no co-scheduling. ``max_ports`` (1-4) bounds a
traversal's port count — the paper's B1B0 knob; phases wider than the
budget pre-split into single-transaction units. ``split_roles=True``
post-splits every traversal into a writes-traversal followed by a
reads-traversal (the two-pass reference / bare-macro pool discipline).

**Pipelining (PR 7).** :func:`plan` is pure host-side work over page-id
footprints — it never touches device buffers — so the engine's async step
loop plans cycle N's schedule while cycle N-1's dispatched decode is still
executing on device (the dispatch is retired at the START of the next
step). That placement is load-bearing for the planner's inputs staying
valid: the phase footprints are computed from host page tables, which the
in-flight cycle never mutates (all table updates happen at commit, before
the next plan). The traversal count this module emits is also the serving
harness's TIME BASE: the open-loop bench's virtual clock advances one tick
per committed pool traversal, so a mode that plans more traversals per
macro-cycle (``static``) pays for them directly in measured TTFT tail.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.ports import MAX_PORTS, READ, WRITE, PortConfig
from repro.core.priority import complete_priority


@dataclasses.dataclass(frozen=True)
class PortTxn:
    """One port transaction: a role-tagged page footprint plus the opaque
    stream payload the engine will commit on that port."""

    port: int                      # physical pool port id
    role: int                      # READ / WRITE
    pages: frozenset               # page-granular footprint
    payload: object = None         # opaque stream bundle (engine-owned)


@dataclasses.dataclass(frozen=True)
class PhaseTxn:
    """One engine phase's transactions; ``phase`` is its program-order
    position (the engine's logical port id), which doubles as the hazard
    ordering key."""

    phase: int
    label: str
    txns: tuple                    # tuple[PortTxn, ...] in program order

    def ports(self) -> tuple:
        return tuple(t.port for t in self.txns)


@dataclasses.dataclass(frozen=True)
class Traversal:
    """One physical pool traversal: the phases co-scheduled into it, in
    program order."""

    phases: tuple                  # tuple[PhaseTxn, ...]

    def txns(self) -> tuple:
        return tuple(t for ph in self.phases for t in ph.txns)

    def ports(self) -> tuple:
        return tuple(t.port for t in self.txns())

    def priority(self) -> tuple:
        """Full priority permutation: program order first (earlier phases
        serviced first — WAW order preservation and writes-before-reads),
        remaining port ids appended in ascending order."""
        return complete_priority(self.ports())

    def port_config(self) -> PortConfig:
        """The per-traversal port mix as a validated PortConfig — the
        paper's per-cycle configurability decision, made by the scheduler
        instead of a fixed wiring."""
        enabled = [False] * MAX_PORTS
        roles = [READ] * MAX_PORTS
        for t in self.txns():
            enabled[t.port] = True
            roles[t.port] = t.role
        return PortConfig(enabled=tuple(enabled), roles=tuple(roles),
                          priority=self.priority())

    def phase_ids(self) -> tuple:
        return tuple(ph.phase for ph in self.phases)


@dataclasses.dataclass(frozen=True)
class PortSchedule:
    """The plan for one macro-cycle: ordered pool traversals, each with its
    own port mix."""

    mode: str
    max_ports: int
    traversals: tuple              # tuple[Traversal, ...]

    @property
    def co_scheduled(self) -> bool:
        """True when any traversal services more than one engine phase —
        the cycle saved at least one pool traversal vs the rigid walk."""
        return any(len(set(t.phase_ids())) > 1 for t in self.traversals)


def conflicts(a: PhaseTxn, b: PhaseTxn) -> Optional[str]:
    """Hazard between program-earlier phase ``a`` and later phase ``b``
    if they shared a traversal: ``"port"`` / ``"raw"`` / ``"war"``, or
    None when co-scheduling is safe (disjoint pages, RAR, or WAW —
    program-order priority preserves write order)."""
    if set(a.ports()) & set(b.ports()):
        return "port"
    for ta in a.txns:
        for tb in b.txns:
            if ta.pages.isdisjoint(tb.pages):
                continue
            if ta.role == WRITE and tb.role == READ:
                return "raw"
            if ta.role == READ and tb.role == WRITE:
                return "war"
    return None


def _split_by_role(trav: Traversal) -> list:
    """Two-pass discipline: the traversal's W transactions, then its R
    transactions, each as their own traversal (program order preserved
    within both)."""
    out = []
    for role in (WRITE, READ):
        phases = []
        for ph in trav.phases:
            sel = tuple(t for t in ph.txns if t.role == role)
            if sel:
                phases.append(ph if sel == ph.txns
                              else PhaseTxn(ph.phase, ph.label, sel))
        if phases:
            out.append(Traversal(tuple(phases)))
    return out


def plan(phases: Sequence[PhaseTxn], *, mode: str = "ooo",
         max_ports: int = MAX_PORTS, split_roles: bool = False
         ) -> PortSchedule:
    """Schedule one macro-cycle's phases onto pool traversals.

    ``phases`` must arrive in program order (ascending ``phase``). In
    ``"ooo"`` mode each phase greedily joins the LAST open traversal when
    (a) no port collides, (b) the combined port count fits ``max_ports``,
    and (c) it has no RAW/WAR hazard against ANY phase already in it —
    joining an EARLIER traversal is never attempted, since issuing before
    the traversal it conflicted with would invert program order.
    ``"static"`` is the rigid-walk oracle: one traversal per phase.
    """
    if mode not in ("static", "ooo"):
        raise ValueError(f"unknown schedule mode: {mode!r}")
    if not 1 <= max_ports <= MAX_PORTS:
        raise ValueError(f"max_ports must be in 1..{MAX_PORTS}, got {max_ports}")
    order = [ph.phase for ph in phases if ph.txns]
    if order != sorted(order):
        raise ValueError(f"phases must arrive in program order, got {order}")

    units: list[PhaseTxn] = []
    for ph in phases:
        if not ph.txns:
            continue
        if len(ph.txns) > max_ports:
            # port budget narrower than the phase: issue its transactions
            # one traversal each, program order (the 1-port degradation)
            units.extend(PhaseTxn(ph.phase, f"{ph.label}[{i}]", (t,))
                         for i, t in enumerate(ph.txns))
        else:
            units.append(ph)

    groups: list[list[PhaseTxn]] = []
    for u in units:
        if mode == "ooo" and groups:
            g = groups[-1]
            ports = {p for ph in g for p in ph.ports()}
            if (len(ports | set(u.ports())) <= max_ports
                    and all(conflicts(ph, u) is None for ph in g)):
                g.append(u)
                continue
        groups.append([u])

    travs = [Traversal(tuple(g)) for g in groups]
    if split_roles:
        travs = [s for t in travs for s in _split_by_role(t)]
    return PortSchedule(mode=mode, max_ports=max_ports,
                        traversals=tuple(travs))
