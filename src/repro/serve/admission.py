"""Host-side admission control for the multi-port serving engine.

The engine used to pop admissions straight off a ``deque`` inside its
prefill phase — workable closed-loop, but the open-loop traffic harness
needs admission to be a first-class HOST-side decision, decoupled from the
device macro-cycle: requests arrive on a virtual-clock schedule (see
``serve/traffic.py``), wait here while slots are contended, and are
admitted when capacity frees up. Keeping the queue its own object also
pins the architectural invariant the regression tests check:

**Admission follows ARRIVAL order (FIFO) under slot contention.** When
several queued requests compete for one freed slot, the OLDEST ready
request wins — :meth:`pop_ready` only ever surfaces the queue head, never
a younger request that happens to look cheaper (shorter prompt, fewer
pages). A ready-set implementation that re-ordered by readiness or size
would systematically starve long-prompt requests behind a stream of short
ones; head-of-line blocking is the contract, and
``tests/serve/test_admission.py`` pins it.

The queue measures itself: ``peak_depth`` (most requests ever waiting),
``admitted``, and per-request wait stamps land on the request objects
themselves (``admit_cycle`` / ``admit_tick``), which the open-loop bench
turns into queue-delay percentiles. Requests only need ``arrival_tick``
(virtual-clock arrival time) — the queue is generic over the payload.
"""
from __future__ import annotations

from collections import deque
from typing import Optional


class AdmissionQueue:
    """Arrival-ordered FIFO of submitted-but-not-admitted requests."""

    def __init__(self):
        self._q: deque = deque()
        self.peak_depth = 0
        self.submitted = 0
        self.admitted = 0

    def push(self, req) -> None:
        """Enqueue in submission order (== arrival order: callers submit as
        the traffic schedule fires, and ties share the submission order)."""
        self._q.append(req)
        self.submitted += 1
        self.peak_depth = max(self.peak_depth, len(self._q))

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def head(self):
        """The oldest queued request (None when empty) — the ONLY request
        eligible for the next admission."""
        return self._q[0] if self._q else None

    def head_ready(self, now: float) -> bool:
        """True when the oldest queued request has arrived by virtual tick
        ``now`` (closed-loop submissions stamp their arrival at submit time,
        so they are always ready)."""
        return bool(self._q) and self._q[0].arrival_tick <= now

    def ready_depth(self, now: float) -> int:
        """How many queued requests have arrived by ``now`` — the open-loop
        bench's queue-depth sample."""
        return sum(1 for r in self._q if r.arrival_tick <= now)

    def pop_ready(self, now: float) -> Optional[object]:
        """Admit the queue HEAD if it has arrived; None otherwise. Never
        skips ahead — a later, shorter request must wait behind the head
        (FIFO; no starvation of long-prompt requests)."""
        if not self.head_ready(now):
            return None
        self.admitted += 1
        return self._q.popleft()
