"""Host-side admission control for the multi-port serving engine.

The engine used to pop admissions straight off a ``deque`` inside its
prefill phase — workable closed-loop, but the open-loop traffic harness
needs admission to be a first-class HOST-side decision, decoupled from the
device macro-cycle: requests arrive on a virtual-clock schedule (see
``serve/traffic.py``), wait here while slots are contended, and are
admitted when capacity frees up. Keeping the queue its own object also
pins the architectural invariant the regression tests check:

**Admission follows ARRIVAL order (FIFO) under slot contention.** When
several queued requests compete for one freed slot, the OLDEST ready
request wins — :meth:`pop_ready` only ever surfaces the queue head, never
a younger request that happens to look cheaper (shorter prompt, fewer
pages). A ready-set implementation that re-ordered by readiness or size
would systematically starve long-prompt requests behind a stream of short
ones; head-of-line blocking is the contract, and
``tests/serve/test_admission.py`` pins it.

The queue measures itself: ``peak_depth`` (most requests ever waiting),
``admitted``, and per-request wait stamps land on the request objects
themselves (``admit_cycle`` / ``admit_tick``), which the open-loop bench
turns into queue-delay percentiles. Requests only need ``arrival_tick``
(virtual-clock arrival time) — the queue is generic over the payload.

**Overload safety (this revision).** Under sustained over-saturation an
unbounded FIFO degrades into unbounded queue delay: every request is
eventually served, none within its SLO. The queue therefore supports two
explicit load-shedding decisions, both COUNTED (``rejected`` /
``shed_expired``) so the serving bench can gate on them:

* a bounded depth (``max_depth``): :meth:`push` REJECTS — returns False —
  when the queue is full, the earliest (and cheapest) place to say no;
* deadline shedding: requests may carry ``deadline_tick`` (an absolute
  virtual-clock tick, arrival + TTL). :meth:`shed_expired_heads` drops
  expired HEADS before they are admitted — work that can no longer meet
  its SLO never gets a slot, a page, or a pool traversal. Shedding only
  ever inspects the head, so the FIFO/no-starvation contract above is
  untouched: a live head is never bypassed because a younger request
  looks fresher.

:class:`OverloadController` (also here: it is admission-layer policy) is
the graceful-degradation stage BEFORE shedding — on sustained ready-queue
pressure it shrinks the engine's prefill chunk and caps admissions per
cycle, restoring both when pressure clears.

**Prefix-aware admission** (:func:`prefix_admission_plan`, also
admission-layer policy): the head's prompt is matched against the pool's
content-addressed prefix index BEFORE the capacity precheck, so matched
pages — attachable by refcount bump — never count as page demand and the
precheck probes the PREFIX's shard (where the shared pages live) instead
of the least-loaded one. A request that would park or shed on a full home
shard can therefore admit against a fuller shard that already holds its
prompt, and only its unmatched tail costs prefill compute.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional


class AdmissionQueue:
    """Arrival-ordered FIFO of submitted-but-not-admitted requests."""

    def __init__(self, max_depth: Optional[int] = None):
        if max_depth is not None and max_depth < 1:
            raise ValueError(f"max_depth must be >= 1, got {max_depth}")
        self._q: deque = deque()
        self.max_depth = max_depth
        self.peak_depth = 0
        self.submitted = 0
        self.admitted = 0
        self.rejected = 0              # pushes refused by the depth bound
        self.shed_expired = 0          # expired heads dropped pre-admission

    def push(self, req) -> bool:
        """Enqueue in submission order (== arrival order: callers submit as
        the traffic schedule fires, and ties share the submission order).
        Returns False — and counts the rejection — when a ``max_depth``
        bound is set and the queue is already full."""
        if self.max_depth is not None and len(self._q) >= self.max_depth:
            self.rejected += 1
            return False
        self._q.append(req)
        self.submitted += 1
        self.peak_depth = max(self.peak_depth, len(self._q))
        return True

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def head(self):
        """The oldest queued request (None when empty) — the ONLY request
        eligible for the next admission."""
        return self._q[0] if self._q else None

    def head_ready(self, now: float) -> bool:
        """True when the oldest queued request has arrived by virtual tick
        ``now`` (closed-loop submissions stamp their arrival at submit time,
        so they are always ready)."""
        return bool(self._q) and self._q[0].arrival_tick <= now

    def ready_depth(self, now: float) -> int:
        """How many queued requests have arrived by ``now`` — the open-loop
        bench's queue-depth sample."""
        return sum(1 for r in self._q if r.arrival_tick <= now)

    def pop_ready(self, now: float) -> Optional[object]:
        """Admit the queue HEAD if it has arrived; None otherwise. Never
        skips ahead — a later, shorter request must wait behind the head
        (FIFO; no starvation of long-prompt requests). Expired heads are
        shed first (see :meth:`shed_expired_heads`), so the request this
        returns can still meet its deadline."""
        self.shed_expired_heads(now)
        if not self.head_ready(now):
            return None
        self.admitted += 1
        return self._q.popleft()

    def drop_head(self):
        """Remove and return the head WITHOUT counting it admitted — the
        engine's shed path (e.g. capacity-retry exhaustion)."""
        return self._q.popleft() if self._q else None

    @staticmethod
    def _expired(req, now: float) -> bool:
        ddl = getattr(req, "deadline_tick", None)
        return ddl is not None and now > ddl

    def shed_expired_heads(self, now: float) -> list:
        """Drop every expired request from the FRONT of the queue (its
        deadline tick has already passed at virtual time ``now``) and
        return them for the caller to stamp/count. Head-only by design:
        an expired request buried behind a live head is left in place —
        it will be shed when it surfaces, and skipping over the head to
        reap it early would break the arrival-order contract the
        starvation tests pin."""
        shed = []
        while self._q and self._expired(self._q[0], now):
            shed.append(self._q.popleft())
        self.shed_expired += len(shed)
        return shed


def prefix_admission_plan(pool, prompt, max_new: int, *,
                          enabled: bool = True):
    """The admission-layer prefix policy: (match, worst_tokens) for one
    candidate request.

    ``worst`` is the request's worst-case lifetime word demand — prompt
    plus generated tokens, minus the final token whose KV never lands
    (eviction precedes its append). The match, when ``enabled``, is capped
    at ``len(prompt) - 1`` tokens: the LAST prompt position is always
    recomputed, because the first generated token is read off its prefill
    logits (a full-prompt attach would leave nothing to take logits from).
    Matching runs BEFORE the capacity precheck by contract — callers pass
    the match to :meth:`PagedPool.admission_precheck` so only the
    unmatched tail counts as page demand, on the prefix's shard."""
    worst = len(prompt) + max_new - 1
    match = None
    if enabled and len(prompt) > 1:
        match = pool.match_prefix(prompt, limit=len(prompt) - 1)
    return match, worst


@dataclasses.dataclass
class OverloadController:
    """Graceful degradation under pressure — the stage between "serve
    everything" and "shed".

    Watches the ready-queue depth the engine samples every macro-cycle.
    After ``sustain`` consecutive cycles at or above ``depth_high`` it
    enters the DEGRADED state: the engine's prefill chunk shrinks by
    ``chunk_shrink`` (new prompts stream in smaller per-cycle slices, so
    in-flight decodes keep making progress instead of stalling behind
    bulk prefill traffic) and new admissions are capped at
    ``admission_cap`` per cycle (the queue absorbs the burst; deadline
    shedding trims what can no longer be served). After ``sustain``
    consecutive cycles at or below ``depth_low`` it restores normal
    service. Hysteresis (high/low bands + the sustain count) keeps it
    from flapping on a single bursty cycle.

    Degrading never changes WHAT is generated — chunked prefill is
    chunk-size invariant (pinned by the chunked-prefill property tests),
    only the per-cycle port traffic shape moves. Every transition is
    logged in ``transitions`` with its cycle, tick, and trigger depth;
    ``degraded_cycles`` counts time spent degraded — both surfaced in the
    serve bench's overload section."""

    depth_high: int = 6
    depth_low: int = 1
    sustain: int = 3
    chunk_shrink: int = 2          # chunk_tokens divisor while degraded
    admission_cap: int = 1         # max admissions per cycle while degraded
    state: str = "normal"
    transitions: list = dataclasses.field(default_factory=list)
    degraded_cycles: int = 0
    _over: int = 0
    _under: int = 0

    def __post_init__(self):
        if self.depth_low >= self.depth_high:
            raise ValueError(
                f"depth_low ({self.depth_low}) must be < depth_high "
                f"({self.depth_high}) — the hysteresis band")
        if self.sustain < 1 or self.chunk_shrink < 1 or self.admission_cap < 1:
            raise ValueError("sustain, chunk_shrink and admission_cap must "
                             "all be >= 1")

    @property
    def degraded(self) -> bool:
        return self.state == "degraded"

    def observe(self, ready_depth: int, *, cycle: int, tick: int) -> None:
        """One macro-cycle's pressure sample; may transition the state."""
        if self.state == "normal":
            self._over = self._over + 1 if ready_depth >= self.depth_high \
                else 0
            if self._over >= self.sustain:
                self.state = "degraded"
                self._over = self._under = 0
                self.transitions.append(
                    {"cycle": cycle, "tick": tick, "to": "degraded",
                     "ready_depth": ready_depth})
        else:
            self.degraded_cycles += 1
            self._under = self._under + 1 if ready_depth <= self.depth_low \
                else 0
            if self._under >= self.sustain:
                self.state = "normal"
                self._over = self._under = 0
                self.transitions.append(
                    {"cycle": cycle, "tick": tick, "to": "normal",
                     "ready_depth": ready_depth})

    def chunk_tokens(self, base: int) -> int:
        """The prefill chunk the engine should use this cycle."""
        return base if self.state == "normal" \
            else max(1, base // self.chunk_shrink)

    def cap(self) -> Optional[int]:
        """Per-cycle admission cap (None = uncapped) for this cycle."""
        return None if self.state == "normal" else self.admission_cap
