"""Multi-port serving engine: the paper's wrapper as a request scheduler
whose data plane IS a paged multi-port memory pool.

The engine's KV storage is not a dense per-slot buffer — it is allocated
from ONE physical :class:`~repro.memory.paged_kv.PagedPool` (a word = one
token's K,V for every layer; sequences own pages through page tables, vLLM
style). Each engine macro-cycle (one external "CLK") walks the paper's FSM
(Fig. 2) over four logical ports, in priority order:

    port A (W, priority 1): EVICT    — free finished slots; freed pages are
                                       scrubbed through the pool's port D
    port B (W, priority 2): PREFILL  — admit queued requests and advance every
                                       mid-prefill slot by ONE fixed-size
                                       token chunk: chunks from different
                                       requests are stacked into one padded
                                       batch, run through a single chunked
                                       prefill step, and ALL chunks' K,V land
                                       as one bulk-write port transaction
                                       (pool port C)
    port C (R/W, priority 3): DECODE — one token for every active slot: the
                                       previous token's K,V append (pool
                                       port A) and this step's attention
                                       gathers (pool port B)
    port D (R, priority 4): STATUS   — scoreboard snapshot (lengths, slots)

Continuous batching: the slot table starts at ``slots`` entries and grows on
demand up to ``max_slots`` (config-driven, well past the seed's fixed 4).
Both the decode batch and the prefill chunk batch are padded to power-of-two
buckets, so slot-pool regrowth retraces the jitted steps only at bucket
boundaries (log2(max_slots) times over the engine's lifetime), never per
request. A request's FIRST generated token comes from its prefill logits
(the last valid position of its final chunk) — decode never re-feeds
``prompt[-1]``, so each KV word lands in the pool exactly once.

The phase walk above COLLECTS traffic; how it commits is a per-cycle
PORT-MIX DECISION made by the dependency scheduler (``serve/scheduler.py``).
Each phase's page-granular footprint is projected against the post-eviction
free lists, and under the default ``schedule_mode="ooo"`` phases touching
DISJOINT pages co-schedule into the SAME pool traversal (e.g. prefill W
ports alongside decode W+R ports — any validated 1-4 port mix), while
RAW/WAR overlaps split conservatively and WAW overlaps share a traversal
under program-order priority (eviction's scrub serviced before a write
reusing the freed page). ``schedule_mode="static"`` keeps the rigid walk as
the oracle: one traversal per phase, never co-scheduled. ``max_ports``
(1-4, the paper's B1B0 knob) caps a traversal's port count; a 1-port
budget also degrades the COMPUTE to the two-pass oracle
(``compute_port_mix="w+r"``) since the fused kernels' 1W+1R contract is no
longer schedulable. ``coschedule_frac`` / ``schedule_log`` expose the
decisions; ``PagedPool.mix_counts`` histograms the traversal mixes served.

In the default ``kernel_mode="pallas"`` a decode macro-cycle's traffic is
ONE physical pool traversal (``PagedPool.cycle`` services the scheduled
ports in the schedule's priority order with same-cycle W->R visibility),
and the decode compute services all active slots through the fused
append+attend Pallas kernel (``kernels/kv_multiport``) — one VMEM
traversal for the W and R ports, claim C1 end-to-end.
``kernel_mode="reference"`` keeps the jnp oracle ``core.step`` under the
pool and two-pass (append-traversal then read-traversal) port
transactions — the baseline the benchmark compares traversal counts
against. ``single_port=True`` additionally services ONE engine port per
macro-cycle (the paper's bare-macro comparison).

Traversals are LENGTH-BOUNDED (``length_bound=True``, pallas mode) and,
by default, RETRACE-FREE (``dynamic_grid=True``): the staging caches keep
ONE shape — the padded full capacity — and the kernels bound their own
tile grid with the runtime live-tile count read from the scalar-prefetched
SMEM lengths, so a single decode trace (and a single chunk trace) serves
every cache length while per-token read traffic still scales with
``cache_len``, not the allocated ``max_len`` (``decode_traces`` /
``prefill_traces`` count jit retraces). ``dynamic_grid=False`` falls back
to the bucketed ladder: staging caches cover the batch's live length
rounded up to a power-of-two count of ``seq_tile`` tiles (retraces at
tile-count buckets, mirroring the slot buckets; the ladder launchers
validate ``--seq-tile`` against via ``final_stage_ladder``). Either way
the kernels skip tiles past each sequence's own live length under
``pl.when``. ``decode_tile_reads`` / ``prefill_tile_reads`` count the
tiles actually touched; ``steady_decode_tile_bound`` is the ideal
``ceil((cache_len+1)/seq_tile)`` budget the CI bench gate checks against.

``interpret=True`` (default) executes the Pallas kernels in Python — the
CPU-CI escape hatch; pass ``False`` on TPU deployments to lower through
Mosaic.

**Async host loop** (this revision): host-side admission/scheduling is
decoupled from device macro-cycles. Admission lives in its own
:class:`~repro.serve.admission.AdmissionQueue` (arrival-ordered FIFO — a
freed slot under contention always goes to the OLDEST ready request, so
long-prompt requests are never starved by younger short ones), and
``step()`` is a two-stage software pipeline: the decode compute of
macro-cycle N is DISPATCHED but not forced (JAX async dispatch — the jit
call returns device futures), and its results are RETIRED at the start of
macro-cycle N+1, after the host has already drained new arrivals and made
the next cycle's admission decisions. While cycle N executes on the
device, cycle N+1 is being planned (phase collection + the PR-6 hazard
scheduler). Staging buffers are DOUBLE-BUFFERED: decode staging alternates
between two preallocated host buffers, so filling cycle N+1's stage never
overwrites memory the in-flight cycle N compute may still be reading.
State evolution (tokens, cycle counts, traversals) is bit-identical to the
synchronous loop — only the forcing point moves; ``flush()`` retires a
trailing in-flight cycle and ``run()`` calls it.

**Virtual clock**: ``vclock`` counts POOL TRAVERSALS (one tick = one
physical pool traversal; a macro-cycle that commits none — idle/status
only — costs one tick). Latency is measured against this clock, so SLO
numbers are deterministic on CI and directly reflect what the paper
prices: a scheduler spending more traversals per macro-cycle burns more
ticks for the same work. Requests carry arrival/admit/first-token/finish
stamps in both ticks and macro-cycles (plus opt-in wall-clock
timestamps); ``slot_contention_cycles`` counts cycles where a ready
arrival waited on a full slot table and ``evict_pressure_admissions``
counts admissions that only proceeded because a slot was freed that same
cycle — the open-loop bench (``benchmarks/serve_bench.py``) turns these
into TTFT/per-token percentiles, goodput, and queue-depth curves.

**Data-parallel KV** (``mesh`` with a ``kv`` axis): the pool's word axis —
its sequence/page axis — shards across devices with page-aligned
boundaries (``distributed.sharding.kv_shard_plan``; a page never straddles
two devices) and page allocation turns device-aware: every request gets a
HOME shard at admission and all its pages are carved from that shard, so
its pool traffic and its kernel compute stay device-local. The engine
stages decode and prefill-chunk batches in contiguous PER-DEVICE row
blocks (each padded to a power-of-two rows-per-device, so the batch always
divides across the mesh) and both fused kernels launch under ``shard_map``:
each device's kernel prefetches only its own sequences' SMEM scalars and
bounds its own dynamic tile grid with ITS max live length — a device
serving short sequences traverses fewer tiles than one serving long
sequences, which ``decode_tile_reads_by_dev`` (and the bench's v4
per-device balance column) makes visible. ``PagedPool.cycle`` runs the
pool traversal under ``shard_map`` too (per-shard address windows, psum'd
read lanes). Greedy decode stays token-identical to the single-device
path at every device count, in both kernel modes — ``kernel_mode=
"reference"`` is the sharded oracle.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import fsm
from repro.core.clockgen import build_schedule
from repro.core.ports import MAX_PORTS, READ, WRITE, PortConfig
from repro.kernels.tiling import fit_seq_tile
from repro.memory.paged_kv import (APPEND, ATTN_READ, BULK_FILL, SCRUB,
                                   PagedPool, PoolCapacityError, _bucket,
                                   seq_tile_buckets)
from repro.models import decode_step, prefill_chunk
from repro.serve import scheduler as sched_mod
from repro.serve.admission import (AdmissionQueue, OverloadController,
                                   prefix_admission_plan)
from repro.serve.scheduler import PhaseTxn, PortTxn

EVICT, PREFILL, DECODE, STATUS = 0, 1, 2, 3

# pool-port stream keyword for each physical port a scheduled transaction
# can issue on (the engine's phase -> pool-port wiring)
_STREAM_KEY = {SCRUB: "scrub", BULK_FILL: "prefill",
               APPEND: "append", ATTN_READ: "read"}


def _jit_traces(fn) -> int:
    """Compiled-trace count of a ``jax.jit`` callable (-1 when the running
    jax version does not expose the cache probe)."""
    try:
        return fn._cache_size()
    except AttributeError:
        return -1


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False
    # open-loop latency stamps (virtual-clock ticks = pool traversals, plus
    # the macro-cycle index; wall-clock seconds recorded alongside as the
    # opt-in column — never the deterministic gate)
    arrival_tick: float = 0.0
    arrival_cycle: int = 0
    # overload-safety state: an optional absolute admission deadline
    # (arrival + TTL, virtual ticks — expired heads are shed, never
    # admitted), why/when the request was shed (None = served), how many
    # cycles it was parked retrying a full home shard, and whether a chaos
    # fault cancelled it mid-stream (cancelled/shed requests are excluded
    # from the survivor token-identity checks)
    deadline_tick: Optional[float] = None
    shed_reason: Optional[str] = None
    shed_tick: Optional[int] = None
    capacity_retries: int = 0
    cancelled: bool = False
    admit_tick: Optional[int] = None
    admit_cycle: Optional[int] = None
    first_token_tick: Optional[int] = None
    first_token_cycle: Optional[int] = None
    finish_tick: Optional[int] = None
    finish_cycle: Optional[int] = None
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def ttft_ticks(self) -> Optional[float]:
        """Time to first token in virtual ticks (None until it exists)."""
        if self.first_token_tick is None:
            return None
        return self.first_token_tick - self.arrival_tick

    @property
    def tpot_ticks(self) -> Optional[float]:
        """Per-token decode latency in virtual ticks — the mean tick cost
        of tokens AFTER the first; None until finished or for single-token
        requests (which never enter decode)."""
        if self.finish_tick is None or self.first_token_tick is None:
            return None
        if len(self.generated) < 2:
            return None
        return ((self.finish_tick - self.first_token_tick)
                / (len(self.generated) - 1))


@dataclasses.dataclass
class _PrefillState:
    """A slot mid-prefill: chunks consumed so far + the staged K,V of those
    chunks (the chunk compute's running cache; the pool stays the decode-side
    source of truth)."""
    consumed: int
    stage_k: np.ndarray                 # [L, max_len, Hkv, D]
    stage_v: np.ndarray


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unretired decode macro-cycle: the jitted step's
    un-forced device results plus the host metadata needed to retire them.
    Created at the end of ``step()`` (JAX async dispatch — the jit call
    returned futures), consumed at the START of the next ``step()`` (or by
    ``flush()``), so the device executes cycle N while the host plans
    cycle N+1."""
    cycle: int                     # macro-cycle index the work belongs to
    vclock_end: int                # virtual clock after that cycle's commit
    active: list                   # slots the decode step served
    row_of: dict                   # slot -> staged batch row
    lens: np.ndarray               # per-row pre-append cache lengths
    state: dict                    # un-forced jit outputs (cache_k/cache_v)
    logits: object                 # un-forced next-token logits
    rids: dict = dataclasses.field(default_factory=dict)
                                   # slot -> rid at dispatch time: retirement
                                   # skips rows whose slot was reassigned
                                   # while the dispatch was outstanding
                                   # (possible when a chaos stall lets
                                   # evict/admit run between dispatch and
                                   # retire)


class _DoubleBuffer:
    """Two alternating preallocated host staging buffers per key: the
    in-flight cycle's staging source is never overwritten by the next
    cycle's fill (``jnp.asarray`` may alias host memory on CPU), and the
    hot loop stops paying a fresh ``np.zeros`` allocation per cycle."""

    def __init__(self):
        self._bufs: dict = {}

    def get(self, key, shape, dtype=np.float32) -> np.ndarray:
        slot = self._bufs.setdefault(key, [None, None, 0])
        idx = slot[2]
        slot[2] ^= 1
        buf = slot[idx]
        if buf is None or buf.shape != shape or buf.dtype != dtype:
            buf = np.zeros(shape, dtype)
            slot[idx] = buf
        else:
            buf.fill(0)
        return buf


class MultiPortEngine:
    def __init__(self, params, cfg: ArchConfig, *, slots: int = 4,
                 max_slots: Optional[int] = None, max_len: int = 256,
                 prefill_bucket: int = 32, chunk_tokens: Optional[int] = None,
                 kernel_mode: str = "pallas", single_port: bool = False,
                 greedy: bool = True, page_tokens: int = 8,
                 seq_tile: int = 128, length_bound: bool = True,
                 dynamic_grid: bool = True, interpret: bool = True,
                 num_kv_splits: int = 1,
                 mesh=None, kv_axis: str = "kv",
                 schedule_mode: str = "ooo", max_ports: int = MAX_PORTS,
                 max_queue_depth: Optional[int] = None,
                 default_ttl_ticks: Optional[float] = None,
                 capacity_retry_limit: int = 16,
                 overload: Optional[OverloadController] = None,
                 prefix_cache: bool = False):
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise ValueError("engine currently serves KV-cache families")
        if kernel_mode not in ("pallas", "reference"):
            raise ValueError(f"unknown kernel_mode: {kernel_mode!r}")
        if schedule_mode not in ("static", "ooo"):
            raise ValueError(f"unknown schedule_mode: {schedule_mode!r}")
        if not 1 <= max_ports <= MAX_PORTS:
            raise ValueError(
                f"max_ports must be in 1..{MAX_PORTS}, got {max_ports}")
        if seq_tile < 1:
            raise ValueError(f"seq_tile must be >= 1, got {seq_tile}")
        if num_kv_splits < 1:
            raise ValueError(
                f"num_kv_splits must be >= 1, got {num_kv_splits}")
        self.params, self.cfg = params, cfg
        # per-cycle port-mix scheduling (see serve/scheduler.py): "ooo"
        # packs non-hazarding phases into shared pool traversals; "static"
        # keeps the rigid one-traversal-per-phase walk as the oracle
        self.schedule_mode = schedule_mode
        self.max_ports = max_ports
        # compute-side port-mix decision: a 1-port budget cannot schedule
        # the fused kernels' 1W+1R traversal, so the attention compute
        # degrades to the two-pass (W traversal, then R traversal) oracle
        self.compute_port_mix = "wr" if max_ports >= 2 else "w+r"
        self._fused_compute = (kernel_mode == "pallas"
                               and self.compute_port_mix == "wr")
        # pool-side two-pass discipline: the reference engine and the bare
        # macro split every traversal into writes-then-reads
        self._split_roles = (kernel_mode != "pallas") or single_port
        self.max_slots = slots if max_slots is None else max_slots
        if self.max_slots < slots:
            raise ValueError(f"max_slots ({self.max_slots}) < slots ({slots})")
        self._init_slots = slots
        self.max_len = max_len
        # chunked prefill: admissions advance chunk_tokens per macro-cycle
        self.chunk_tokens = chunk_tokens or prefill_bucket
        self.kernel_mode = kernel_mode
        self.single_port = single_port
        self.interpret = interpret
        # length-bounded traversals: staging caches (and so the Pallas
        # kernels' tile grids) cover the batch's LIVE length rounded up to a
        # power-of-two count of seq_tile tiles, not the allocated max_len.
        # The ladder is the same one launch/serve validates --seq-tile
        # against; every entry is a whole number of tiles (the last padded
        # past max_len if needed) so kernels never fall back to degenerate
        # fit-down tile sizes.
        self.seq_tile = min(seq_tile, max_len)
        self.length_bound = length_bound
        # dynamic-grid traversal (pallas + length_bound): the staging caches
        # always cover the full padded capacity and the KERNEL bounds its own
        # grid with the runtime live-tile count — ONE decode trace serves
        # every cache length, deleting the stage-length ladder from the hot
        # path. The ladder stays as the dynamic_grid=False (bucketed,
        # retrace-per-bucket) fallback and the --seq-tile validation surface.
        self.dynamic_grid = (dynamic_grid and self._fused_compute
                             and length_bound)
        # split-KV flash-decode: each decode traversal's R-port chain runs
        # as num_kv_splits grid-parallel partial-softmax chains plus one
        # LSE-combine step (see kernels/kv_multiport.py). Only the fused
        # pallas compute has a traversal to split — the two-pass reference
        # oracle stays serial so splits never change its tokens
        self.num_kv_splits = num_kv_splits if self._fused_compute else 1
        self._stage_buckets = self.final_stage_ladder(max_len, seq_tile)
        self.stage_lens_seen: set = set()
        # padded batch rows carry the Pallas kernels' dead-row sentinel
        # (cache_len/offset -1: zero tiles serviced) so tile accounting
        # stays exact under padding; the two-pass compute (jnp reference,
        # or a pallas engine degraded to a 1-port compute budget) keeps 0
        # — its dense read needs finite positions
        self._dead_row = -1 if self._fused_compute else 0

        # data-parallel KV: shard the pool page-aligned across the mesh's
        # kv axis and group staged batches by home device (see module doc)
        self.mesh = mesh
        self.kv_axis = kv_axis
        if mesh is not None and kv_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh {mesh.axis_names} has no {kv_axis!r} axis — build it "
                f"with launch.mesh.make_kv_mesh")
        self.n_kv_shards = int(mesh.shape[kv_axis]) if mesh is not None else 1

        # physical pool: word = one token's (K, V) across all layers, sized
        # for the FULL grown slot table (the pool rounds up to a whole
        # number of pages per shard — page-aligned shard boundaries)
        self._kv_dims = (cfg.n_layers, 2, cfg.n_kv_heads, cfg.head_dim_)
        word_width = int(np.prod(self._kv_dims))
        n_pages = self.max_slots * (-(-max_len // page_tokens))
        self.pool = PagedPool.create(
            n_pages=n_pages, page_tokens=page_tokens, word_width=word_width,
            dtype=jnp.float32, use_kernel=(kernel_mode == "pallas"),
            interpret=interpret, seq_tile=self.seq_tile,
            mesh=mesh, kv_axis=kv_axis)

        self.slot_req: list[Optional[Request]] = [None] * slots
        self.slot_len: list[int] = [0] * slots      # tokens committed to pool
        self._pending: dict[int, np.ndarray] = {}   # slot -> KV word to append
        self._prefilling: dict[int, _PrefillState] = {}
        # host-side admission: arrival-ordered FIFO, decoupled from the
        # device macro-cycle (see serve/admission.py); bounded when the
        # caller sets max_queue_depth (overload safety: explicit rejection
        # beats unbounded queue delay)
        self.admission = AdmissionQueue(max_depth=max_queue_depth)
        # overload-safe serving state: the default admission TTL stamped on
        # submissions (deadline = arrival + TTL, virtual ticks), the
        # capacity-retry budget for a head parked on a full home shard, the
        # optional graceful-degradation controller, and the shed record
        if capacity_retry_limit < 1:
            raise ValueError(
                f"capacity_retry_limit must be >= 1, got "
                f"{capacity_retry_limit}")
        self.default_ttl_ticks = default_ttl_ticks
        self.capacity_retry_limit = capacity_retry_limit
        self.overload = overload
        # refcounted prefix caching: admission matches each prompt against
        # the pool's content-addressed prefix index BEFORE the capacity
        # precheck (matched pages attach by refcount bump; only the
        # unmatched tail counts as demand and prefill compute), and every
        # completed prefill registers its prompt pages for future matches.
        # Default OFF: the oracle engines stay bit-identical to exclusive
        # ownership — with it ON, greedy tokens are still identical (the
        # adopted words are the words prefill would have recomputed).
        self.prefix_cache = prefix_cache
        self.shed: list[Request] = []       # all shed requests, any reason
        self.shed_deadline = 0              # expired before admission
        self.shed_queue_full = 0            # rejected by the bounded queue
        self.shed_capacity = 0              # capacity-retry budget exhausted
        self.capacity_parked_cycles = 0     # cycles a head waited on pages
        self.capacity_recoveries = 0        # parked heads later admitted
        self.cancelled = 0                  # chaos mid-stream cancellations
        # chaos delayed-retirement state: cycles the in-flight decode must
        # stay unretired (the host keeps admitting/prefilling/evicting but
        # cannot dispatch new decode work until the stall drains)
        self.retire_stall_cycles = 0
        self.stalled_retirements = 0
        self.finished: list[Request] = []
        self.cycles = 0
        # virtual clock: pool traversals + idle macro-cycles (1 tick each);
        # all latency stamps are measured against this
        self.idle_ticks = 0
        # open-loop pressure counters: cycles where a ready arrival waited
        # on a full slot table, and admissions that only went through
        # because an eviction freed their slot that same cycle
        self.slot_contention_cycles = 0
        self.evict_pressure_admissions = 0
        self.evictions = 0
        # async pipeline state: the dispatched-but-unretired decode cycle,
        # double-buffered staging, and this cycle's stamp/bookkeeping sets
        self._inflight: Optional[_InFlight] = None
        self._stage_bufs = _DoubleBuffer()
        self._freed_slots_this_cycle: set = set()
        # prompts whose prefill completed this cycle, registered into the
        # pool's prefix index after the cycle's traversals commit
        self._register_pending: list = []
        self._token_events: list[Request] = []
        self.decode_steps = 0           # macro-cycles that carried decode traffic
        self.decode_traversals = 0      # pool traversals those cycles needed
        # steady state = decode cycles carrying both an append and a read
        # (a slot's FIRST decode has no pending append yet)
        self.steady_decode_steps = 0
        self.steady_decode_traversals = 0
        self.prefill_steps = 0          # macro-cycles that carried chunk traffic
        self.prefill_traversals = 0     # pool traversals those cycles needed
        self.prefill_tokens = 0         # prompt tokens committed to the pool
        self.prefill_chunks = 0         # per-slot chunk computations
        # tile accounting: seq_tile-sized staging-cache tiles the attention
        # kernels' R ports touch (per slot per layer-normalized traversal)
        self.decode_tile_reads = 0
        self.steady_decode_tile_reads = 0
        self.steady_decode_tile_bound = 0   # sum of ceil((len+1)/seq_tile)
        # critical-path chain: per step, the longest single dependent
        # accumulation chain (longest row's tiles; / num_kv_splits + 1
        # under split-KV) — the steady-step LATENCY proxy the bench's
        # split-speedup gate reads, vs tile_reads' total-traffic proxy
        self.decode_critical_tiles = 0
        self.steady_decode_critical_tiles = 0
        self.prefill_tile_reads = 0
        # per-device attribution of the same R-port tiles (device = the
        # sequence's home shard == its kernel shard): the balance surface
        # the bench's v4 per-device column reads
        self.decode_tile_reads_by_dev = [0] * self.n_kv_shards
        self.steady_decode_tile_reads_by_dev = [0] * self.n_kv_shards
        self.prefill_tile_reads_by_dev = [0] * self.n_kv_shards
        self.port_log: list[tuple[int, ...]] = []
        # per-cycle schedule observability: which phases shared which pool
        # traversal (one tuple of phase-id tuples per cycle), how many
        # cycles carried >1 pool phase, and how many of those the scheduler
        # packed into a shared traversal
        self.schedule_log: list[tuple] = []
        self.multi_phase_cycles = 0
        self.coscheduled_cycles = 0
        self._next_rid = 0
        self._sp_rotate = 0

        attn_mode = "multiport" if kernel_mode == "pallas" else "reference"
        pmix = self.compute_port_mix
        tile, dyn = self.seq_tile, self.dynamic_grid
        # the fused kernels only shard when the mesh is non-trivial; the jnp
        # reference ignores the mesh (it is the sharded-pool oracle)
        kmesh = mesh if self.n_kv_shards > 1 else None
        nsp = self.num_kv_splits
        self._decode = jax.jit(
            lambda p, s, b: decode_step(p, cfg, s, b, kernel_mode=attn_mode,
                                        seq_tile=tile,
                                        length_mask=length_bound,
                                        dynamic_grid=dyn,
                                        num_kv_splits=nsp,
                                        interpret=interpret,
                                        mesh=kmesh, mesh_axis=kv_axis,
                                        port_mix=pmix))
        self._prefill_chunk = jax.jit(
            lambda p, s, b: prefill_chunk(p, cfg, s, b, kernel_mode=attn_mode,
                                          seq_tile=tile, dynamic_grid=dyn,
                                          interpret=interpret,
                                          mesh=kmesh, mesh_axis=kv_axis,
                                          port_mix=pmix))

    # ---- client API --------------------------------------------------------
    @classmethod
    def final_stage_ladder(cls, max_len: int, seq_tile: int) -> tuple:
        """The stage-length ladder the engine uses for its whole lifetime,
        slot growth to ``max_slots`` included — the surface ``--seq-tile``
        must be validated against. The ladder's geometry inputs (max_len,
        CLAMPED seq_tile) are growth-invariant, so the final ladder is
        computable up front; but a launcher that hand-rolls the startup
        ladder instead of calling THIS silently diverges from the engine
        the moment the clamp or bucketing changes (the validation bug this
        replaces: raw ``seq_tile_buckets(max_len, seq_tile)`` skipped the
        engine's ``seq_tile = min(seq_tile, max_len)`` clamp)."""
        if seq_tile < 1:
            raise ValueError(f"seq_tile must be >= 1, got {seq_tile}")
        return seq_tile_buckets(max_len, min(seq_tile, max_len))

    @property
    def decode_traces(self) -> int:
        """Times the jitted decode step has been (re)traced — 1 on the
        dynamic-grid path regardless of cache length; O(log S_max/seq_tile)
        ladder buckets on the bucketed fallback."""
        return _jit_traces(self._decode)

    @property
    def prefill_traces(self) -> int:
        """Times the jitted chunked-prefill step has been (re)traced."""
        return _jit_traces(self._prefill_chunk)

    @property
    def n_slots(self) -> int:
        """Current slot-table size (grows on demand up to ``max_slots``)."""
        return len(self.slot_req)

    def submit(self, prompt: list[int], max_new: int = 16,
               arrival_tick: Optional[float] = None,
               ttl_ticks: Optional[float] = None) -> Request:
        """Enqueue a request and return it (latency stamps land on the
        returned object as the request moves through admission/serving).
        ``arrival_tick`` is its open-loop arrival time on the virtual
        clock; omitted (closed loop) it arrives NOW, so it is immediately
        admissible — the pre-harness behavior. ``ttl_ticks`` (default: the
        engine's ``default_ttl_ticks``) sets an admission deadline of
        ``arrival + ttl`` on the virtual clock: a request whose deadline
        passes while it is still queued is SHED, never admitted. When a
        ``max_queue_depth`` bound is set and the queue is full, the
        request is shed immediately (``shed_reason == "queue_full"``) —
        callers must check ``req.shed_reason`` rather than assume
        enqueue."""
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"max_len ({self.max_len})")
        if not prompt:
            raise ValueError("empty prompt")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(
            rid, list(prompt), max_new,
            arrival_tick=(self.vclock if arrival_tick is None
                          else arrival_tick),
            arrival_cycle=self.cycles, t_submit=time.perf_counter())
        ttl = self.default_ttl_ticks if ttl_ticks is None else ttl_ticks
        if ttl is not None:
            if ttl <= 0:
                raise ValueError(f"ttl_ticks must be > 0, got {ttl}")
            req.deadline_tick = req.arrival_tick + ttl
        if not self.admission.push(req):
            self._shed(req, "queue_full")
        return req

    def _shed(self, req: Request, reason: str) -> None:
        """Record a load-shedding decision: stamp the request with why and
        when (virtual tick) it was dropped and bump the per-reason
        counter. Shed requests never occupy a slot, a page, or a pool
        traversal past this point."""
        req.shed_reason = reason
        req.shed_tick = self.vclock
        self.shed.append(req)
        if reason == "deadline":
            self.shed_deadline += 1
        elif reason == "queue_full":
            self.shed_queue_full += 1
        elif reason == "capacity":
            self.shed_capacity += 1
        else:
            raise ValueError(f"unknown shed reason: {reason!r}")

    def cancel(self, rid: int) -> bool:
        """Cancel a request mid-stream: mark it done so the next EVICT
        phase frees its slot and scrubs its pages through the pool's
        normal port-D path (no bespoke teardown — cancellation IS an
        eviction). The request lands in ``finished`` flagged
        ``cancelled=True`` so token-identity checks exclude it. Returns
        False when ``rid`` is not live in a slot (already finished,
        queued, or unknown)."""
        for r in self.slot_req:
            if r is not None and r.rid == rid and not r.done:
                r.cancelled = True
                r.done = True
                self.cancelled += 1
                return True
        return False

    def stall_retirement(self, cycles: int) -> None:
        """Chaos hook: delay retirement of the async-dispatched decode by
        ``cycles`` macro-cycles. While stalled the engine keeps evicting,
        admitting and prefilling, but the in-flight decode is neither
        forced nor is new decode work dispatched (per-slot decode compute
        is independent, so the stall is token-identical — only WHEN
        results are folded back moves)."""
        if cycles < 1:
            raise ValueError(f"cycles must be >= 1, got {cycles}")
        self.retire_stall_cycles += cycles

    def pending_work(self) -> bool:
        return bool(self.admission) or any(r is not None
                                           for r in self.slot_req)

    @property
    def vclock(self) -> int:
        """Virtual-clock ticks elapsed: one per pool traversal, plus one
        per idle macro-cycle — the deterministic time base every latency
        stamp and SLO gate is measured in."""
        return self.pool.traversals + self.idle_ticks

    def advance_idle(self, ticks: int) -> None:
        """Fast-forward the virtual clock through a known-idle stretch
        (the open-loop driver calls this instead of spinning status-only
        macro-cycles while waiting for the next scheduled arrival)."""
        if ticks < 0:
            raise ValueError(f"ticks must be >= 0, got {ticks}")
        self.idle_ticks += ticks

    @property
    def has_inflight(self) -> bool:
        """True while a dispatched decode macro-cycle awaits retirement."""
        return self._inflight is not None

    def flush(self) -> None:
        """Retire a trailing in-flight decode cycle (forces its device
        results). ``run()`` calls this; drivers that step manually must
        too before reading final per-request state."""
        if self._inflight is not None:
            self._retire(self._inflight)
            self._inflight = None

    @property
    def pool_traversals(self) -> int:
        return self.pool.traversals

    @property
    def kv_tile_balance(self) -> float:
        """Per-device steady-decode tile-read balance: max over devices
        divided by the per-device mean (1.0 = perfectly balanced traffic;
        the bench's v4 gate asserts this stays within 1.25x of ideal).
        Trivially 1.0 unsharded or before any steady decode."""
        per = self.steady_decode_tile_reads_by_dev
        total = sum(per)
        if self.n_kv_shards == 1 or not total:
            return 1.0
        return max(per) / (total / self.n_kv_shards)

    @property
    def prefix_stats(self) -> dict:
        """Prefix-cache observability: index lookups/hits at admission,
        tokens and pages adopted without recompute, and the copy-on-write
        traffic those adoptions later cost. All zero with
        ``prefix_cache=False``."""
        p = self.pool
        return {"lookups": p.prefix_lookups, "hits": p.prefix_hits,
                "attached_tokens": p.prefix_attached_tokens,
                "attached_pages": p.prefix_attached_pages,
                "cow_copies": p.cow_copies, "cow_words": p.cow_words}

    @property
    def coschedule_frac(self) -> float:
        """Fraction of multi-phase macro-cycles (cycles whose pool traffic
        spans >1 engine phase) the scheduler packed into a shared traversal
        — 0.0 before any multi-phase cycle ran, and always 0.0 under
        ``schedule_mode="static"``."""
        if not self.multi_phase_cycles:
            return 0.0
        return self.coscheduled_cycles / self.multi_phase_cycles

    # ---- port collection routines -------------------------------------------
    def _free_slot(self) -> Optional[int]:
        """Lowest free slot index; grows the slot table (bounded by
        ``max_slots``) when every existing slot is occupied."""
        slot = next((i for i, r in enumerate(self.slot_req) if r is None),
                    None)
        if slot is None and len(self.slot_req) < self.max_slots:
            self.slot_req.append(None)
            self.slot_len.append(0)
            slot = len(self.slot_req) - 1
        return slot

    def _port_enables(self) -> PortConfig:
        finished = any(r is not None and r.done for r in self.slot_req)
        can_place = (any(r is None for r in self.slot_req)
                     or len(self.slot_req) < self.max_slots)
        admit = ((self.admission.head_ready(self.vclock) and can_place)
                 or bool(self._prefilling))
        active = any(r is not None and not r.done and i not in self._prefilling
                     for i, r in enumerate(self.slot_req))
        enabled = (finished, admit, active, True)
        if not any(enabled[:3]):
            enabled = (False, False, False, True)
        return PortConfig(enabled=enabled,
                          roles=(WRITE, WRITE, WRITE, READ))

    def _collect_evict(self) -> list:
        """Port A: retire finished requests; return freed pool pages."""
        freed: list[int] = []
        for i, r in enumerate(self.slot_req):
            if r is not None and r.done:
                self.finished.append(r)
                freed.extend(self.pool.free(r.rid))
                self.slot_req[i] = None
                self.slot_len[i] = 0
                self._pending.pop(i, None)
                self._prefilling.pop(i, None)
                self.evictions += 1
                self._freed_slots_this_cycle.add(i)
        return freed

    def _stage_len(self, need: int) -> int:
        """Length-bounded staging-cache size for this cycle: the smallest
        ladder bucket (power-of-two counts of seq_tile tiles — see
        ``seq_tile_buckets``) covering ``need`` live tokens, so jit retraces
        stay at tile-count buckets like the slot buckets. Unbounded pallas
        stages the padded full capacity; the two-pass compute (jnp
        reference, or a 1-port compute budget) stages max_len densely."""
        if not self._fused_compute:
            return self.max_len
        if self.dynamic_grid or not self.length_bound:
            # dynamic grid: ONE staged shape (the padded capacity) for every
            # cycle — the kernel bounds its own grid from the SMEM lengths,
            # so the ladder is out of the hot path entirely
            got = self._stage_buckets[-1]
        else:
            got = next((b for b in self._stage_buckets if b >= need),
                       self._stage_buckets[-1])
        self.stage_lens_seen.add(got)
        return got

    def _group_rows(self, slots: list, *, base: int
                    ) -> tuple[int, dict, list]:
        """Per-HOME-DEVICE contiguous row blocks for a staged batch: device
        ``d``'s sequences occupy rows ``[d*rpd, d*rpd + len(group_d))`` with
        ``rpd`` a power of two >= the largest group (>= ``base // n`` for
        jit shape stability), so ``nb = rpd * n_kv_shards`` always divides
        across the mesh and each shard_map shard sees exactly its own
        sequences. Returns (nb, slot->row, per-device slot groups)."""
        n = self.n_kv_shards
        groups: list[list] = [[] for _ in range(n)]
        for i in slots:
            groups[self.pool.assign_home(self.slot_req[i].rid)].append(i)
        rpd = _bucket(max([len(g) for g in groups] + [1]),
                      lo=max(1, base // n))
        row_of = {i: d * rpd + j
                  for d, g in enumerate(groups) for j, i in enumerate(g)}
        return rpd * n, row_of, groups

    def _tiles_touched(self, needs_by_dev: list, stage_s: int,
                       bounded: bool, splits: int = 1
                       ) -> tuple[int, int, list, int]:
        """(tiles the kernel's R port touches, ideal ceil-bound, per-device
        tile reads, critical-path chain) summed over the traversals of the
        per-device live-length groups against a ``stage_s``-long staging
        cache. The dynamic grid is bounded PER DEVICE — each shard's
        traversal stops at ITS OWN live-tile count. Unbounded traversals
        touch every grid tile.

        The CRITICAL chain is the step's latency proxy: batch rows (and
        devices) are grid-parallel, so a step takes as long as its longest
        single dependent accumulation chain. Serially that is the longest
        row's tile count; under split-KV (``splits > 1``) each row's chain
        shortens to ``ceil(chain / splits)`` partial chains running in
        parallel plus one LSE-combine step. Total tiles touched are
        UNCHANGED by splits — same tiles, parallel chains — which is why
        the tile-bound gate needs no split awareness."""
        tile = fit_seq_tile(stage_s, self.seq_tile)
        grid_full = stage_s // tile
        per_dev, bound_total, critical = [], 0, 0
        for needs in needs_by_dev:
            grid = grid_full
            if bounded and self.dynamic_grid and needs:
                # each shard's dynamic grid stops at its live-tile count
                grid = min(grid, max(1, max(-(-n // tile) for n in needs)))
            bound = sum(min(-(-n // tile), grid) for n in needs)
            touched = bound if bounded else grid * len(needs)
            per_dev.append(touched)
            bound_total += bound
            for n in needs:
                chain = min(-(-n // tile), grid) if bounded else grid
                if splits > 1:
                    chain = -(-chain // splits) + 1       # + the combine
                critical = max(critical, chain)
        return sum(per_dev), bound_total, per_dev, critical

    def _kv_words(self, cache_k, cache_v, slot: int, t0: int, t1: int
                  ) -> np.ndarray:
        """Flatten cache positions [t0, t1) of one slot into pool words."""
        k = np.asarray(cache_k[:, slot, t0:t1], np.float32)   # [L, T, hkv, hd]
        v = np.asarray(cache_v[:, slot, t0:t1], np.float32)
        w = np.stack([k, v], axis=1)                          # [L, 2, T, ...]
        w = np.moveaxis(w, 2, 0)                              # [T, L, 2, ...]
        return w.reshape(t1 - t0, -1)

    def _reserved_pages_by_shard(self) -> list[int]:
        """Worst-case pages every LIVE slot may still carve from its home
        shard: a request commits at most ``len(prompt) + max_new - 1``
        words (the final token's KV never lands — eviction precedes its
        append), so its outstanding claim is that ceiling minus the pages
        it already holds. The admission precheck (and the chaos harness's
        quarantine floor) subtracts these reservations from the free
        lists, so admitting a new request — or quarantining pages — can
        never strand a request that was already admitted."""
        reserved = [0] * self.n_kv_shards
        pt = self.pool.page_tokens
        for r in self.slot_req:
            if r is None:
                continue
            worst = len(r.prompt) + r.max_new - 1
            held = len(self.pool.tables.get(r.rid, ()))
            # a shared tail page is write-private: the next append will
            # copy-on-write it, carving one page beyond plain table growth
            need = (max(0, -(-worst // pt) - held)
                    + self.pool.pending_cow_pages(r.rid))
            reserved[self.pool.assign_home(r.rid)] += need
        return reserved

    def _collect_prefill(self) -> list:
        """Port B: admit queued requests into free (or newly grown) slots,
        then advance EVERY mid-prefill slot by one fixed-size token chunk.
        Chunks from different requests are stacked into one padded batch, run
        through a single chunked-prefill compute step, and all chunks' K,V
        become streams of the SAME bulk-write port transaction."""
        nl, _, hkv, hd = self._kv_dims
        # arrival-ordered admission wave: only the QUEUE HEAD is ever
        # eligible (AdmissionQueue.pop_ready) — under slot contention a
        # freed slot goes to the oldest ready request, never a younger
        # shorter one (FIFO; no long-prompt starvation). Overload safety
        # wraps the same loop: a degraded controller caps admissions per
        # cycle, and each candidate head passes the pool's capacity
        # precheck BEFORE it is popped — a full home shard parks the head
        # (retry next cycle, after evictions free pages) instead of
        # raising mid-admission, and a head that exhausts its retry
        # budget is shed.
        now = self.vclock
        cap = self.overload.cap() if self.overload is not None else None
        admitted_now = 0
        reserved = None
        while self.admission.head_ready(now):
            if cap is not None and admitted_now >= cap:
                break
            head = self.admission.head()
            if reserved is None:
                reserved = self._reserved_pages_by_shard()
            # prefix-aware admission: match BEFORE the capacity precheck,
            # so matched pages (attachable by refcount bump) never count
            # as demand and the probe moves to the prefix's shard
            match, worst = prefix_admission_plan(
                self.pool, head.prompt, head.max_new,
                enabled=self.prefix_cache)
            try:
                shard = self.pool.admission_precheck(
                    head.rid, worst, reserved_by_shard=reserved,
                    prefix=match)
            except PoolCapacityError:
                if head.capacity_retries >= self.capacity_retry_limit:
                    # eviction-aware backoff exhausted: shed (drop_head
                    # keeps the admitted counter honest)
                    self.admission.drop_head()
                    self._shed(head, "capacity")
                    continue
                head.capacity_retries += 1
                self.capacity_parked_cycles += 1
                break       # park: this cycle's evictions already ran,
                            # retry after the NEXT cycle frees pages
            slot = self._free_slot()
            if slot is None:
                # a ready arrival waited this cycle on a full slot table
                self.slot_contention_cycles += 1
                break
            req = self.admission.pop_ready(now)
            admitted_now += 1
            if req.capacity_retries:
                self.capacity_recoveries += 1
            full = match.full_pages if match is not None else 0
            reserved[shard] += max(
                0, -(-worst // self.pool.page_tokens) - full)
            req.slot = slot
            req.admit_cycle = self.cycles
            req.admit_tick = now
            if slot in self._freed_slots_this_cycle:
                # admission only proceeded because this cycle's EVICT
                # phase freed the slot — eviction-pressure signal
                self.evict_pressure_admissions += 1
            if self.cfg.input_mode == "embeddings":
                raise NotImplementedError("engine demo serves token models")
            self.slot_req[slot] = req
            attached = 0
            if match is not None:
                # adopt the matched prefix by refcount bump: the request's
                # home FOLLOWS the shared pages' shard, its table starts at
                # the matched pages, and prefill resumes at the tail
                self.pool.attach_prefix(req.rid, match)
                attached = match.tokens
            # device-aware placement: the home shard is fixed at admission
            # (least-loaded, or the prefix's shard), BEFORE the first page
            # is carved, so the first chunk's compute can already be
            # grouped onto its device
            self.pool.assign_home(req.rid)
            self.slot_len[slot] = attached
            ps = _PrefillState(
                consumed=attached,
                stage_k=np.zeros((nl, self.max_len, hkv, hd), np.float32),
                stage_v=np.zeros((nl, self.max_len, hkv, hd), np.float32))
            if attached:
                # the chunk compute attends over the STAGED running cache,
                # not the pool — backfill the stage with the adopted words
                # (inverse of _kv_words) so the tail's attention sees the
                # prefix KV it never computed
                w = self.pool.gather_words(req.rid, np.arange(attached))
                w = w.reshape(attached, nl, 2, hkv, hd)
                ps.stage_k[:, :attached] = np.moveaxis(w[:, :, 0], 0, 1)
                ps.stage_v[:, :attached] = np.moveaxis(w[:, :, 1], 0, 1)
            self._prefilling[slot] = ps
        if not self._prefilling:
            return []

        # one padded chunk batch across all prefilling slots (batch dim
        # bucketed to a power of two so admissions don't retrace the jit);
        # the staging caches cover a bucketed LIVE prefix, not max_len, so
        # the chunk kernel's tile grid is bounded by the longest live prefix
        order = sorted(self._prefilling)
        # a degraded overload controller shrinks the per-cycle chunk (the
        # generated tokens are unchanged — chunked prefill is chunk-size
        # invariant — only the per-cycle port-traffic shape moves)
        c = (self.overload.chunk_tokens(self.chunk_tokens)
             if self.overload is not None else self.chunk_tokens)
        if self.n_kv_shards == 1:
            nb = _bucket(len(order), lo=1)
            row_of = {s: j for j, s in enumerate(order)}
            groups = [list(order)]
        else:
            nb, row_of, groups = self._group_rows(order, base=1)
        need_of = {s: self._prefilling[s].consumed
                   + min(c, len(self.slot_req[s].prompt)
                         - self._prefilling[s].consumed) for s in order}
        stage_s = self._stage_len(max(need_of.values()))
        live = min(stage_s, self.max_len)   # last bucket may pad past max_len
        toks = np.zeros((nb, c), np.int32)
        clen = np.zeros((nb,), np.int32)
        offs = np.full((nb,), self._dead_row, np.int32)
        stage_k = self._stage_bufs.get(("prefill", "k"),
                                       (nl, nb, stage_s, hkv, hd))
        stage_v = self._stage_bufs.get(("prefill", "v"),
                                       (nl, nb, stage_s, hkv, hd))
        for slot in order:
            j = row_of[slot]
            ps = self._prefilling[slot]
            req = self.slot_req[slot]
            t0 = ps.consumed
            n = min(c, len(req.prompt) - t0)
            toks[j, :n] = req.prompt[t0:t0 + n]
            clen[j] = n
            offs[j] = t0
            stage_k[:, j, :live] = ps.stage_k[:, :live]
            stage_v[:, j, :live] = ps.stage_v[:, :live]

        state = {"len": jnp.asarray(offs),
                 "cache_k": jnp.asarray(stage_k),
                 "cache_v": jnp.asarray(stage_v)}
        st, logits = self._prefill_chunk(self.params, state,
                                         {"inputs": jnp.asarray(toks),
                                          "chunk_len": jnp.asarray(clen)})
        ck, cv = np.asarray(st["cache_k"]), np.asarray(st["cache_v"])
        lg = np.asarray(logits)
        # the chunk kernel masks dead tiles per sequence; the jnp reference
        # reads the whole staged cache densely per chunk
        touched, _, per_dev, _ = self._tiles_touched(
            [[need_of[s] for s in g] for g in groups], stage_s,
            bounded=self._fused_compute)
        self.prefill_tile_reads += touched
        for d, t in enumerate(per_dev):
            self.prefill_tile_reads_by_dev[d] += t
        self.prefill_chunks += len(order)

        streams = []
        for slot in order:
            j = row_of[slot]
            ps = self._prefilling[slot]
            req = self.slot_req[slot]
            t0, n = int(offs[j]), int(clen[j])
            ps.stage_k[:, :live] = ck[:, j, :live]
            ps.stage_v[:, :live] = cv[:, j, :live]
            streams.append({"seq": req.rid,
                            "vectors": self._kv_words(ck, cv, j, t0, t0 + n)})
            ps.consumed = t0 + n
            self.slot_len[slot] += n          # committed later this same cycle
            self.prefill_tokens += n
            if ps.consumed == len(req.prompt):
                # prefill complete: the FIRST generated token comes from the
                # prefill logits (no re-feed of prompt[-1] through decode)
                del self._prefilling[slot]
                if self.prefix_cache:
                    # registration is deferred past this cycle's pool
                    # commit — the final chunk's words are not in the pool
                    # yet, and nothing can match before the next cycle's
                    # admissions anyway
                    self._register_pending.append((req.rid,
                                                   tuple(req.prompt)))
                req.generated.append(int(np.argmax(lg[j])))
                if len(req.generated) >= req.max_new:
                    req.done = True
                # stamped AFTER this cycle's pool commit (the token isn't
                # "served" until its KV traversal lands) — see step()
                self._token_events.append(req)
            elif self.prefix_cache:
                # register the full pages committed so far: a sharer that
                # arrives mid-prefill can attach the in-progress prefix
                # instead of waiting for completion. Only whole pages — a
                # partial-tail entry would end the chain and permanently
                # shadow the full-page entry (first registration wins),
                # so the sub-page tail is left for the completion call.
                pt = self.pool.page_tokens
                full = ps.consumed - ps.consumed % pt
                if full >= pt:
                    self._register_pending.append(
                        (req.rid, tuple(req.prompt[:full])))
        return streams

    def _collect_decode(self):
        """Port C: pending appends (last step's KV words) + attention-read
        gathers for every active slot."""
        appends = [{"seq": self.slot_req[i].rid, "vectors": w[None]}
                   for i, w in sorted(self._pending.items())
                   if self.slot_req[i] is not None]
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and not r.done
                  and i not in self._prefilling]
        reads = [{"seq": self.slot_req[i].rid,
                  "positions": np.arange(self._total_len(i))}
                 for i in active]
        return appends, active, reads

    def _total_len(self, slot: int) -> int:
        """Tokens the slot will hold once this cycle's append commits."""
        return self.slot_len[slot] + (1 if slot in self._pending else 0)

    def _dispatch_decode(self, active: list, gathered: list
                         ) -> tuple[int, int, list, int, _InFlight]:
        """Dispatch one fused decode step for all active slots over staging
        caches assembled from the pool gather — WITHOUT forcing the device
        results (JAX async dispatch): retirement (``_retire``) happens at
        the start of the next macro-cycle, after the host has planned it,
        so device compute and host scheduling overlap. The staging batch is
        padded to a power-of-two bucket so slot-pool growth retraces the
        jit only at bucket edges, the staging LENGTH covers a bucketed
        count of live seq_tile tiles so the decode kernel's grid scales
        with cache_len, not max_len, and the staging buffers are
        DOUBLE-BUFFERED — the next cycle's fill never touches the buffer
        this cycle's in-flight compute was dispatched from. Under
        data-parallel KV the batch rows are grouped into contiguous
        per-home-device blocks so the shard_map'd kernel's shards line up
        with the pool's page placement.

        Returns (R-port tiles touched, ideal per-slot ceil tile bound,
        per-device tile reads, critical-path chain, the in-flight handle)
        — tile accounting is pure host arithmetic over live lengths, so it
        needs no results."""
        nl, _, hkv, hd = self._kv_dims
        if self.n_kv_shards == 1:
            nb = _bucket(len(self.slot_req), lo=self._init_slots)
            row_of = {i: i for i in active}
            groups = [list(active)]
        else:
            nb, row_of, groups = self._group_rows(
                active, base=_bucket(len(self.slot_req),
                                     lo=self._init_slots))
        need_of = {i: rows.shape[0] + 1                 # post-append lens
                   for i, rows in zip(active, gathered)}
        stage_s = self._stage_len(max(need_of.values(), default=1))
        stage_k = self._stage_bufs.get(("decode", "k"),
                                       (nl, nb, stage_s, hkv, hd))
        stage_v = self._stage_bufs.get(("decode", "v"),
                                       (nl, nb, stage_s, hkv, hd))
        lens = np.full((nb,), self._dead_row, np.int32)
        last_tokens = np.zeros((nb, 1), np.int32)
        for i, rows in zip(active, gathered):
            j = row_of[i]
            t = rows.shape[0]
            w = np.asarray(rows, np.float32).reshape(t, nl, 2, hkv, hd)
            stage_k[:, j, :t] = np.moveaxis(w[:, :, 0], 0, 1)
            stage_v[:, j, :t] = np.moveaxis(w[:, :, 1], 0, 1)
            lens[j] = t
            r = self.slot_req[i]
            seqs = r.generated or r.prompt
            last_tokens[j, 0] = seqs[-1]

        state = {"len": jnp.asarray(lens),
                 "cache_k": jnp.asarray(stage_k),
                 "cache_v": jnp.asarray(stage_v)}
        st, logits = self._decode(self.params, state,
                                  {"inputs": jnp.asarray(last_tokens)})
        inflight = _InFlight(cycle=self.cycles, vclock_end=self.vclock,
                             active=list(active), row_of=row_of, lens=lens,
                             state=st, logits=logits,
                             rids={i: self.slot_req[i].rid for i in active})
        bounded = self._fused_compute and self.length_bound
        tiles, bound, per_dev, crit = self._tiles_touched(
            [[need_of[i] for i in g] for g in groups], stage_s,
            bounded=bounded, splits=self.num_kv_splits)
        return tiles, bound, per_dev, crit, inflight

    def _retire(self, inf: _InFlight) -> None:
        """Force an in-flight decode cycle's device results and fold them
        into host state: each slot's new KV word becomes the NEXT cycle's
        append, its token lands on the request, and finished requests get
        their latency stamps — at the virtual-clock time their cycle's
        traversals committed, not the later wall moment retirement ran."""
        ck = np.asarray(inf.state["cache_k"])
        cv = np.asarray(inf.state["cache_v"])
        nxt = np.asarray(jnp.argmax(inf.logits, axis=-1))
        now_wall = time.perf_counter()
        for i in inf.active:
            j = inf.row_of[i]
            r = self.slot_req[i]
            if r is None or r.rid != inf.rids.get(i):
                # the slot was evicted (e.g. a chaos cancel) and possibly
                # reassigned while this dispatch was outstanding — folding
                # the stale row back in would corrupt the new occupant
                continue
            self._pending[i] = self._kv_words(ck, cv, j, int(inf.lens[j]),
                                              int(inf.lens[j]) + 1)[0]
            r.generated.append(int(nxt[j]))
            if len(r.generated) >= r.max_new:
                r.done = True
                r.finish_cycle = inf.cycle
                r.finish_tick = inf.vclock_end
                r.t_finish = now_wall

    def _service_status(self) -> dict:
        return {"cycle": self.cycles,
                "vclock": self.vclock,
                "queue": len(self.admission),
                "queue_ready": self.admission.ready_depth(self.vclock),
                "active": sum(r is not None and not r.done
                              for r in self.slot_req),
                "prefilling": len(self._prefilling),
                "slots": len(self.slot_req),
                "lens": [self._total_len(i) if self.slot_req[i] is not None
                         else 0 for i in range(len(self.slot_req))],
                "pool_utilization": self.pool.utilization,
                "pool_traversals": self.pool.traversals,
                "kv_shards": self.n_kv_shards,
                "shed": len(self.shed),
                "overload_state": (self.overload.state
                                   if self.overload is not None else None)}

    # ---- dependency scheduling ----------------------------------------------
    def _build_phases(self, scrub: list, admits: list, appends: list,
                      reads: list) -> list:
        """Turn the cycle's collected traffic into program-ordered
        :class:`PhaseTxn` bundles with page-granular footprints — the
        scheduler's hazard-analysis input.

        Write footprints are PROJECTED against the post-eviction free lists
        in commit order (prefills then appends — the same order
        ``PagedPool.cycle`` grows tables), so a footprint includes the tail
        page a demand fills and any free page it will pop; the decode read's
        footprint is every active sequence's mapped pages plus the pages its
        own append lands on (the intra-phase append+read pair stays ONE
        phase — the exempt same-cycle W->R contract)."""
        demands = ([(s["seq"], int(s["vectors"].shape[0])) for s in admits]
                   + [(s["seq"], int(s["vectors"].shape[0]))
                      for s in appends])
        footprints = self.pool.project_write_pages(demands)
        prefill_pages = frozenset().union(*footprints[:len(admits)]) \
            if admits else frozenset()
        append_pages = frozenset().union(*footprints[len(admits):]) \
            if appends else frozenset()

        phases = []
        if scrub:
            phases.append(PhaseTxn(EVICT, "evict", (
                PortTxn(SCRUB, WRITE, frozenset(scrub), scrub),)))
        if admits:
            phases.append(PhaseTxn(PREFILL, "prefill", (
                PortTxn(BULK_FILL, WRITE, prefill_pages, admits),)))
        if appends or reads:
            txns = []
            if appends:
                txns.append(PortTxn(APPEND, WRITE, append_pages, appends))
            if reads:
                read_pages = append_pages.union(
                    *[self.pool.mapped_pages(s["seq"]) for s in reads])
                txns.append(PortTxn(ATTN_READ, READ, read_pages, reads))
            phases.append(PhaseTxn(DECODE, "decode", tuple(txns)))
        return phases

    def _commit(self, schedule) -> list:
        """Issue a :class:`~repro.serve.scheduler.PortSchedule` against the
        pool — one :meth:`PagedPool.cycle` per traversal, each under ITS
        port config's priority, with the capacity precheck spanning every
        co-scheduled write — and return the decode gathers (empty when the
        cycle carried no reads)."""
        groups = []
        read_gi = None
        for trav in schedule.traversals:
            streams = {_STREAM_KEY[t.port]: t.payload for t in trav.txns()}
            if "read" in streams:
                read_gi = len(groups)
            groups.append((streams, trav.priority()))
        outs = self.pool.cycle_batch(groups)
        if read_gi is None:
            return []
        return outs[read_gi]["read"] or []

    # ---- the macro-cycle -----------------------------------------------------
    def step(self) -> dict:
        """One external clock cycle of the PIPELINED host loop: retire the
        previous cycle's in-flight decode (its tokens/appends feed this
        cycle's phases), walk enabled ports in priority order, issue the
        collected traffic against the physical pool, then DISPATCH this
        cycle's decode compute without forcing it — the device executes it
        while the host plans the next macro-cycle. State evolution is
        bit-identical to the synchronous loop; only the forcing point
        moved."""
        # chaos delayed retirement: while stalled the in-flight decode is
        # NOT forced this cycle (and no new decode work is collected or
        # dispatched below) — evict/admit/prefill keep running
        stalled = self.retire_stall_cycles > 0
        if stalled:
            self.retire_stall_cycles -= 1
            if self._inflight is not None:
                self.stalled_retirements += 1
        else:
            self.flush()
        # deadline shedding happens at the HEAD of the cycle, before any
        # admission decision: expired heads never reach a slot, a page, or
        # a pool traversal (head-only — see AdmissionQueue)
        for req in self.admission.shed_expired_heads(self.vclock):
            self._shed(req, "deadline")
        if self.overload is not None:
            self.overload.observe(self.admission.ready_depth(self.vclock),
                                  cycle=self.cycles, tick=self.vclock)
        self._freed_slots_this_cycle = set()
        self._token_events = []
        cfg = self._port_enables()
        sched = build_schedule(cfg)
        slots = sched.slots
        if self.single_port:
            # bare macro: one port per CLK (rotate through enabled ports)
            slots = fsm.rotate_single_port(slots, self._sp_rotate)
            self._sp_rotate += 1

        collected = {"status": {}, "scrub": [], "admits": [],
                     "appends": [], "active": [], "reads": []}

        def service(state, port):
            if port == EVICT:
                state["scrub"] = self._collect_evict()
            elif port == PREFILL:
                state["admits"] = self._collect_prefill()
            elif port == DECODE:
                if not stalled:
                    (state["appends"], state["active"],
                     state["reads"]) = self._collect_decode()
            else:
                state["status"] = self._service_status()
            return state

        walk_cfg = PortConfig(
            enabled=tuple(p in slots for p in range(4)),
            roles=cfg.roles, priority=cfg.priority)
        collected = fsm.walk_static(walk_cfg, collected, service)
        status = collected["status"]
        scrub, admits = collected["scrub"], collected["admits"]
        appends, active, reads = (collected["appends"], collected["active"],
                                  collected["reads"])

        # schedule the cycle's traffic: hazard analysis over page
        # footprints picks the per-traversal port mix, then the plan
        # commits against the physical pool in program order
        t0 = self.pool.traversals
        phases = self._build_phases(scrub, admits, appends, reads)
        plan = sched_mod.plan(phases, mode=self.schedule_mode,
                              max_ports=self.max_ports,
                              split_roles=self._split_roles)
        gathered = self._commit(plan)
        self.schedule_log.append(
            tuple(t.phase_ids() for t in plan.traversals))
        if len({ph.phase for ph in phases}) > 1:
            self.multi_phase_cycles += 1
            if plan.co_scheduled:
                self.coscheduled_cycles += 1
        for s in appends:                          # appends are now committed
            slot = next(i for i in range(len(self.slot_req))
                        if self.slot_req[i] is not None
                        and self.slot_req[i].rid == s["seq"])
            self.slot_len[slot] += 1
            self._pending.pop(slot, None)
        # completed prompts' pages join the prefix index now that their
        # final chunk's words are committed (see _collect_prefill)
        for rid, ptoks in self._register_pending:
            if rid in self.pool.tables:
                self.pool.register_prefix(rid, ptoks)
        self._register_pending = []

        dt = self.pool.traversals - t0
        if dt == 0:
            # an idle (status-only) macro-cycle still costs one virtual
            # tick — otherwise the clock would stall while the open-loop
            # engine waits on future arrivals
            self.idle_ticks += 1
        # latency stamps for this cycle's prefill-produced tokens: a first
        # token counts as served once its cycle's traversals COMMITTED, at
        # the post-commit virtual-clock reading
        now_tick, now_wall = self.vclock, time.perf_counter()
        for r in self._token_events:
            r.first_token_cycle = self.cycles
            r.first_token_tick = now_tick
            r.t_first = now_wall
            if r.done:
                r.finish_cycle = self.cycles
                r.finish_tick = now_tick
                r.t_finish = now_wall
        if admits:
            self.prefill_steps += 1
            self.prefill_traversals += dt
        if active:
            self.decode_steps += 1
            self.decode_traversals += dt
            tiles, bound, per_dev, crit, inflight = self._dispatch_decode(
                active, gathered)
            self._inflight = inflight
            self.decode_tile_reads += tiles
            self.decode_critical_tiles += crit
            for d, t in enumerate(per_dev):
                self.decode_tile_reads_by_dev[d] += t
            if appends:
                self.steady_decode_steps += 1
                self.steady_decode_traversals += dt
                self.steady_decode_tile_reads += tiles
                self.steady_decode_tile_bound += bound
                self.steady_decode_critical_tiles += crit
                for d, t in enumerate(per_dev):
                    self.steady_decode_tile_reads_by_dev[d] += t

        self.cycles += 1
        self.port_log.append(slots)
        return status

    def run(self, max_cycles: int = 10_000) -> list[Request]:
        while self.pending_work() and self.cycles < max_cycles:
            self.step()
        self.flush()
        return self.finished
