"""Multi-port serving engine: the paper's wrapper as a request scheduler.

The engine's batch of KV-cache slots IS a multi-port memory: each engine
macro-cycle (one external "CLK") services up to four logical ports against it,
in priority order, exactly as the paper's FSM walks its ports (Fig. 2):

    port A (W, priority 1): EVICT    — free finished slots
    port B (W, priority 2): PREFILL  — admit a queued request into a free slot
    port C (R/W, priority 3): DECODE — one token for every active slot
    port D (R, priority 4): STATUS   — scoreboard snapshot (lengths, slots)

Ports are enabled per-cycle by pending work (``port_en``), the service order
comes from core.clockgen.build_schedule, and utilization per cycle is
recorded for the engine benchmark. The single-port baseline
(``single_port=True``) services ONE port per cycle — the paper's bare-macro
comparison; benchmarks/engine.py measures the throughput ratio (claim C1 at
the system level: ~Nx fewer cycles at equal work).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.clockgen import build_schedule
from repro.core.ports import READ, WRITE, PortConfig
from repro.models import decode_step, init_decode_state, prefill

EVICT, PREFILL, DECODE, STATUS = 0, 1, 2, 3


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    done: bool = False


class MultiPortEngine:
    def __init__(self, params, cfg: ArchConfig, *, slots: int = 4,
                 max_len: int = 256, prefill_bucket: int = 32,
                 kernel_mode: str = "reference", single_port: bool = False,
                 greedy: bool = True):
        if cfg.family not in ("dense", "moe", "vlm", "audio"):
            raise ValueError("engine currently serves KV-cache families")
        self.params, self.cfg = params, cfg
        self.n_slots, self.max_len = slots, max_len
        self.bucket = prefill_bucket
        self.single_port = single_port
        self.state = init_decode_state(cfg, slots, max_len)
        self.slot_req: list[Optional[Request]] = [None] * slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.cycles = 0
        self.port_log: list[tuple[int, ...]] = []
        self._next_rid = 0
        self._sp_rotate = 0

        self._decode = jax.jit(
            lambda p, s, b: decode_step(p, cfg, s, b, kernel_mode=kernel_mode))
        self._prefill1 = jax.jit(lambda p, s, b: prefill(p, cfg, s, b))

    # ---- client API --------------------------------------------------------
    def submit(self, prompt: list[int], max_new: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, list(prompt), max_new))
        return rid

    def pending_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slot_req)

    # ---- port service routines ----------------------------------------------
    def _port_enables(self) -> PortConfig:
        finished = any(r is not None and r.done for r in self.slot_req)
        free = any(r is None for r in self.slot_req)
        admit = bool(self.queue) and free
        active = any(r is not None and not r.done for r in self.slot_req)
        enabled = (finished, admit, active, True)
        if not any(enabled[:3]):
            enabled = (False, False, False, True)
        return PortConfig(enabled=enabled,
                          roles=(WRITE, WRITE, WRITE, READ))

    def _service_evict(self) -> None:
        for i, r in enumerate(self.slot_req):
            if r is not None and r.done:
                self.finished.append(r)
                self.slot_req[i] = None

    def _service_prefill(self) -> None:
        if not self.queue:
            return
        slot = next((i for i, r in enumerate(self.slot_req) if r is None), None)
        if slot is None:
            return
        req = self.queue.popleft()
        req.slot = slot
        # bucket-pad the prompt, run a single-request prefill, splice caches
        plen = len(req.prompt)
        bucket = min(self.max_len,
                     max(self.bucket, 1 << (plen - 1).bit_length()))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = req.prompt
        sub = init_decode_state(self.cfg, 1, self.max_len)
        batch = {"inputs": jnp.asarray(toks)}
        if self.cfg.input_mode == "embeddings":
            raise NotImplementedError("engine demo serves token models")
        sub, _ = self._prefill1(self.params, sub, batch)
        # write ports into the engine state: splice slot `slot`
        st = dict(self.state)
        for k in ("cache_k", "cache_v"):
            st[k] = jax.lax.dynamic_update_slice(
                st[k], sub[k], (0, slot, 0, 0, 0))
        st["len"] = st["len"].at[slot].set(plen)   # true length, not bucket
        self.state = st
        self.slot_req[slot] = req

    def _service_decode(self) -> None:
        active = [i for i, r in enumerate(self.slot_req)
                  if r is not None and not r.done]
        if not active:
            return
        last_tokens = np.zeros((self.n_slots, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            seqs = r.generated or r.prompt
            last_tokens[i, 0] = seqs[-1]
        prev_len = self.state["len"]
        st, logits = self._decode(self.params, self.state,
                                  {"inputs": jnp.asarray(last_tokens)})
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        # inactive slots: undo the length advance (their KV write is benign —
        # it lands at their stale cursor and is overwritten on reuse)
        mask = np.zeros((self.n_slots,), bool)
        for i in active:
            mask[i] = True
        st = dict(st, len=jnp.where(jnp.asarray(mask), st["len"], prev_len))
        self.state = st
        for i in active:
            r = self.slot_req[i]
            r.generated.append(int(nxt[i]))
            if len(r.generated) >= r.max_new:
                r.done = True

    def _service_status(self) -> dict:
        return {"cycle": self.cycles,
                "queue": len(self.queue),
                "active": sum(r is not None and not r.done
                              for r in self.slot_req),
                "lens": np.asarray(self.state["len"]).tolist()}

    # ---- the macro-cycle -----------------------------------------------------
    def step(self) -> dict:
        """One external clock cycle: walk enabled ports in priority order."""
        cfg = self._port_enables()
        sched = build_schedule(cfg)
        slots = sched.slots
        if self.single_port:
            # bare macro: one port per CLK (rotate through enabled ports)
            slots = (slots[self._sp_rotate % len(slots)],)
            self._sp_rotate += 1
        status = {}
        for port in slots:
            if port == EVICT:
                self._service_evict()
            elif port == PREFILL:
                self._service_prefill()
            elif port == DECODE:
                self._service_decode()
            else:
                status = self._service_status()
        self.cycles += 1
        self.port_log.append(slots)
        return status

    def run(self, max_cycles: int = 10_000) -> list[Request]:
        while self.pending_work() and self.cycles < max_cycles:
            self.step()
        return self.finished
