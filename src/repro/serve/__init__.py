"""repro.serve subpackage."""
