"""Open-loop traffic generation: seeded arrival processes with heavy-tailed
prompt/output lengths drawn over the config registry's scenario spread.

Every bench before this one was CLOSED-loop: all requests submitted up
front, the engine drained at its own pace, and the gates were ratio-shaped
(traversals, tiles, traces). The paper's pitch — bandwidth for
multi-connected devices under real traffic — only cashes out if the
configurable port mix holds tail latency when arrivals are bursty and
lengths are heavy-tailed, the regime the flexible multi-port memory
controller literature (arXiv 1712.03477) evaluates with open-loop request
streams. This module provides that stream:

* :func:`poisson_arrivals` — a seeded Poisson process (exponential
  inter-arrivals at ``rate`` requests per VIRTUAL TICK — see below) whose
  per-request prompt/output lengths are bounded-Pareto heavy-tailed
  (``alpha`` ~ 1.2: most requests short, a fat tail of long ones), scaled
  per request by a scenario drawn from the registry spread.
* :func:`trace_arrivals` / :func:`write_trace` — JSONL trace replay (and
  its inverse), so measured or hand-built schedules rerun bit-identically.
* :func:`scenario_spread` — one scenario per registry architecture, its
  length scale derived deterministically from the arch's reduced geometry
  (layers x heads x head_dim as a proxy for the context its deployments
  carry). The engine under test serves ONE architecture's weights, so
  scenarios modulate LENGTHS (and tag the request), not token ids.

**Shared-prefix pools (PR 9).** Real traffic is not i.i.d. tokens:
requests reusing one deployment share its system prompt / few-shot
header, which is exactly what the pool's refcounted prefix cache
exploits. A :class:`Scenario` can therefore carry a CONTENT pool —
``shared_prefixes`` distinct headers of ``prefix_tokens`` tokens each —
and every request drawn under that scenario has its prompt's head
replaced by one of those headers (at least one trailing token always
stays request-private, so prompts never fully collide). The headers come
from a PER-SCENARIO rng seeded by ``(seed, crc32(name))`` — ``crc32``
because ``hash(str)`` is randomized per process — so the MAIN rng stream
is consumed identically with pools on or off: lengths, arrival ticks and
body tokens of every other scenario are bit-identical, and the default
(pool-less) spread reproduces PR 7 schedules exactly. Prompts serialize
whole, so traces round-trip with no special casing.

**The clock is virtual.** Arrival times are in POOL-TRAVERSAL ticks — the
engine's hardware time unit (one tick = one physical pool traversal; an
idle macro-cycle costs one tick). Scheduling arrivals in ticks is what
makes the harness genuinely open-loop: the arrival process does not slow
down because the server got slower, so a scheduler that spends more
traversals per macro-cycle (``schedule_mode="static"``) faces the same
tick schedule with less capacity and its queues — and tail latency — grow.
Determinism on CI falls out: same seed, same schedule, same percentiles;
wall-clock timing is recorded alongside but never gates.

Same seed => identical arrival schedule, bit-for-bit
(``tests/serve/test_traffic.py`` pins it).
"""
from __future__ import annotations

import dataclasses
import json
import time
import zlib
from collections import deque
from typing import Optional, Sequence

import numpy as np

from repro.configs import registry


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: arrival time in virtual ticks + its payload."""

    arrival_tick: int
    prompt: tuple                  # token ids
    max_new: int
    scenario: str = ""

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A traffic profile: length scale factors applied to the base
    heavy-tailed prompt/output draws, tagged with the registry arch that
    induced it — plus an optional shared-header content pool
    (``shared_prefixes`` headers x ``prefix_tokens`` tokens) modelling
    the deployment's common system prompt / few-shot preamble."""

    name: str
    prompt_scale: float
    output_scale: float
    shared_prefixes: int = 0       # pool size; 0 = length-only scenario
    prefix_tokens: int = 0         # header length in tokens

    def __post_init__(self):
        if self.shared_prefixes < 0 or self.prefix_tokens < 0:
            raise ValueError(f"negative prefix pool geometry: {self}")
        if bool(self.shared_prefixes) != bool(self.prefix_tokens):
            raise ValueError(
                "shared_prefixes and prefix_tokens must be both zero or "
                f"both positive, got {self.shared_prefixes}/"
                f"{self.prefix_tokens}")


def scenario_spread(arch_ids: Optional[Sequence[str]] = None, *,
                    shared_prefixes: int = 0, prefix_tokens: int = 0
                    ) -> tuple[Scenario, ...]:
    """One scenario per registry architecture, length scales spread over
    [0.5x, 2.0x] by the arch's reduced attention geometry (layers x heads x
    head_dim — a deterministic, config-derived proxy for how long that
    arch's deployments run). The spread is what keeps the traffic mix from
    collapsing to one effective length distribution. ``shared_prefixes``/
    ``prefix_tokens`` give EVERY scenario in the spread its own header
    pool of that geometry (the headers themselves still differ per
    scenario — each pool is seeded off the scenario name); the zero
    default keeps the spread length-only, exactly PR 7's behavior."""
    ids = tuple(arch_ids) if arch_ids is not None else registry.ARCH_IDS
    sizes = {}
    for a in ids:
        cfg = registry.get(a, reduced=True)
        hd = cfg.head_dim or cfg.d_model // cfg.n_heads
        sizes[a] = cfg.n_layers * cfg.n_heads * hd
    lo, hi = min(sizes.values()), max(sizes.values())
    span = max(hi - lo, 1)

    def _scale(v: int) -> float:
        return 0.5 * 4.0 ** ((v - lo) / span)          # 0.5 .. 2.0

    return tuple(
        Scenario(name=a, prompt_scale=_scale(sizes[a]),
                 # outputs skew shorter than prompts but keep the spread
                 output_scale=0.5 + 0.5 * _scale(sizes[a]),
                 shared_prefixes=shared_prefixes,
                 prefix_tokens=prefix_tokens)
        for a in ids)


def _bounded_pareto(rng: np.random.Generator, alpha: float, lo: float,
                    hi: float, size: int) -> np.ndarray:
    """Bounded Pareto(alpha) on [lo, hi] via inverse-CDF — heavy-tailed
    (most mass near ``lo``, a fat tail toward ``hi``) yet hard-bounded so
    every draw fits the engine's ``max_len`` budget."""
    u = rng.random(size)
    ratio = (lo / hi) ** alpha
    return lo / (1.0 - u * (1.0 - ratio)) ** (1.0 / alpha)


def poisson_arrivals(n_requests: int, rate: float, *, seed: int, vocab: int,
                     max_prompt: int, max_output: int, min_prompt: int = 2,
                     min_output: int = 1, alpha: float = 1.2,
                     scenarios: Optional[Sequence[Scenario]] = None
                     ) -> tuple[Arrival, ...]:
    """A seeded open-loop schedule: ``n_requests`` Poisson arrivals at
    ``rate`` requests per virtual tick, each with bounded-Pareto prompt and
    output lengths scaled by a per-request scenario drawn uniformly from
    ``scenarios`` (default: the full registry spread). Deterministic in
    ``seed``; token ids uniform over ``vocab``.

    Scenarios carrying a shared-prefix pool overlay one of their headers
    onto each request's prompt head (the body keeps the request-private
    draw, and at least the final token always stays private). Headers and
    header picks come from per-scenario rngs seeded ``(seed,
    crc32(name))`` so the main stream is consumed identically whether any
    scenario has a pool or not — ticks, lengths, scenario assignment and
    body tokens never move when pools are switched on."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if not min_prompt <= max_prompt:
        raise ValueError(f"bad prompt bounds [{min_prompt}, {max_prompt}]")
    if not min_output <= max_output:
        raise ValueError(f"bad output bounds [{min_output}, {max_output}]")
    scen = tuple(scenarios) if scenarios is not None else scenario_spread()
    rng = np.random.default_rng(seed)
    headers: dict = {}      # scenario index -> (header tuples, pick rng)
    for j, s in enumerate(scen):
        if s.shared_prefixes:
            hrng = np.random.default_rng(
                [seed, zlib.crc32(s.name.encode())])
            headers[j] = (tuple(
                tuple(int(t) for t in rng_row)
                for rng_row in hrng.integers(
                    0, vocab, (s.shared_prefixes, s.prefix_tokens))), hrng)
    gaps = rng.exponential(1.0 / rate, n_requests)
    ticks = np.floor(np.cumsum(gaps)).astype(np.int64)
    plen = _bounded_pareto(rng, alpha, min_prompt, max_prompt, n_requests)
    olen = _bounded_pareto(rng, alpha, min_output, max_output, n_requests)
    which = rng.integers(0, len(scen), n_requests)
    out = []
    for i in range(n_requests):
        s = scen[which[i]]
        p = int(np.clip(round(plen[i] * s.prompt_scale),
                        min_prompt, max_prompt))
        o = int(np.clip(round(olen[i] * s.output_scale),
                        min_output, max_output))
        prompt = tuple(int(t) for t in rng.integers(0, vocab, p))
        if which[i] in headers:
            pool, hrng = headers[which[i]]
            head = pool[int(hrng.integers(0, len(pool)))]
            k = min(len(head), p - 1)   # last token stays request-private
            if k > 0:
                prompt = head[:k] + prompt[k:]
        out.append(Arrival(arrival_tick=int(ticks[i]), prompt=prompt,
                           max_new=o, scenario=s.name))
    return tuple(out)


@dataclasses.dataclass
class DriveResult:
    """What one open-loop run did: the per-cycle ready-queue-depth samples
    and wall seconds the pre-overload harness returned, plus the
    load-shedding ledger (every overload decision the engine made, counted
    by reason) the bench's overload section gates on. Iterates as the
    legacy ``(qdepth, wall)`` pair so existing unpacking call sites keep
    working."""

    qdepth: list
    wall: float
    submitted: int = 0
    served: int = 0
    shed: int = 0                   # total, any reason
    shed_deadline: int = 0
    shed_queue_full: int = 0
    shed_capacity: int = 0
    capacity_recoveries: int = 0    # parked heads later admitted
    cancelled: int = 0              # chaos mid-stream cancellations
    degraded_cycles: int = 0        # cycles the overload controller degraded
    overload_transitions: int = 0

    def __iter__(self):
        return iter((self.qdepth, self.wall))


def drive(eng, arrivals: Sequence[Arrival], max_cycles: int = 20_000,
          on_cycle=None) -> DriveResult:
    """The open-loop host loop: submit each arrival once the engine's
    virtual clock reaches its tick, step macro-cycles continuously
    (fast-forwarding idle stretches with :meth:`advance_idle` so the clock
    never stalls), and retire the last in-flight dispatch at the end.
    Returns a :class:`DriveResult` (unpacks as the legacy ``(qdepth,
    wall)`` pair); latency stamps land on the engine's request objects and
    shed requests land in ``eng.shed`` with their reason. ``on_cycle``
    (the chaos harness's injection point) is called with the engine after
    each cycle's arrivals are submitted and ONLY on cycles that will
    actually step a macro-cycle, immediately before that step — a fault
    injected there shapes the very cycle it is due in. Idle fast-forwards
    deliberately skip it: injecting before discovering there is no pending
    work would land the fault on a cycle that never runs a traversal, so
    its effective tick silently drifts past ``advance_idle``'s jump (the
    harness stamps any residual drift on each injected record)."""
    pending = deque(arrivals)
    qdepth: list[int] = []
    t0 = time.perf_counter()
    while pending or eng.pending_work() or eng.has_inflight:
        while pending and pending[0].arrival_tick <= eng.vclock:
            a = pending.popleft()
            eng.submit(list(a.prompt), a.max_new, arrival_tick=a.arrival_tick)
        if not eng.pending_work():
            if pending:
                # idle until the next scheduled arrival — the virtual
                # clock keeps ticking, the engine does not spin
                eng.advance_idle(max(int(pending[0].arrival_tick)
                                     - eng.vclock, 1))
                continue
            eng.flush()
            continue
        if on_cycle is not None:
            on_cycle(eng)
        eng.step()
        qdepth.append(eng.admission.ready_depth(eng.vclock))
        if eng.cycles >= max_cycles:
            break
    eng.flush()
    ov = getattr(eng, "overload", None)
    return DriveResult(
        qdepth=qdepth, wall=time.perf_counter() - t0,
        submitted=len(arrivals), served=len(eng.finished),
        shed=len(eng.shed), shed_deadline=eng.shed_deadline,
        shed_queue_full=eng.shed_queue_full,
        shed_capacity=eng.shed_capacity,
        capacity_recoveries=eng.capacity_recoveries,
        cancelled=eng.cancelled,
        degraded_cycles=ov.degraded_cycles if ov is not None else 0,
        overload_transitions=len(ov.transitions) if ov is not None else 0)


def write_trace(path: str, arrivals: Sequence[Arrival]) -> None:
    """Persist a schedule as JSONL — one ``{"arrival", "prompt", "max_new",
    "scenario"}`` object per line — the replayable inverse of
    :func:`trace_arrivals`."""
    with open(path, "w") as f:
        for a in arrivals:
            f.write(json.dumps({"arrival": a.arrival_tick,
                                "prompt": list(a.prompt),
                                "max_new": a.max_new,
                                "scenario": a.scenario}) + "\n")


def trace_arrivals(path: str, *, vocab: int, seed: int = 0
                   ) -> tuple[Arrival, ...]:
    """Replay a JSONL trace. Each line needs ``arrival`` and ``max_new``
    plus EITHER ``prompt`` (explicit token ids) or ``prompt_len`` (ids
    filled deterministically from ``seed``). Lines must be sorted by
    arrival; malformed lines raise with their line number."""
    rng = np.random.default_rng(seed)
    out: list[Arrival] = []
    last = None
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
                tick = int(rec["arrival"])
                max_new = int(rec["max_new"])
                if "prompt" in rec:
                    prompt = tuple(int(t) for t in rec["prompt"])
                else:
                    prompt = tuple(
                        int(t) for t in
                        rng.integers(0, vocab, int(rec["prompt_len"])))
            except (KeyError, TypeError, ValueError) as e:
                raise ValueError(f"{path}:{ln}: bad trace line: {e}") from e
            if not prompt:
                raise ValueError(f"{path}:{ln}: empty prompt")
            if last is not None and tick < last:
                raise ValueError(
                    f"{path}:{ln}: arrivals must be sorted "
                    f"({tick} after {last})")
            last = tick
            out.append(Arrival(arrival_tick=tick, prompt=prompt,
                               max_new=max_new,
                               scenario=str(rec.get("scenario", "trace"))))
    return tuple(out)
