"""Fault-injection chaos harness for the multi-port serving engine.

The overload layer (deadlines, bounded admission, capacity retry, graceful
degradation) is only trustworthy if it holds under the failures it was
built for — and those failures must be REPRODUCIBLE, or a CI pass means
nothing. This module makes fault injection a seeded, virtual-clock-
scheduled experiment:

* :class:`FaultPlan` — a deterministic schedule of faults
  (``FaultPlan.generate(seed, horizon)``: same seed, same plan,
  bit-for-bit), each fault pinned to a virtual tick. Three kinds:

  - ``squeeze``: an admission-time capacity squeeze — quarantine N free
    pages per shard (``PagedPool.quarantine``) for a bounded duration,
    then release. The quarantine respects the engine's worst-case
    reservations (``keep_free``), so a squeeze pressures ADMISSION —
    requests park, retry after evictions, or shed — without ever making
    an already-admitted sequence's append fail mid-stream.
  - ``cancel``: mid-stream request cancellation — a live slot picked
    deterministically from the plan's pre-drawn choice is marked done
    (``MultiPortEngine.cancel``) and its slot + pages are freed through
    the NORMAL evict/scrub path next cycle; no bespoke teardown.
  - ``stall``: delayed retirement of the async-dispatched decode
    (``MultiPortEngine.stall_retirement``) — the in-flight device work
    stays un-forced for N macro-cycles while the host keeps evicting,
    admitting, and prefilling.

* :func:`check_invariants` — the engine-wide consistency audit the
  harness runs after EVERY injection and release (and once more at the
  end): free ∪ quarantined ∪ ⋃mapped-with-multiplicity partitions pool
  capacity (a page in k tables is owned exactly k times, all by its
  refcount; free/quarantined pages are owned once and never also
  mapped), refcounts equal table multiplicity exactly, quarantine never
  holds a referenced page, prefix-index registrations only cover live
  pages, no orphaned page tables (every table belongs to a live slot),
  page tables sized exactly for their sequence's committed words, every
  page on the shard its free list / table placement claims, and slot
  bookkeeping in sync with the pool. A violation raises
  :class:`InvariantViolation` — a hard CI failure, never a warning.

* :class:`ChaosHarness` — plugs into ``drive(..., on_cycle=harness)``:
  fires due faults before the macro-cycle they are scheduled in, releases
  expiring squeezes, and keeps the ``distributed/fault.py`` liveness
  helpers wired in: a :class:`~repro.distributed.fault.Heartbeat` beats
  once per driven cycle (when given a directory), and a
  :class:`~repro.distributed.fault.StragglerDetector` watches the
  VIRTUAL-tick duration of each driven cycle — a parked/stalled stretch
  that fast-forwards the clock shows up as a deterministic straggler
  event, counted in ``straggler_events``.

The end-to-end contract (``benchmarks/serve_bench.py --chaos-seed`` and
``tests/serve/test_chaos.py``): every fault passes the invariant audit,
and SURVIVORS — requests neither shed nor cancelled — finish with tokens
identical to a fault-free run of the same arrival schedule.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.distributed.fault import Heartbeat, StragglerDetector

KINDS = ("squeeze", "cancel", "stall")


class InvariantViolation(AssertionError):
    """An engine/pool consistency invariant broke after a fault."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault: what to inject and when (virtual ticks)."""

    tick: int                   # virtual-clock tick the fault fires at
    kind: str                   # "squeeze" | "cancel" | "stall"
    magnitude: int = 1          # squeeze: pages/shard; stall: cycles
    duration: int = 0           # squeeze: ticks until release
    choice: float = 0.0         # cancel: pre-drawn pick in [0, 1)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind: {self.kind!r}")
        if self.tick < 0 or self.magnitude < 1 or self.duration < 0:
            raise ValueError(f"bad fault geometry: {self}")
        if not 0.0 <= self.choice < 1.0:
            raise ValueError(f"choice must be in [0, 1), got {self.choice}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable schedule of faults, sorted by tick."""

    seed: int
    faults: tuple

    @classmethod
    def generate(cls, seed: int, horizon: int, *, n_faults: int = 6,
                 kinds: tuple = KINDS, max_squeeze: int = 2,
                 max_stall: int = 3, max_duration: int = 24) -> "FaultPlan":
        """Draw ``n_faults`` faults uniformly over ``[0, horizon)`` ticks
        with kinds cycled from ``kinds`` (every kind exercised) and
        magnitudes/durations drawn from the seeded rng — deterministic:
        same arguments, same plan, bit-for-bit."""
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        if n_faults < 1:
            raise ValueError(f"n_faults must be >= 1, got {n_faults}")
        for k in kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind: {k!r}")
        rng = np.random.default_rng(seed)
        ticks = np.sort(rng.integers(0, horizon, n_faults))
        faults = []
        for i, t in enumerate(ticks):
            kind = kinds[i % len(kinds)]
            faults.append(Fault(
                tick=int(t), kind=kind,
                magnitude=int(rng.integers(
                    1, (max_squeeze if kind == "squeeze" else max_stall)
                    + 1)),
                duration=(int(rng.integers(1, max_duration + 1))
                          if kind == "squeeze" else 0),
                choice=float(rng.random()) if kind == "cancel" else 0.0))
        return cls(seed=seed, faults=tuple(faults))


def _mapped_pages(pool) -> list:
    return [p for t in pool.tables.values() for p in t]


def check_invariants(eng) -> None:
    """Audit the engine + pool for consistency; raise
    :exc:`InvariantViolation` with a specific message on the first break.

    The invariants (the chaos gate's hard failures):

    1. **Partition with multiplicity**: free ∪ quarantined ∪
       ⋃mapped-with-multiplicity covers exactly ``0..n_pages-1``. A page
       in k tables is owned k times — all k accounted for by its
       refcount; free and quarantined pages are owned exactly once and
       never also mapped.
    2. **Refcount exactness**: ``pool.refcounts[p]`` equals the number
       of table slots referencing ``p``, for EVERY mapped page; no
       refcount entry survives for an unmapped page (no rc-0 retention);
       every prefix-index-registered page is live (rc >= 1).
    3. **No orphans**: every page table belongs to a request live in a
       slot (finished/cancelled sequences were freed by EVICT).
    4. **Table sizing**: each sequence's table holds exactly
       ``ceil(words / page_tokens)`` pages.
    5. **Shard placement**: every free/quarantined page sits in ITS
       shard's list, and every sequence's pages live on its home shard
       (prefix attaches re-home the sequence to the shared pages'
       shard, so this stays exact under sharing).
    6. **Slot bookkeeping**: ``slot_len`` matches the pool's committed
       word count for every occupied slot.
    """
    pool = eng.pool
    n_pages = pool.plan.n_pages

    mapped = _mapped_pages(pool)
    mult: dict = {}
    for p in mapped:
        mult[p] = mult.get(p, 0) + 1
    free = pool.free_pages
    quar = list(pool.quarantined_pages)
    exclusive = free + quar
    if len(set(exclusive)) != len(exclusive):
        dup = sorted(p for p in set(exclusive)
                     if exclusive.count(p) > 1)
        raise InvariantViolation(
            f"pages free/quarantined twice: {dup}")
    overlap = set(exclusive) & set(mult)
    if overlap:
        raise InvariantViolation(
            f"mapped pages also free/quarantined: {sorted(overlap)}")
    owned = set(exclusive) | set(mult)
    if sorted(owned) != list(range(n_pages)):
        lost = sorted(set(range(n_pages)) - owned)
        extra = sorted(owned - set(range(n_pages)))
        raise InvariantViolation(
            f"free+quarantined+mapped do not partition capacity "
            f"(lost {lost}, alien {extra})")

    # refcounts mirror table multiplicity EXACTLY: every mapped page has
    # a refcount equal to how many table slots hold it, and no refcount
    # outlives its last reference (the no-tombstone contract)
    bad = {p: (pool.refcounts.get(p), k) for p, k in mult.items()
           if pool.refcounts.get(p) != k}
    if bad:
        raise InvariantViolation(
            f"refcounts != table multiplicity (page: (rc, refs)): {bad}")
    stale = sorted(set(pool.refcounts) - set(mult))
    if stale:
        raise InvariantViolation(
            f"refcounts retained for unmapped pages: {stale}")
    dead_reg = sorted(p for p in pool.page_reg if p not in mult)
    if dead_reg:
        raise InvariantViolation(
            f"prefix index registers unmapped pages: {dead_reg}")

    live = {r.rid for r in eng.slot_req if r is not None}
    orphans = set(pool.tables) - live
    if orphans:
        raise InvariantViolation(
            f"orphaned page tables for evicted seqs {sorted(orphans)}")

    pt = pool.page_tokens
    for seq, table in pool.tables.items():
        words = pool.lengths.get(seq, 0)
        need = -(-words // pt)
        if len(table) != need:
            raise InvariantViolation(
                f"seq {seq}: {len(table)} pages mapped for {words} words "
                f"(needs {need})")
        home = pool.home.get(seq)
        wrong = [p for p in table if pool.plan.shard_of_page(p) != home]
        if wrong:
            raise InvariantViolation(
                f"seq {seq} (home shard {home}) holds foreign pages "
                f"{wrong}")

    for s, fl in enumerate(pool.free_by_shard):
        wrong = [p for p in fl if pool.plan.shard_of_page(p) != s]
        if wrong:
            raise InvariantViolation(
                f"shard {s} free list holds foreign pages {wrong}")
    for s, q in enumerate(pool.quarantine_by_shard):
        wrong = [p for p in q if pool.plan.shard_of_page(p) != s]
        if wrong:
            raise InvariantViolation(
                f"shard {s} quarantine holds foreign pages {wrong}")

    for i, r in enumerate(eng.slot_req):
        if r is None:
            continue
        words = pool.lengths.get(r.rid, 0)
        if words != eng.slot_len[i]:
            raise InvariantViolation(
                f"slot {i} (rid {r.rid}): slot_len {eng.slot_len[i]} != "
                f"pool words {words}")


class ChaosHarness:
    """Inject a :class:`FaultPlan` into a driven engine, auditing
    invariants after every action. Callable — pass it straight to
    ``drive(eng, arrivals, on_cycle=harness)``."""

    def __init__(self, plan: FaultPlan, *,
                 heartbeat_dir: Optional[str] = None,
                 worker: str = "engine",
                 straggler_multiplier: float = 4.0):
        self.plan = plan
        self._due = deque(sorted(plan.faults, key=lambda f: f.tick))
        self._release_tick: Optional[int] = None
        self._last_tick: Optional[int] = None
        self.injected: list[dict] = []     # every action, with its tick
        self.invariant_checks = 0
        self.straggler = StragglerDetector(multiplier=straggler_multiplier)
        self.straggler_events = 0
        self.heartbeat = (Heartbeat(heartbeat_dir, worker)
                          if heartbeat_dir is not None else None)

    # -- injection primitives (each audited) ------------------------------
    def _audit(self, eng) -> None:
        check_invariants(eng)
        self.invariant_checks += 1

    def _squeeze(self, eng, fault: Fault, now: int) -> None:
        if self._release_tick is not None:
            # one squeeze at a time: release the active one first
            eng.pool.release_quarantine()
            self._release_tick = None
        taken = eng.pool.quarantine(
            fault.magnitude, keep_free=eng._reserved_pages_by_shard())
        self._release_tick = now + fault.duration
        self.injected.append({"tick": now, "kind": "squeeze",
                              "pages": len(taken),
                              "release_tick": self._release_tick})

    def _cancel(self, eng, fault: Fault, now: int) -> None:
        live = sorted(r.rid for r in eng.slot_req
                      if r is not None and not r.done)
        if not live:
            self.injected.append({"tick": now, "kind": "cancel",
                                  "rid": None})
            return
        rid = live[int(fault.choice * len(live))]
        eng.cancel(rid)
        self.injected.append({"tick": now, "kind": "cancel", "rid": rid})

    def _stall(self, eng, fault: Fault, now: int) -> None:
        eng.stall_retirement(fault.magnitude)
        self.injected.append({"tick": now, "kind": "stall",
                              "cycles": fault.magnitude})

    # -- the drive() hook --------------------------------------------------
    def __call__(self, eng) -> None:
        now = eng.vclock
        if self.heartbeat is not None:
            self.heartbeat.beat(eng.cycles)
        # straggler watch over VIRTUAL cycle duration: a parked or stalled
        # stretch that fast-forwards the clock is a deterministic outlier
        if self._last_tick is not None:
            if self.straggler.record(eng.cycles, float(now
                                                      - self._last_tick)):
                self.straggler_events += 1
        self._last_tick = now
        if self._release_tick is not None and now >= self._release_tick:
            eng.pool.release_quarantine()
            self._release_tick = None
            self.injected.append({"tick": now, "kind": "release"})
            self._audit(eng)
        while self._due and self._due[0].tick <= now:
            fault = self._due.popleft()
            {"squeeze": self._squeeze, "cancel": self._cancel,
             "stall": self._stall}[fault.kind](eng, fault, now)
            # injection-tick vs plan-tick audit: drive() only calls the
            # hook on cycles that actually step, so a fault due inside an
            # idle fast-forward fires at the first REAL cycle after it —
            # the drift stamp makes that residual (and any regression in
            # the drive() ordering) visible instead of silent
            self.injected[-1].update(plan_tick=fault.tick,
                                     drift=now - fault.tick)
            self._audit(eng)

    def finalize(self, eng) -> None:
        """End of run: force any trailing in-flight work, release a still-
        active squeeze, fire faults past the traffic horizon (audited like
        any other), and audit once more."""
        eng.flush()
        while self._due:
            fault = self._due.popleft()
            {"squeeze": self._squeeze, "cancel": self._cancel,
             "stall": self._stall}[fault.kind](eng, fault, eng.vclock)
            self.injected[-1].update(plan_tick=fault.tick,
                                     drift=eng.vclock - fault.tick)
            self._audit(eng)
        if self._release_tick is not None:
            eng.pool.release_quarantine()
            self._release_tick = None
            self.injected.append({"tick": eng.vclock, "kind": "release"})
        eng.flush()
        self._audit(eng)
