"""Checkpointing: sharded-safe, manifest-verified, async, reshardable.

Layout (one directory per step):
    <root>/step_000123/
        manifest.json    — tree structure, shapes, dtypes, crc32 per leaf, step
        arrays.npz       — one entry per leaf (path-encoded keys)
    <root>/step_000123.tmp/   — staging; atomic rename on completion

Fault-tolerance properties:
  * atomic: a crashed save never leaves a half-readable step directory;
  * verified: restore checks crc32 of every leaf against the manifest;
  * reshardable: restore takes target shardings and device_puts each leaf,
    so a job restarted on a DIFFERENT mesh (elastic down/up-scale) loads the
    same checkpoint (tests/distributed/test_elastic.py);
  * async: ``save_async`` snapshots to host then writes on a worker thread,
    returning a handle — training continues during the write.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(root: str, step: int, tree: PyTree, *, extra: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous checkpoint write. Returns the step directory path."""
    flat = _flatten(tree)
    return _write(root, step, flat, extra or {}, keep_last)


def _write(root: str, step: int, flat: dict[str, np.ndarray], extra: dict,
           keep_last: int) -> str:
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "extra": extra, "leaves": {}}
    for k, v in flat.items():
        manifest["leaves"][k] = {
            "shape": list(v.shape), "dtype": str(v.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()),
        }
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(root, keep_last)
    return final


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(root)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(root, d))


class AsyncSaver:
    """Snapshot-then-write on a background thread (one in flight at a time)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, root: str, step: int, tree: PyTree, *,
             extra: Optional[dict] = None, keep_last: int = 3) -> None:
        self.wait()
        flat = _flatten(tree)                  # snapshot on caller thread

        def work():
            try:
                _write(root, step, flat, extra or {}, keep_last)
            except BaseException as e:         # surfaced on next wait()
                self._error = e
        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(root: str, template: PyTree, *, step: Optional[int] = None,
            shardings: Optional[PyTree] = None) -> tuple[PyTree, dict]:
    """Restore into the structure of ``template``; verify checksums; place
    leaves per ``shardings`` (same treedef as template) when given."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_p))
    out = []
    for (path, leaf), shd in zip(leaves_p, shard_leaves):
        key = _SEP.join(_path_str(p) for p in path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        meta = manifest["leaves"][key]
        if zlib.crc32(np.ascontiguousarray(arr).tobytes()) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {key}")
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest
