"""repro.checkpoint subpackage."""
