"""Training launcher.

CPU (reduced config, single device):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 32

TPU slice (full config; the same code path the dry-run compiles):
    python -m repro.launch.train --arch qwen2.5-3b --batch 256 --seq 4096 \
        --mesh production [--multi-pod] [--compress-grads]
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.distributed import sharding as shd
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import init_params
from repro.optim import AdamWConfig
from repro.train.loop import RunnerConfig, TrainingRunner
from repro.train.train_step import TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adamw8bit", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default="none", choices=["none", "production"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = registry.get(args.arch, reduced=args.reduced)
    n_pods = 2 if args.multi_pod else 1
    tcfg = TrainConfig(optimizer=args.optimizer, peak_lr=args.lr,
                       warmup_steps=max(args.steps // 20, 1),
                       total_steps=args.steps,
                       microbatches=args.microbatches,
                       grad_compression="int8_ef" if args.compress_grads else None,
                       n_pods=n_pods if args.compress_grads else 1,
                       adamw=AdamWConfig())
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_train_state(params, tcfg)
    step = make_train_step(cfg, tcfg)
    loader = ShardedLoader(cfg, DataConfig(seed=0), batch=args.batch,
                           seq=args.seq)

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        rules = shd.Rules.for_mesh(mesh)
        st_shapes = jax.eval_shape(lambda: state)
        st_specs = SP.train_state_pspecs(cfg, mesh, rules, st_shapes)
        bspecs = shd.batch_specs(cfg, mesh, rules, global_batch=args.batch)
        state = jax.device_put(state, SP.named_tree(mesh, st_specs))
        jstep = jax.jit(step,
                        in_shardings=(SP.named_tree(mesh, st_specs),
                                      SP.named_tree(mesh, bspecs)),
                        out_shardings=(SP.named_tree(mesh, st_specs), None),
                        donate_argnums=0)
        ctx = jax.set_mesh(mesh)
        ctx.__enter__()
    else:
        jstep = jax.jit(step, donate_argnums=0)

    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.arch_id} params={n/1e6:.1f}M optimizer={args.optimizer} "
          f"devices={jax.device_count()}")
    runner = TrainingRunner(
        jstep, state, loader.get,
        RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     heartbeat_dir=args.ckpt_dir + "/hb"))
    runner.run(args.steps)
    hist = runner.history
    print(f"ce first5={sum(h['ce'] for h in hist[:5])/5:.4f} "
          f"last5={sum(h['ce'] for h in hist[-5:])/5:.4f} "
          f"stragglers={len(runner.straggler.events)}")


if __name__ == "__main__":
    main()
