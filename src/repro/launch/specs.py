"""ShapeDtypeStruct stand-ins for every model input/state — the dry-run's
no-allocation input builders, plus the sharding trees for each step kind."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
# re-export: the stage-length bucket ladder (single source of truth in
# memory/paged_kv.py, next to the queue bucketing it mirrors). Since the
# dynamic-grid kernels took the ladder out of the decode hot path, this is
# a VALIDATION/FALLBACK surface only: launchers validate --seq-tile against
# ``MultiPortEngine.final_stage_ladder`` (which applies the engine's clamp
# and growth regeneration on top of these buckets), and the engine walks
# the ladder only under ``dynamic_grid=False``.
from repro.memory.paged_kv import seq_tile_buckets  # noqa: F401
from repro.models import init_decode_state, init_params
from repro.train.train_step import TrainConfig, init_train_state

PyTree = Any


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """Batch ShapeDtypeStructs for one shape cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind == "decode":
        if cfg.input_mode == "tokens":
            return {"inputs": sds((b, 1), jnp.int32)}
        return {"inputs": sds((b, 1, cfg.d_model), cfg.cdtype)}
    batch = {"labels": sds((b, s), jnp.int32)}
    if cfg.input_mode == "tokens":
        batch["inputs"] = sds((b, s), jnp.int32)
    else:
        batch["inputs"] = sds((b, s, cfg.d_model), cfg.cdtype)
    if cfg.pos_embed == "mrope":
        batch["positions"] = sds((b, s, 3), jnp.int32)
    return batch


def prefill_chunk_specs(cfg: ArchConfig, batch: int, chunk: int) -> dict:
    """Batch ShapeDtypeStructs for one chunked-prefill step — the serving
    engine's admission compute: ``batch`` concurrently-prefilling sequences
    each contribute one ``chunk``-token slice of their prompt plus its valid
    row count (see ``repro.models.prefill_chunk``)."""
    if cfg.input_mode != "tokens":
        raise NotImplementedError("chunked prefill serves token models")
    return {"inputs": sds((batch, chunk), jnp.int32),
            "chunk_len": sds((batch,), jnp.int32)}


def kv_pool_specs(mesh: Mesh, *, n_pages: int, page_tokens: int,
                  word_width: int, axis: str = "kv"
                  ) -> tuple[jax.ShapeDtypeStruct, NamedSharding]:
    """No-allocation stand-in for the serving engine's paged KV pool
    storage: the ``[num_words, word_pad(word_width)]`` ShapeDtypeStruct plus
    its page-aligned NamedSharding over the ``kv`` axis — the dry-run's way
    to validate a deployment's pool geometry (page counts rounded to whole
    pages per shard, no shard boundary inside a page) without touching
    device memory. Mirrors ``PagedPool.create(mesh=...)``."""
    from repro.kernels.tiling import word_pad

    plan = shd.kv_shard_plan(int(mesh.shape[axis]), n_pages=n_pages,
                             page_tokens=page_tokens)
    pspec = shd.kv_pool_spec(mesh, num_words=plan.num_words,
                             page_tokens=page_tokens, axis=axis)
    return (sds((plan.num_words, word_pad(word_width)), jnp.float32),
            NamedSharding(mesh, pspec))


def kv_split_partial_specs(cfg: ArchConfig, batch: int,
                           num_kv_splits: int) -> dict:
    """No-allocation stand-ins for the split-KV decode intermediates: the
    stage-1 partial accumulators (``[B, splits * Hp, Dp]`` f32) and LSE
    stats (``[B, splits * Hp, LANE]`` f32, col 0 = running max, col 1 =
    denominator) that stage 2 combines — per attention layer, scratch the
    dry-run can size without launching a kernel. Geometry is read off the
    SAME lint-checked table the kernel launches from
    (``kv_multiport.split_block_specs``), so a drift there shows up here."""
    from repro.kernels.kv_multiport import split_block_specs

    table = {nm: arr for nm, _, arr in split_block_specs(
        batch, 1, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_, 1,
        num_kv_splits)}
    return {"acc_partial": sds(table["acc_partial"], jnp.float32),
            "lse_partial": sds(table["lse_partial"], jnp.float32)}


def params_shapes(cfg: ArchConfig) -> PyTree:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_params(k, cfg), key)


def train_state_shapes(cfg: ArchConfig, tcfg: TrainConfig) -> PyTree:
    return jax.eval_shape(
        lambda k: init_train_state(init_params(k, cfg), tcfg),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def decode_state_shapes(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    return jax.eval_shape(lambda: init_decode_state(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def _lookup(flat: dict, path: str):
    return flat.get(path)


def opt_state_pspecs(opt_shapes: PyTree, param_specs: PyTree, mesh: Mesh,
                     rules: shd.Rules) -> PyTree:
    """Specs for optimizer state: moments mirror params; quantized blocks
    shard their block axis on fsdp; adafactor factors drop the reduced dim."""
    flat_params = {
        "/".join(shd._key_str(k) for k in path): spec
        for path, spec in jax.tree_util.tree_flatten_with_path(
            param_specs, is_leaf=lambda x: isinstance(x, P))[0]
    }

    def assign(path_tuple, leaf):
        parts = [shd._key_str(k) for k in path_tuple]
        path = "/".join(parts)
        if path == "step":
            return P()
        head, rest = parts[0], parts[1:]
        if head == "ef":                       # [n_pods, *param_shape]
            base = flat_params.get("/".join(rest))
            pod = "pod" if "pod" in mesh.axis_names else None
            dims = tuple(base) if base else (None,) * (leaf.ndim - 1)
            if pod is not None:                # pod now shards the lead axis
                def strip(a):
                    if a == pod:
                        return None
                    if isinstance(a, tuple):
                        rest_a = tuple(x for x in a if x != pod)
                        return rest_a if len(rest_a) > 1 else (
                            rest_a[0] if rest_a else None)
                    return a
                dims = tuple(strip(a) for a in dims)
            return P(pod, *dims)
        tail = rest[-1] if rest else ""
        base = flat_params.get("/".join(rest))
        if base is not None:                   # moments mirror the param spec
            return P(*base)
        if tail in ("q", "scale") and "/".join(rest[:-1]) in flat_params:
            # last-axis-blocked quantized state [*param_lead, nblocks, BLOCK]:
            # inherit the param's leading-dim sharding (layout-aligned — no
            # reshard in the optimizer), shard the block dim when divisible.
            base = flat_params["/".join(rest[:-1])]
            lead = tuple(base)[:-1]
            last_axes = tuple(base)[-1] if len(base) else None
            nb = leaf.shape[-2] if leaf.ndim >= 2 else 1
            return P(*lead, shd._fit(mesh, last_axes, nb)
                     if last_axes else None, None)
        if tail in ("vr",):                    # param spec minus last dim
            base = flat_params.get("/".join(rest[:-1]))
            return P(*base[:-1]) if base else P(*(None,) * leaf.ndim)
        if tail in ("vc",):                    # param spec minus 2nd-to-last
            base = flat_params.get("/".join(rest[:-1]))
            if base and len(base) >= 2:
                return P(*base[:-2], base[-1])
            return P(*(None,) * leaf.ndim)
        if tail == "v" and "/".join(rest[:-1]) in flat_params:
            base = flat_params["/".join(rest[:-1])]
            return P(*base)
        return P(*(None,) * leaf.ndim)

    return jax.tree_util.tree_map_with_path(assign, opt_shapes)


def train_state_pspecs(cfg: ArchConfig, mesh: Mesh, rules: shd.Rules,
                       state_shapes: PyTree) -> PyTree:
    pspecs = shd.param_pspecs(state_shapes["params"], mesh, rules, cfg=cfg)
    out = {"params": pspecs,
           "opt": opt_state_pspecs(state_shapes["opt"], pspecs, mesh, rules)}
    if "ef" in state_shapes:
        out["ef"] = opt_state_pspecs({"ef": state_shapes["ef"]}, pspecs,
                                     mesh, rules)["ef"]
    return out


def named_tree(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
