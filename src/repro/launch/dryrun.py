import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST precede every other import (jax locks the device
# count at first backend init). This module is the multi-pod dry-run driver:
# for each (architecture x shape x mesh) cell it lowers + compiles the real
# train/prefill/serve step against ShapeDtypeStruct inputs, proving the
# sharding config is coherent at 256/512 chips, and records
# memory_analysis / cost_analysis / per-collective HLO bytes as JSON for the
# roofline (EXPERIMENTS.md §Dry-run, §Roofline).
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-3b \
#       --shape train_4k --mesh single            # one cell
#   PYTHONPATH=src python -m repro.launch.dryrun --all  # every cell, resumable

import argparse  # noqa: E402
import json      # noqa: E402
import re        # noqa: E402
import time      # noqa: E402
import traceback # noqa: E402

import jax                      # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import applicable_shapes  # noqa: E402
from repro.configs import registry           # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.distributed.api import activation_specs  # noqa: E402
from repro.launch import hlo_analysis          # noqa: E402
from repro.launch import specs as SP           # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import decode_step, prefill        # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402


def _act_specs(cfg, mesh, rules, global_batch, seq_len=None):
    ba = shd.batch_axes(mesh, rules, global_batch)
    tp = shd._fit(mesh, rules.tp, cfg.vocab)
    # Residual stream sharded over tp on the SEQUENCE dim (Megatron sequence
    # parallelism): remat-saved carries shrink by the tp degree (§Perf
    # iteration 6: 405B backward temp 765 -> 78 GiB/chip) AND the per-layer
    # boundary collectives are bf16 seq gathers instead of f32 d-dim gathers
    # (§Perf iteration 8: tinyllama train collective term 6.16 -> 1.30 s).
    # Family-gated: Mamba convs/chunked scans and the MoE row-local dispatch
    # need the sequence dim intact — seq sharding regresses them (measured:
    # zamba2 train mem 17.6 -> 65.2 s, deepseek 13.4 -> 28.8 s).
    seq_tp = (shd._fit(mesh, rules.tp, seq_len)
              if seq_len and cfg.family in ("dense", "vlm", "audio") else None)
    specs = {"logits": P(ba, None, tp), "hidden": P(ba, seq_tp, None)}
    if cfg.moe is not None:
        # NOTE: constraining the staging buffer's expert dim onto the model
        # axis forces the dispatch scatter itself to be partitioned, which
        # XLA lowers as dense masking + giant all-reduces (§Perf iteration 5,
        # refuted variant). Leave the buffer unconstrained: the expert-sharded
        # weights of the batched FFN induce the reshard as a local slice.
        specs["moe_buf"] = P(ba, None, None, None)
    return specs


def _with_hints(fn, specs):
    def wrapped(*a, **k):
        with activation_specs(specs):
            return fn(*a, **k)
    return wrapped

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|"
                      r"u32|u16|u8|pred|c64)\[([0-9,]*)\]")


def _bytes_of_types(sig: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(sig):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device RESULT bytes of every collective op in the HLO.

    Ring-algorithm wire multipliers are applied downstream (§Roofline):
    all-reduce 2x, all-gather/reduce-scatter/all-to-all 1x, permute 1x.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        for cname in _COLLECTIVES:
            if rhs.startswith(cname + "(") or re.match(
                    rf"\S+ {cname}\(", rhs) or rhs.split("(")[0].endswith(cname):
                sig = rhs.split("(")[0]       # result type(s) precede op name
                out[cname] += _bytes_of_types(sig)
                counts[cname] += 1
                break
    return {"bytes": out, "counts": counts}


def tokens_of(cell) -> int:
    if cell.kind == "decode":
        return cell.global_batch
    return cell.global_batch * cell.seq_len


def build_step(cfg, cell, mesh, rules, *, optimizer=None,
               grad_compression=None, microbatches=1):
    """Returns (jitted_fn, example_args (ShapeDtypeStructs))."""
    assert cell.kind == "train", cell.kind
    opt = optimizer or ("adamw8bit" if cfg.arch_id.startswith("llama3-405b")
                        else "adamw")
    n_pods = mesh.shape.get("pod", 1)
    tcfg = TrainConfig(optimizer=opt, microbatches=microbatches,
                       grad_compression=grad_compression,
                       n_pods=n_pods if grad_compression else 1)
    step = _with_hints(make_train_step(cfg, tcfg),
                       _act_specs(cfg, mesh, rules, cell.global_batch,
                                  seq_len=cell.seq_len))
    state_shapes = SP.train_state_shapes(cfg, tcfg)
    state_specs = SP.train_state_pspecs(cfg, mesh, rules, state_shapes)
    batch = SP.input_specs(cfg, cell)
    bspecs = shd.batch_specs(cfg, mesh, rules, global_batch=cell.global_batch)
    jf = jax.jit(step,
                 in_shardings=(SP.named_tree(mesh, state_specs),
                               SP.named_tree(mesh, bspecs)),
                 out_shardings=(SP.named_tree(mesh, state_specs), None),
                 donate_argnums=0)
    return jf, (state_shapes, batch)


def build_cell_fn(cfg, cell, mesh, rules, *, optimizer=None,
                  grad_compression=None, microbatches=1, remat=None):
    """Unified builder: returns (jitted fn, args-as-ShapeDtypeStructs)."""
    if remat is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=remat)
    if cell.kind == "train":
        return build_step(cfg, cell, mesh, rules, optimizer=optimizer,
                          grad_compression=grad_compression,
                          microbatches=microbatches)

    if cell.kind == "decode":
        # Weight-stationary serving rules (§Perf iteration 4): weight columns
        # shard over the COMBINED (data x model) axes so no weight is ever
        # gathered — per-layer activation psums are MB-scale while FSDP-style
        # weight gathers would be 100s of MB per matmul per token. The KV
        # cache keeps batch on "pod" (if any) and seq/heads on data x model.
        rules = shd.Rules(
            tp=("data", "model"), fsdp=(),
            dp=("pod",) if "pod" in mesh.axis_names else ())
    params = SP.params_shapes(cfg)
    pspecs = shd.param_pspecs(params, mesh, rules, cfg=cfg)
    state_shapes = SP.decode_state_shapes(cfg, cell.global_batch, cell.seq_len)
    state_specs = shd.decode_state_pspecs(cfg, mesh, rules, state_shapes,
                                          batch=cell.global_batch)
    batch = SP.input_specs(cfg, cell)
    ba = shd.batch_axes(mesh, rules, cell.global_batch)
    acts = _act_specs(cfg, mesh, rules, cell.global_batch,
                      seq_len=cell.seq_len if cell.kind == "prefill" else None)
    if cell.kind == "prefill":
        fn = _with_hints(lambda p, s, b: prefill(p, cfg, s, b), acts)
        bspecs = shd.batch_specs(cfg, mesh, rules,
                                 global_batch=cell.global_batch)
        bspecs.pop("labels")
        batch = {k: v for k, v in batch.items() if k != "labels"}
    else:
        acts = {"logits": P(ba, None, shd._fit(mesh, rules.tp, cfg.vocab)),
                "hidden": P(ba, None, None)}
        fn = _with_hints(lambda p, s, b: decode_step(p, cfg, s, b), acts)
        if cfg.input_mode == "tokens":
            bspecs = {"inputs": P(ba, None)}
        else:
            bspecs = {"inputs": P(ba, None, None)}
    jf = jax.jit(fn,
                 in_shardings=(SP.named_tree(mesh, pspecs),
                               SP.named_tree(mesh, state_specs),
                               SP.named_tree(mesh, bspecs)),
                 donate_argnums=1)
    return jf, (params, state_shapes, batch)


def run_cell(arch_id: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, force: bool = False, **build_kw) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch_id}__{shape_name}__{mesh_kind}"
    if build_kw:
        tag += "__" + "_".join(f"{k}-{v}" for k, v in sorted(build_kw.items()))
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = registry.get(arch_id)
    cell = next(c for c in applicable_shapes(cfg) if c.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rules = shd.Rules.for_mesh(
        mesh, fsdp_over_pod=arch_id.startswith("llama3-405b"))

    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
           "kind": cell.kind, "chips": mesh.size,
           "tokens_per_step": tokens_of(cell), "status": "error"}
    t0 = time.time()
    try:
        jf, args = build_cell_fn(cfg, cell, mesh, rules, **build_kw)
        with jax.set_mesh(mesh):
            lowered = jf.lower(*args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            }
            ca = compiled.cost_analysis() or {}
            rec["cost"] = {"flops": ca.get("flops", 0.0),
                           "bytes_accessed": ca.get("bytes accessed", 0.0)}
            hlo_text = compiled.as_text()
            rec["hlo"] = hlo_analysis.analyze(hlo_text)
            rec["status"] = "ok"
    except Exception as e:  # recorded, not raised — the sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = time.time() - t0
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    archs = registry.ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" or args.all else [args.mesh]
    n_ok = n_err = 0
    for arch in archs:
        cfg = registry.get(arch)
        cells = applicable_shapes(cfg)
        names = [c.name for c in cells]
        shapes = names if (args.all or args.shape is None) else [args.shape]
        for shape in shapes:
            if shape not in names:
                print(f"[skip] {arch} x {shape} (inapplicable)")
                continue
            for mk in meshes:
                rec = run_cell(arch, shape, mk, args.out, force=args.force)
                ok = rec["status"] == "ok"
                n_ok += ok
                n_err += (not ok)
                msg = (f"mem={rec['memory']['argument_bytes']/2**30:.2f}GiB "
                       f"temp={rec['memory']['temp_bytes']/2**30:.2f}GiB "
                       f"flops={rec['cost']['flops']:.3g}" if ok
                       else rec.get("error", "?"))
                print(f"[{'ok' if ok else 'ERR'}] {arch} x {shape} x {mk}: {msg}",
                      flush=True)
    print(f"done: {n_ok} ok, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
