"""Post-SPMD HLO analysis with while-loop expansion.

``compiled.cost_analysis()`` counts a while body ONCE, so a scan-over-layers
transformer reports ~1/n_layers of its true FLOPs. This module parses the
optimized HLO text, builds the computation call graph (while / call /
conditional / fusion), reads loop trip counts (XLA's ``known_trip_count``
backend config, falling back to the condition computation's compare bound),
and accumulates per-device:

  * dot_flops         — 2 * prod(result dims) * prod(contracting dims) per
                        dot, loop-expanded (the MXU roofline numerator);
  * traffic_bytes     — HBM traffic at fusion granularity, loop-expanded (the
                        memory-roofline numerator). Refined model:
                          - (dynamic-)slice / gather: RESULT bytes only (a
                            slice reads its window, not the whole operand);
                          - dynamic-update-slice / scatter: 2x UPDATE bytes
                            (XLA performs them in place under aliasing — the
                            slice region is read-modified-written);
                          - convert: excluded, tallied in ``convert_bytes``
                            (XLA:CPU lowers bf16 dots via f32 converts that
                            do not exist on TPU's MXU);
                          - everything else: operand+result bytes.
                        ``traffic_bytes_naive`` keeps the crude
                        operand+result-for-everything number for reference;
  * collective_bytes  — result bytes per collective type, loop-expanded
                        (ring multipliers applied downstream: all-reduce 2x,
                        gather/scatter/all-to-all/permute 1x).

All numbers are per-device: the HLO is the per-device SPMD program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(
    r"(f64|f32|bf16|f16|f8e4m3|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
    r"c64|c128|s4|u4)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_CALLEE_RE = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation)="
    r"%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n["\s:]+\"?(\d+)')
_PARAM_RE = re.compile(r"([\w\.\-]+)\s*:\s*((?:\([^()]*(?:\([^()]*\)[^()]*)*\))|[^,()]+)")
_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _bytes_of_types(sig: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _TYPE_RE.findall(sig))


@dataclasses.dataclass
class Totals:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0        # refined model (see analyze docstring)
    traffic_bytes_naive: float = 0.0  # operand+result for every op
    convert_bytes: float = 0.0        # dtype converts (CPU-lowering artifact)
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        self.traffic_bytes_naive += other.traffic_bytes_naive * mult
        self.convert_bytes += other.convert_bytes * mult
        for k in _COLLECTIVES:
            self.collective_bytes[k] += other.collective_bytes[k] * mult
            self.collective_counts[k] += other.collective_counts[k] * mult

    def as_dict(self) -> dict:
        return {"dot_flops": self.dot_flops,
                "traffic_bytes": self.traffic_bytes,
                "traffic_bytes_naive": self.traffic_bytes_naive,
                "convert_bytes": self.convert_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "collective_counts": dict(self.collective_counts)}


@dataclasses.dataclass
class Computation:
    name: str
    header: str
    lines: list


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            ls = line.strip()
            if (not line.startswith((" ", "\t"))
                    and ls.endswith("{") and "->" in ls):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", ls)
                if m:
                    cur = Computation(m.group(1), ls, [])
                    comps[cur.name] = cur
        else:
            if line.strip() == "}":
                cur = None
            else:
                cur.lines.append(line.rstrip())
    return comps


def _types_map(comp: Computation) -> dict[str, str]:
    """%name -> type signature, from the header params and op definitions."""
    types: dict[str, str] = {}
    hdr = comp.header
    inner = hdr[hdr.index("("): hdr.rindex("->")] if "->" in hdr else ""
    for name, tp in _PARAM_RE.findall(inner):
        types[name] = tp
    for ln in comp.lines:
        m = _OP_RE.match(ln)
        if m:
            rhs = m.group(2)
            type_sig, _ = _split_type_op(rhs)
            types[m.group(1)] = type_sig
    return types


def _split_type_op(rhs: str) -> tuple[str, str]:
    """'(f32[..], s32[]) while(...)' -> ('(f32[..], s32[])', 'while')."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += (ch == "(") - (ch == ")")
            if depth == 0:
                return rhs[: i + 1], rhs[i + 1:].strip().split("(")[0].strip()
        return rhs, ""
    parts = rhs.split(None, 1)
    if len(parts) < 2:
        return rhs, ""
    return parts[0], parts[1].strip().split("(")[0].strip()


def _operand_names(rhs: str, opname: str) -> list[str]:
    args = rhs.split(opname + "(", 1)
    if len(args) < 2:
        return []
    depth, out, cur = 1, [], []
    for ch in args[1]:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur.append(ch)
    return _NAME_RE.findall("".join(cur))


def _dot_flops(rhs: str, types: dict[str, str]) -> float:
    m = _TYPE_RE.search(rhs)                       # result type
    if not m:
        return 0.0
    res_elems = _shape_elems(m.group(2))
    ops = _operand_names(rhs, "dot")
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    contract = 1
    if ops and cd and ops[0] in types:
        lhs_types = _TYPE_RE.findall(types[ops[0]])
        if lhs_types:
            lhs_dims = lhs_types[0][1].split(",") if lhs_types[0][1] else []
            for idx in (cd.group(1).split(",") if cd.group(1) else []):
                if int(idx) < len(lhs_dims):
                    contract *= int(lhs_dims[int(idx)])
    return 2.0 * res_elems * contract


def _trip_count(rhs: str, cond: Optional[Computation]) -> float:
    m = _TRIP_RE.search(rhs)
    if m:
        return float(m.group(1))
    if cond is None:
        return 1.0
    consts = {}
    for ln in cond.lines:
        mm = _CONST_RE.search(ln)
        if mm:
            consts[mm.group(1)] = int(mm.group(2))
    for ln in cond.lines:
        if "compare(" in ln and "direction=" in ln:
            for name, val in consts.items():
                if re.search(rf"%{re.escape(name)}\b",
                             ln.split("compare", 1)[1]):
                    return float(val)
    if len(consts) == 1:
        return float(next(iter(consts.values())))
    return 1.0


_SKIP_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "after-all", "partition-id", "replica-id", ""}

_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _fusion_dot_flops(comp: Optional[Computation]) -> float:
    if comp is None:
        return 0.0
    types = _types_map(comp)
    total = 0.0
    for ln in comp.lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        rhs = m.group(2)
        _, op = _split_type_op(rhs)
        if op == "dot":
            total += _dot_flops(rhs, types)
    return total


def _fusion_input_bytes(comp: Optional[Computation],
                        operand_types: list[str]) -> float:
    """Slice-aware input bytes of a fusion:
      * a parameter consumed ONLY by slice/gather ops contributes its
        slices' result bytes (XLA reads just the accessed window);
      * a parameter consumed ONLY as the TARGET (operand 0) of
        dynamic-update-slice ops contributes the update bytes (in-place
        read-modify-write of the touched region under buffer aliasing);
      * everything else contributes its full size."""
    if comp is None:
        return float(sum(_bytes_of_types(tp) for tp in operand_types))
    hdr = comp.header
    inner = hdr[hdr.index("("): hdr.rindex("->")] if "->" in hdr else ""
    params = [name for name, _ in _PARAM_RE.findall(inner)]
    types = _types_map(comp)
    # Dtype/layout plumbing (convert/bitcast/copy/reshape) inside a fusion is
    # register-resident: results of such ops alias their source param for the
    # consumption analysis (XLA:CPU converts bf16 operands to f32 in fused
    # regions; TPU reads the original bytes once).
    alias_of: dict[str, str] = {}
    consumers: dict[str, list[tuple]] = {p: [] for p in params}
    for ln in comp.lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        rhs = m.group(2)
        ts, op = _split_type_op(rhs)
        if op == "parameter":
            continue
        names = _operand_names(rhs, op)
        if op in ("convert", "bitcast", "copy", "reshape") and len(names) == 1:
            src = alias_of.get(names[0], names[0])
            if src in consumers:
                alias_of[m.group(1)] = src
            continue
        upd = 0
        if op == "dynamic-update-slice" and len(names) > 1:
            upd_name = alias_of.get(names[1], names[1])
            upd = _bytes_of_types(types.get(names[1],
                                            types.get(upd_name, "")))
        for idx, n in enumerate(names):
            root = alias_of.get(n, n)
            if root in consumers:
                consumers[root].append((op, _bytes_of_types(ts), idx, upd))
    total = 0.0
    for i, p in enumerate(params):
        full = _bytes_of_types(operand_types[i]) if i < len(operand_types) else 0
        uses = consumers.get(p, [])
        contrib, whole = 0.0, not uses
        for op, rb, idx, upd in uses:
            if op in _SLICE_OPS and idx == 0:
                contrib += rb                   # reads its window only
            elif op == "dynamic-update-slice" and idx == 0:
                contrib += 2 * upd              # in-place RMW of the window
            elif op == "dynamic-update-slice" and idx == 1:
                pass                            # the update value is internal
            elif op == "dynamic-slice" and idx > 0:
                pass                            # index operand
            else:
                whole = True                    # consumed wholesale
        total += full if whole else min(contrib, full)
    return total


def _fusion_result_bytes(comp: Optional[Computation], result_sig: str) -> float:
    """Result bytes of a fusion, treating dynamic-update-slice roots as
    in-place (their write traffic is carried by _fusion_input_bytes)."""
    rb = _bytes_of_types(result_sig)
    if comp is None:
        return rb
    dus_out = 0.0
    for ln in comp.lines:
        m = _OP_RE.match(ln)
        if not m:
            continue
        rhs = m.group(2)
        ts, op = _split_type_op(rhs)
        if op == "dynamic-update-slice":
            dus_out += _bytes_of_types(ts)
    return max(rb - dus_out, 0.0)


def analyze(hlo: str, entry: str | None = None) -> dict:
    comps = parse_computations(hlo)
    if not comps:
        return Totals().as_dict()

    called = set()
    for c in comps.values():
        for ln in c.lines:
            called.update(_CALLEE_RE.findall(ln))
            b = _BRANCHES_RE.search(ln)
            if b:
                called.update(x.strip().lstrip("%")
                              for x in b.group(1).split(","))
    if entry is None:
        entry = next((n for n in comps if n not in called and "main" in n),
                     next((n for n in comps if n not in called), None))

    memo: dict[str, Totals] = {}

    def total_of(name: str) -> Totals:
        if name in memo:
            return memo[name]
        memo[name] = Totals()                      # cycle guard
        comp = comps.get(name)
        t = Totals()
        if comp is None:
            return t
        types = _types_map(comp)

        def operand_bytes(rhs, opname):
            return sum(_bytes_of_types(types.get(n, ""))
                       for n in _operand_names(rhs, opname))

        def nth_operand_bytes(rhs, opname, idx):
            names = _operand_names(rhs, opname)
            if idx < len(names):
                return _bytes_of_types(types.get(names[idx], ""))
            return 0

        for ln in comp.lines:
            m = _OP_RE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            type_sig, opname = _split_type_op(rhs)
            if opname == "while":
                callees = dict(re.findall(r"(condition|body)=%?([\w\.\-]+)", rhs))
                trips = _trip_count(rhs, comps.get(callees.get("condition", "")))
                if "body" in callees:
                    t.add(total_of(callees["body"]), trips)
                t.traffic_bytes += _bytes_of_types(type_sig)
                t.traffic_bytes_naive += _bytes_of_types(type_sig)
                continue
            if opname == "fusion":
                # Fusion internals are register/VMEM-resident: traffic is the
                # result + slice-aware input bytes; only internal dots add
                # FLOPs. (Counting internal elementwise ops would overstate
                # HBM traffic by the fusion's depth.)
                callees = _CALLEE_RE.findall(rhs)
                for c in callees:
                    t.dot_flops += _fusion_dot_flops(comps.get(c))
                fcomp = comps.get(callees[0]) if callees else None
                rb = _fusion_result_bytes(fcomp, type_sig)
                ib = _fusion_input_bytes(
                    fcomp,
                    [types.get(n, "") for n in _operand_names(rhs, opname)])
                t.traffic_bytes += rb + ib
                t.traffic_bytes_naive += rb + operand_bytes(rhs, opname)
                continue
            if opname in ("call", "custom-call", "async-start"):
                for c in _CALLEE_RE.findall(rhs):
                    t.add(total_of(c), 1.0)
                fb = _bytes_of_types(type_sig) + operand_bytes(rhs, opname)
                t.traffic_bytes += fb
                t.traffic_bytes_naive += fb
                continue
            if opname == "conditional":
                b = _BRANCHES_RE.search(rhs)
                branches = ([x.strip().lstrip("%") for x in b.group(1).split(",")]
                            if b else _CALLEE_RE.findall(rhs))
                if branches:
                    sub = [total_of(c) for c in branches]
                    best = max(sub, key=lambda s: s.dot_flops + s.traffic_bytes)
                    t.add(best, 1.0)
                continue
            if opname == "dot":
                t.dot_flops += _dot_flops(rhs, types)
            hit_collective = False
            for cname in _COLLECTIVES:
                if opname == cname or opname.startswith(cname + "-"):
                    t.collective_bytes[cname] += _bytes_of_types(type_sig)
                    t.collective_counts[cname] += 1
                    hit_collective = True
                    break
            if opname in _SKIP_OPS:
                continue
            result_b = _bytes_of_types(type_sig)
            opers_b = operand_bytes(rhs, opname)
            t.traffic_bytes_naive += result_b + opers_b
            if opname == "convert":
                t.convert_bytes += result_b + opers_b
            elif opname in ("dynamic-slice", "slice", "gather"):
                t.traffic_bytes += result_b
            elif opname == "dynamic-update-slice":
                t.traffic_bytes += 2 * nth_operand_bytes(rhs, opname, 1)
            elif opname == "scatter":
                # operands: target, indices, updates
                t.traffic_bytes += 2 * nth_operand_bytes(rhs, opname, 2)
            else:
                t.traffic_bytes += result_b + opers_b
            del hit_collective
        memo[name] = t
        return t

    return (total_of(entry) if entry else Totals()).as_dict()
