"""repro.launch subpackage."""
