"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 forced host devices)."""
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
