"""Production mesh builders + JAX-version compat shims.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Compat: newer JAX exposes ``jax.sharding.AxisType`` (and ``jax.set_mesh``)
for the sharding-in-types world; the pinned 0.4.x line has neither. All mesh
construction in this repo goes through :func:`make_mesh` / :func:`use_mesh`
below, which feature-detect and degrade gracefully:

  * ``make_mesh(shape, axes)`` — ``jax.make_mesh`` with ``axis_types`` only
    when the running JAX supports it.
  * ``use_mesh(mesh)``        — ``jax.set_mesh`` when present, else the
    classic ``Mesh`` context manager (a no-op wrapper for jit calls that
    pass explicit ``NamedSharding``s, which is how this repo shards).
"""
from __future__ import annotations

import contextlib

import jax


def make_mesh(shape, axes, *, devices=None):
    """Version-portable ``jax.make_mesh``.

    Uses ``AxisType.Auto`` axis types when the running JAX exposes them
    (>= 0.5-era sharding-in-types API); otherwise builds a plain ``Mesh``.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(axis_type.Auto,) * len(axes),
                                 **kwargs)
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes, **kwargs)


def use_mesh(mesh):
    """Version-portable ``with jax.set_mesh(mesh)``.

    Explicit-sharding jits (``in_shardings=NamedSharding(...)``) don't need an
    ambient mesh, so on older JAX the classic ``Mesh`` context manager (or
    nothing at all) is sufficient.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def make_kv_mesh(n_shards: int, *, axis: str = "kv", devices=None):
    """1-D ``kv`` mesh over the first ``n_shards`` devices — the serving
    engine's data-parallel-KV surface (paged pool sharded page-aligned on
    its word axis; staged kernel batches sharded by home device). Built as
    a plain ``Mesh`` (no axis types): the pool and the fused kernels enter
    it through explicit ``shard_map``, never an ambient-mesh jit.

    On CPU CI, force host devices BEFORE the first jax import:
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if n_shards > len(devices):
        raise ValueError(
            f"kv mesh needs {n_shards} devices but only {len(devices)} are "
            f"visible — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards} before jax "
            f"initializes")
    return Mesh(np.array(devices[:n_shards]), (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 4), axes=("data", "model")):
    """Small mesh for multi-device CPU tests (8 forced host devices)."""
    return make_mesh(shape, axes)
