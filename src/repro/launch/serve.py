"""Serving launcher: the multi-port engine over a token-model architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-new 8 [--single-port]

Multi-device (data-parallel KV — the paged pool sharded page-aligned over a
``kv`` mesh axis, kernels shard_map'd by home device):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --kv-shards 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_kv_mesh
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="initial slot-table size")
    ap.add_argument("--max-slots", type=int, default=64,
                    help="slot-table growth bound (continuous batching)")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="prefill chunk size (tokens per admission per cycle)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seq-tile", type=int, default=None,
                    help="KV-cache tile size for length-bounded traversals "
                         "(default: min(64, max_len)); validated against "
                         "--max-len's bucket ladder at startup")
    ap.add_argument("--no-length-bound", action="store_true",
                    help="disable live-length bounding (stage full max_len "
                         "caches every step — the unbounded baseline)")
    ap.add_argument("--no-dynamic-grid", action="store_true",
                    help="fall back to the bucketed stage-length ladder "
                         "(one jit retrace per power-of-two tile bucket) "
                         "instead of the dynamic-grid kernels whose single "
                         "trace serves every cache length")
    ap.add_argument("--kv-shards", type=int, default=1,
                    help="shard the paged KV pool page-aligned across this "
                         "many devices (data-parallel KV: device-aware page "
                         "allocation + shard_map'd pool/kernels); on CPU, "
                         "force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--single-port", action="store_true")
    ap.add_argument("--kernel-mode", default="pallas",
                    choices=["pallas", "reference"])
    ap.add_argument("--schedule-mode", default="ooo",
                    choices=["static", "ooo"],
                    help="macro-cycle port scheduler: 'ooo' co-schedules "
                         "non-hazarding phases (disjoint pages) into shared "
                         "pool traversals; 'static' keeps the rigid "
                         "one-traversal-per-phase walk (the oracle)")
    ap.add_argument("--max-ports", type=int, default=4,
                    help="per-traversal port budget (1-4, the paper's B1B0 "
                         "knob); 1 degrades the attention compute to the "
                         "two-pass W-then-R oracle")
    ap.add_argument("--no-interpret", action="store_true",
                    help="lower Pallas kernels through Mosaic (TPU)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = registry.get(args.arch, reduced=args.reduced)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} has a stub frontend; serve a token arch")
    seq_tile = (min(64, args.max_len) if args.seq_tile is None
                else args.seq_tile)
    # validate against the engine's OWN ladder construction (clamp
    # included) — the ladder it keeps through max_slots growth — not a
    # hand-rolled snapshot that silently diverged from the engine's actual
    # staging geometry (the old validation skipped the engine's
    # seq_tile=min(seq_tile, max_len) clamp)
    try:
        buckets = MultiPortEngine.final_stage_ladder(args.max_len, seq_tile)
    except ValueError as e:
        raise SystemExit(f"--seq-tile: {e}")
    if seq_tile > args.max_len:
        print(f"--seq-tile {seq_tile} exceeds --max-len {args.max_len}; "
              f"clamping to {args.max_len} (the engine's own clamp)")
        seq_tile = args.max_len
    grid = "bucketed" if args.no_dynamic_grid else "dynamic-grid"
    print(f"length-bounded staging buckets (seq_tile={seq_tile}, "
          f"S_max={args.max_len}, {grid}): {list(buckets)}")
    mesh = None
    if args.kv_shards > 1:
        try:
            mesh = make_kv_mesh(args.kv_shards)
        except ValueError as e:
            raise SystemExit(f"--kv-shards: {e}")
        print(f"data-parallel KV: pool sharded page-aligned over "
              f"{args.kv_shards} devices ({[str(d) for d in mesh.devices.flat]})")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = MultiPortEngine(params, cfg, slots=args.slots,
                          max_slots=max(args.max_slots, args.slots),
                          max_len=args.max_len,
                          chunk_tokens=args.chunk_tokens,
                          kernel_mode=args.kernel_mode,
                          single_port=args.single_port,
                          seq_tile=seq_tile,
                          length_bound=not args.no_length_bound,
                          dynamic_grid=not args.no_dynamic_grid,
                          interpret=not args.no_interpret,
                          mesh=mesh,
                          schedule_mode=args.schedule_mode,
                          max_ports=args.max_ports)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        eng.submit(list(rng.integers(0, cfg.vocab, int(rng.integers(3, 10)))),
                   max_new=args.max_new)
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    mode = "single-port" if args.single_port else "multi-port"
    print(f"[{mode}] {len(done)} requests, {toks} tokens, "
          f"{eng.cycles} macro-cycles, {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    print(f"pool traversals: {eng.pool_traversals} "
          f"({eng.pool_traversals / max(toks, 1):.2f}/token); "
          f"slots grown to {eng.n_slots}/{eng.max_slots}; prefill "
          f"{eng.prefill_traversals / max(eng.prefill_tokens, 1):.3f} "
          f"traversals/prompt-token over {eng.prefill_steps} chunk cycles")
    print(f"jit traces: decode {eng.decode_traces}, prefill-chunk "
          f"{eng.prefill_traces} (dynamic grid: {eng.dynamic_grid})")
    mixes = ", ".join(f"{k}: {v}" for k, v in
                      sorted(eng.pool.mix_counts.items()))
    print(f"schedule [{eng.schedule_mode}, max_ports={eng.max_ports}]: "
          f"{eng.coscheduled_cycles}/{eng.multi_phase_cycles} multi-phase "
          f"cycles co-scheduled (frac {eng.coschedule_frac:.2f}); "
          f"traversal mixes {{{mixes}}}")
    print(f"tile reads (seq_tile={eng.seq_tile}): decode "
          f"{eng.steady_decode_tile_reads} steady "
          f"(bound {eng.steady_decode_tile_bound}), prefill "
          f"{eng.prefill_tile_reads / max(eng.prefill_chunks, 1):.2f}/chunk "
          f"vs {-(-args.max_len // eng.seq_tile)} dense; pool "
          f"r/w {eng.pool.tile_reads}/{eng.pool.tile_writes}")
    if eng.n_kv_shards > 1:
        print(f"kv shards: {eng.n_kv_shards} "
              f"(pages/shard {eng.pool.plan.pages_per_shard}); steady decode "
              f"tile reads by device {eng.steady_decode_tile_reads_by_dev} "
              f"(balance {eng.kv_tile_balance:.2f}x ideal); pool tiles r/w "
              f"by shard {eng.pool.tile_reads_by_shard}/"
              f"{eng.pool.tile_writes_by_shard}")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
