"""Serving launcher: the multi-port engine over a token-model architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --requests 8 --max-new 8 [--single-port]

Multi-device (data-parallel KV — the paged pool sharded page-aligned over a
``kv`` mesh axis, kernels shard_map'd by home device):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --kv-shards 4

Open-loop (requests ARRIVE on a virtual-clock schedule instead of all being
submitted up front — seeded Poisson via ``--arrival-rate``, or a JSONL
trace via ``--trace``; ``--slo`` prints p99-TTFT SLO attainment in
virtual-clock ticks, 1 tick = 1 pool traversal):

    PYTHONPATH=src python -m repro.launch.serve --arrival-rate 0.25 \
        --requests 16 --slo 120
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.launch.mesh import make_kv_mesh
from repro.models import init_params
from repro.serve import traffic
from repro.serve.engine import MultiPortEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=registry.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="initial slot-table size")
    ap.add_argument("--max-slots", type=int, default=64,
                    help="slot-table growth bound (continuous batching)")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="prefill chunk size (tokens per admission per cycle)")
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seq-tile", type=int, default=None,
                    help="KV-cache tile size for length-bounded traversals "
                         "(default: min(64, max_len)); validated against "
                         "--max-len's bucket ladder at startup")
    ap.add_argument("--no-length-bound", action="store_true",
                    help="disable live-length bounding (stage full max_len "
                         "caches every step — the unbounded baseline)")
    ap.add_argument("--no-dynamic-grid", action="store_true",
                    help="fall back to the bucketed stage-length ladder "
                         "(one jit retrace per power-of-two tile bucket) "
                         "instead of the dynamic-grid kernels whose single "
                         "trace serves every cache length")
    ap.add_argument("--num-kv-splits", type=int, default=1,
                    help="split-KV flash-decode: run each sequence's decode "
                         "traversal as this many grid-parallel partial-"
                         "attention chains plus an LSE-combine step, so a "
                         "long context no longer bounds the step latency "
                         "(1 = today's serial traversal, the bit-exact "
                         "oracle; pallas decode only)")
    ap.add_argument("--kv-shards", type=int, default=1,
                    help="shard the paged KV pool page-aligned across this "
                         "many devices (data-parallel KV: device-aware page "
                         "allocation + shard_map'd pool/kernels); on CPU, "
                         "force host devices with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--single-port", action="store_true")
    ap.add_argument("--kernel-mode", default="pallas",
                    choices=["pallas", "reference"])
    ap.add_argument("--schedule-mode", default="ooo",
                    choices=["static", "ooo"],
                    help="macro-cycle port scheduler: 'ooo' co-schedules "
                         "non-hazarding phases (disjoint pages) into shared "
                         "pool traversals; 'static' keeps the rigid "
                         "one-traversal-per-phase walk (the oracle)")
    ap.add_argument("--max-ports", type=int, default=4,
                    help="per-traversal port budget (1-4, the paper's B1B0 "
                         "knob); 1 degrades the attention compute to the "
                         "two-pass W-then-R oracle")
    ap.add_argument("--no-interpret", action="store_true",
                    help="lower Pallas kernels through Mosaic (TPU)")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="open-loop mode: seeded Poisson arrivals at this "
                         "many requests per virtual tick (1 tick = 1 pool "
                         "traversal), heavy-tailed lengths over the "
                         "registry scenario spread; requests are admitted "
                         "FIFO as slots free up instead of being submitted "
                         "all at once")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="open-loop mode: replay a JSONL arrival trace "
                         "(see repro.serve.traffic.write_trace) instead of "
                         "the Poisson generator")
    ap.add_argument("--slo", type=float, default=None, metavar="TICKS",
                    help="p99-TTFT SLO in virtual-clock ticks: print "
                         "attainment (fraction of requests whose TTFT met "
                         "it) with the open-loop latency summary")
    ap.add_argument("--deadline", type=float, default=None, metavar="TICKS",
                    help="admission TTL in virtual ticks: a request still "
                         "queued past arrival+TTL is SHED (head-only, "
                         "counted) instead of admitted — overload-safe "
                         "serving's deadline stage")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="bound the admission queue: submissions beyond "
                         "this depth are rejected immediately "
                         "(shed_reason='queue_full') rather than queued "
                         "into unbounded delay")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="open-loop only: inject a seeded FaultPlan "
                         "(capacity squeezes, mid-stream cancels, delayed "
                         "retirement) through serve.chaos.ChaosHarness "
                         "with engine/pool invariant audits after every "
                         "fault")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable refcounted prefix sharing: every "
                         "admission prefills its full prompt even when an "
                         "identical prefix is already resident (the "
                         "launcher serves with the prefix cache ON by "
                         "default; tokens are bit-identical either way)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.trace and args.arrival_rate is not None:
        raise SystemExit("--trace and --arrival-rate are exclusive")

    cfg = registry.get(args.arch, reduced=args.reduced)
    if cfg.input_mode != "tokens":
        raise SystemExit(f"{args.arch} has a stub frontend; serve a token arch")
    seq_tile = (min(64, args.max_len) if args.seq_tile is None
                else args.seq_tile)
    # validate against the engine's OWN ladder construction (clamp
    # included) — the ladder it keeps through max_slots growth — not a
    # hand-rolled snapshot that silently diverged from the engine's actual
    # staging geometry (the old validation skipped the engine's
    # seq_tile=min(seq_tile, max_len) clamp)
    try:
        buckets = MultiPortEngine.final_stage_ladder(args.max_len, seq_tile)
    except ValueError as e:
        raise SystemExit(f"--seq-tile: {e}")
    if seq_tile > args.max_len:
        print(f"--seq-tile {seq_tile} exceeds --max-len {args.max_len}; "
              f"clamping to {args.max_len} (the engine's own clamp)")
        seq_tile = args.max_len
    if args.num_kv_splits < 1:
        raise SystemExit(f"--num-kv-splits must be >= 1, "
                         f"got {args.num_kv_splits}")
    grid = "bucketed" if args.no_dynamic_grid else "dynamic-grid"
    print(f"length-bounded staging buckets (seq_tile={seq_tile}, "
          f"S_max={args.max_len}, {grid}): {list(buckets)}")
    if args.num_kv_splits > 1:
        print(f"split-KV flash-decode: {args.num_kv_splits} partial chains "
              f"per sequence + LSE combine (pallas decode path)")
    mesh = None
    if args.kv_shards > 1:
        try:
            mesh = make_kv_mesh(args.kv_shards)
        except ValueError as e:
            raise SystemExit(f"--kv-shards: {e}")
        print(f"data-parallel KV: pool sharded page-aligned over "
              f"{args.kv_shards} devices ({[str(d) for d in mesh.devices.flat]})")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = MultiPortEngine(params, cfg, slots=args.slots,
                          max_slots=max(args.max_slots, args.slots),
                          max_len=args.max_len,
                          chunk_tokens=args.chunk_tokens,
                          kernel_mode=args.kernel_mode,
                          single_port=args.single_port,
                          seq_tile=seq_tile,
                          length_bound=not args.no_length_bound,
                          dynamic_grid=not args.no_dynamic_grid,
                          num_kv_splits=args.num_kv_splits,
                          interpret=not args.no_interpret,
                          mesh=mesh,
                          schedule_mode=args.schedule_mode,
                          max_ports=args.max_ports,
                          default_ttl_ticks=args.deadline,
                          max_queue_depth=args.max_queue_depth,
                          prefix_cache=not args.no_prefix_cache)
    open_loop = args.trace is not None or args.arrival_rate is not None
    if args.chaos_seed is not None and not open_loop:
        raise SystemExit("--chaos-seed needs open-loop mode "
                         "(--arrival-rate or --trace)")
    if open_loop:
        if args.trace:
            arrivals = traffic.trace_arrivals(args.trace, vocab=cfg.vocab,
                                              seed=args.seed)
        else:
            max_prompt = max(args.max_len - args.max_new, 2)
            arrivals = traffic.poisson_arrivals(
                args.requests, args.arrival_rate, seed=args.seed,
                vocab=cfg.vocab, max_prompt=min(40, max_prompt),
                max_output=args.max_new)
        for a in arrivals:
            if a.prompt_len + a.max_new > args.max_len:
                raise SystemExit(
                    f"arrival ({a.prompt_len}+{a.max_new}) exceeds "
                    f"--max-len {args.max_len}")
        print(f"open-loop: {len(arrivals)} arrivals over ticks "
              f"[{arrivals[0].arrival_tick}, {arrivals[-1].arrival_tick}]"
              if arrivals else "open-loop: empty schedule")
        harness = None
        if args.chaos_seed is not None:
            from repro.serve.chaos import ChaosHarness, FaultPlan
            horizon = (arrivals[-1].arrival_tick + 1) if arrivals else 1
            harness = ChaosHarness(
                FaultPlan.generate(args.chaos_seed, horizon=horizon))
        t0 = time.perf_counter()
        traffic.drive(eng, arrivals, on_cycle=harness)
        if harness is not None:
            harness.finalize(eng)
        dt = time.perf_counter() - t0
        done = eng.finished
    else:
        rng = np.random.default_rng(args.seed)
        for _ in range(args.requests):
            eng.submit(list(rng.integers(0, cfg.vocab,
                                         int(rng.integers(3, 10)))),
                       max_new=args.max_new)
        t0 = time.perf_counter()
        done = eng.run()
        dt = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in done)
    mode = "single-port" if args.single_port else "multi-port"
    print(f"[{mode}] {len(done)} requests, {toks} tokens, "
          f"{eng.cycles} macro-cycles, {dt:.2f}s ({toks/max(dt,1e-9):.1f} tok/s)")
    print(f"pool traversals: {eng.pool_traversals} "
          f"({eng.pool_traversals / max(toks, 1):.2f}/token); "
          f"slots grown to {eng.n_slots}/{eng.max_slots}; prefill "
          f"{eng.prefill_traversals / max(eng.prefill_tokens, 1):.3f} "
          f"traversals/prompt-token over {eng.prefill_steps} chunk cycles")
    print(f"jit traces: decode {eng.decode_traces}, prefill-chunk "
          f"{eng.prefill_traces} (dynamic grid: {eng.dynamic_grid})")
    mixes = ", ".join(f"{k}: {v}" for k, v in
                      sorted(eng.pool.mix_counts.items()))
    print(f"schedule [{eng.schedule_mode}, max_ports={eng.max_ports}]: "
          f"{eng.coscheduled_cycles}/{eng.multi_phase_cycles} multi-phase "
          f"cycles co-scheduled (frac {eng.coschedule_frac:.2f}); "
          f"traversal mixes {{{mixes}}}")
    print(f"tile reads (seq_tile={eng.seq_tile}): decode "
          f"{eng.steady_decode_tile_reads} steady "
          f"(bound {eng.steady_decode_tile_bound}), prefill "
          f"{eng.prefill_tile_reads / max(eng.prefill_chunks, 1):.2f}/chunk "
          f"vs {-(-args.max_len // eng.seq_tile)} dense; pool "
          f"r/w {eng.pool.tile_reads}/{eng.pool.tile_writes}")
    if eng.n_kv_shards > 1:
        print(f"kv shards: {eng.n_kv_shards} "
              f"(pages/shard {eng.pool.plan.pages_per_shard}); steady decode "
              f"tile reads by device {eng.steady_decode_tile_reads_by_dev} "
              f"(balance {eng.kv_tile_balance:.2f}x ideal); pool tiles r/w "
              f"by shard {eng.pool.tile_reads_by_shard}/"
              f"{eng.pool.tile_writes_by_shard}")
    if eng.prefix_cache:
        ps = eng.prefix_stats
        print(f"prefix cache: {ps['hits']}/{ps['lookups']} admissions "
              f"attached a resident prefix ({ps['attached_tokens']} tokens "
              f"/ {ps['attached_pages']} pages adopted without recompute); "
              f"copy-on-write splits {ps['cow_copies']} "
              f"({ps['cow_words']} words copied)")
    if open_loop:
        ttft = np.array([r.ttft_ticks for r in done
                         if r.ttft_ticks is not None], dtype=np.float64)
        tpot = np.array([r.tpot_ticks for r in done
                         if r.tpot_ticks is not None], dtype=np.float64)
        if ttft.size:
            line = (f"latency (virtual ticks, 1 tick = 1 pool traversal): "
                    f"TTFT p50/p99 {np.percentile(ttft, 50):.1f}/"
                    f"{np.percentile(ttft, 99):.1f}")
            if tpot.size:
                line += (f"; per-token p50/p99 {np.percentile(tpot, 50):.2f}/"
                         f"{np.percentile(tpot, 99):.2f}")
            print(line)
        print(f"queue: peak depth {eng.admission.peak_depth}, "
              f"slot-contention cycles {eng.slot_contention_cycles}, "
              f"evict-pressure admissions {eng.evict_pressure_admissions}, "
              f"total ticks {eng.vclock}")
        if eng.shed or eng.cancelled or eng.capacity_parked_cycles:
            print(f"overload: shed {len(eng.shed)} "
                  f"(deadline {eng.shed_deadline}, queue_full "
                  f"{eng.shed_queue_full}, capacity {eng.shed_capacity}), "
                  f"capacity parked/recovered "
                  f"{eng.capacity_parked_cycles}/{eng.capacity_recoveries}, "
                  f"cancelled {eng.cancelled}")
        if harness is not None:
            print(f"chaos [seed {args.chaos_seed}]: "
                  f"{len(harness.injected)} actions, "
                  f"{harness.invariant_checks} invariant audits clean, "
                  f"stalled retirements {eng.stalled_retirements}, "
                  f"straggler events {harness.straggler_events}")
        if args.slo is not None and ttft.size:
            met = int((ttft <= args.slo).sum())
            print(f"SLO (p99 TTFT <= {args.slo:g} ticks): "
                  f"{'MET' if np.percentile(ttft, 99) <= args.slo else 'MISSED'}"
                  f" — {met}/{ttft.size} requests within SLO")
    for r in done[:4]:
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.generated}")


if __name__ == "__main__":
    main()
