"""Memory subsystem: the paged KV pool built on the multi-port memory."""
from repro.memory.paged_kv import PagedPool

__all__ = ["PagedPool"]
