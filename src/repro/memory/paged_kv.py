"""Paged KV cache on the multi-port memory — the paper's technique as the
serving memory manager.

The physical pool is ONE word-addressable MultiPortMemory (a word = one
token's K or V vector for one layer); sequences own pages of ``page_tokens``
words through a page table, exactly like vLLM's paged attention — except the
pool is accessed through the paper's configurable ports:

    port A (W): decode append     — one word per active sequence
    port B (R): attention reads   — gathers of page-resident words
    port C (W): prefill bulk fill — a prompt's pages in one macro-cycle
    port D (W): eviction          — freed pages zeroed (optional scrub)

Every macro-cycle services the enabled ports against the same physical pool
in priority order (core.multiport semantics), so fragmentation-free sharing
of HBM between growing/shrinking sequences comes for free, and the
bandwidth-amplification claim C1 applies verbatim: one pool traversal
services all four streams.

This module keeps the page-table bookkeeping host-side (python ints —
it is control plane, like the engine's scheduler) while all data-plane
traffic flows through ``core.step``/``step_banked``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MemorySpec, PortConfig, READ, WRITE, PortRequest,
                        empty_request, step, step_banked)


@dataclasses.dataclass
class PagedPool:
    """Physical pool + free list + per-sequence page tables."""

    spec: MemorySpec
    page_tokens: int
    storage: jax.Array
    free_pages: list
    tables: dict                       # seq_id -> list[page_id]
    lengths: dict                      # seq_id -> tokens stored
    use_kernel: bool = False

    @classmethod
    def create(cls, *, n_pages: int, page_tokens: int, word_width: int,
               dtype=jnp.float32, num_banks: int = 8,
               use_kernel: bool = False) -> "PagedPool":
        spec = MemorySpec(num_words=n_pages * page_tokens,
                          word_width=word_width, dtype=dtype,
                          num_banks=num_banks)
        return cls(spec=spec, page_tokens=page_tokens,
                   storage=spec.init_storage(),
                   free_pages=list(range(n_pages)), tables={}, lengths={},
                   use_kernel=use_kernel)

    # ---- control plane ------------------------------------------------------
    def _ensure_capacity(self, seq: int, new_tokens: int) -> None:
        table = self.tables.setdefault(seq, [])
        self.lengths.setdefault(seq, 0)
        need = -(-(self.lengths[seq] + new_tokens) // self.page_tokens)
        while len(table) < need:
            if not self.free_pages:
                raise MemoryError("pool exhausted")
            table.append(self.free_pages.pop())

    def _addr(self, seq: int, token_idx: np.ndarray) -> np.ndarray:
        table = np.asarray(self.tables[seq])
        return (table[token_idx // self.page_tokens] * self.page_tokens
                + token_idx % self.page_tokens)

    def free(self, seq: int) -> None:
        self.free_pages.extend(self.tables.pop(seq, []))
        self.lengths.pop(seq, None)

    # ---- data plane: one macro-cycle -----------------------------------------
    def cycle(self, *, append: Optional[dict] = None,
              read: Optional[dict] = None,
              prefill: Optional[dict] = None) -> dict:
        """Service up to three logical streams in ONE pool traversal.

        append:  {"seq": int, "vectors": [T, W]} — decode appends
        read:    {"seq": int, "positions": int array} — attention gather
        prefill: {"seq": int, "vectors": [T, W]} — bulk prompt fill
        Returns {"read": [Q, W] or None}.
        """
        q = 0
        for s in (append, read, prefill):
            if s is not None:
                n = (len(s["positions"]) if "positions" in s
                     else s["vectors"].shape[0])
                q = max(q, n)
        if q == 0:
            return {"read": None}

        reqs = [empty_request(q, self.spec.word_width, self.spec.dtype)
                for _ in range(4)]
        roles = [WRITE, READ, WRITE, READ]

        def _fill_write(port, stream):
            seq, vec = stream["seq"], np.asarray(stream["vectors"])
            t = vec.shape[0]
            self._ensure_capacity(seq, t)
            idx = np.arange(self.lengths[seq], self.lengths[seq] + t)
            addr = np.zeros(q, np.int32)
            data = np.zeros((q, self.spec.word_width), np.float32)
            mask = np.zeros(q, bool)
            addr[:t] = self._addr(seq, idx)
            data[:t] = vec
            mask[:t] = True
            self.lengths[seq] += t
            reqs[port] = PortRequest(addr=jnp.asarray(addr),
                                     data=jnp.asarray(data, self.spec.dtype),
                                     mask=jnp.asarray(mask))

        if append is not None:
            _fill_write(0, append)
        if prefill is not None:
            _fill_write(2, prefill)
        if read is not None:
            seq = read["seq"]
            pos = np.asarray(read["positions"])
            addr = np.zeros(q, np.int32)
            mask = np.zeros(q, bool)
            addr[: len(pos)] = self._addr(seq, pos)
            mask[: len(pos)] = True
            reqs[1] = PortRequest(addr=jnp.asarray(addr),
                                  data=jnp.zeros((q, self.spec.word_width),
                                                 self.spec.dtype),
                                  mask=jnp.asarray(mask))

        cfg = PortConfig(enabled=(append is not None, read is not None,
                                  prefill is not None, False),
                         roles=tuple(roles))
        runner = step_banked if self.use_kernel else step
        self.storage, reads = runner(self.spec, cfg, self.storage, reqs)
        out = reads[1] if read is not None else None
        if out is not None:
            out = out[: len(read["positions"])]
        return {"read": out}

    @property
    def utilization(self) -> float:
        total = self.spec.num_words // self.page_tokens
        return 1.0 - len(self.free_pages) / total
