"""Paged KV cache on the multi-port memory — the paper's technique as the
serving memory manager.

The physical pool is ONE word-addressable MultiPortMemory (a word = one
token's full KV footprint: K and V vectors for every layer); sequences own
pages of ``page_tokens`` words through a page table, exactly like vLLM's
paged attention — except the pool is accessed through the paper's
configurable ports:

    port A (W): decode append     — one word per active sequence
    port B (R): attention reads   — gathers of page-resident words
    port C (W): prefill bulk fill — admitted prompts' pages in one shot
    port D (W): eviction scrub    — freed pages zeroed

One :meth:`cycle` call is ONE physical traversal of the pool servicing every
enabled port, in the engine's FSM order (priority ``A > D > C > B``): decode
appends land first, eviction scrubs reclaim pages before bulk prefill can
reuse them, and attention reads observe everything written earlier in the
same macro-cycle (the paper's same-cycle W->R visibility). ``traversals``
counts physical traversals — the serving engine benchmark divides it by
generated tokens to measure claim C1 at the system level. ``tile_reads`` /
``tile_writes`` additionally count the DISTINCT ``seq_tile``-word tiles each
traversal actually touches per port role, so a traversal over a short live
sequence is visibly cheaper than one over a full-capacity sequence — the
length-bounded-traversal discipline measured at the pool level.

Each port stream accepts a single ``{"seq": ...}`` dict or a LIST of them
(multi-sequence transactions): the pool packs all streams of a port into one
vectorized request queue, so e.g. every active slot's decode append is one
port-A transaction.

``use_kernel=True`` backs the data plane with ``core.step_banked`` (the
Pallas one-traversal kernel; ``interpret=`` executes it in Python on CPU
CI), ``use_kernel=False`` keeps the jnp oracle ``core.step``. The page-table
bookkeeping stays host-side (python ints — it is control plane, like the
engine's scheduler).

**Multi-device sharding** (``kv_shards`` > 1, optionally backed by a real
``mesh`` with a ``kv`` axis): the pool's word axis — its sequence/page axis
— shards across devices with PAGE-ALIGNED boundaries (the plan is validated
by :func:`repro.distributed.sharding.kv_shard_plan`; a page never straddles
two shards). Page allocation becomes device-aware: each sequence gets a HOME
shard on admission (least-loaded by live-sequence count, then by free
pages) and every one of its pages is carved from that shard's own free
list, so a sequence's whole KV — and therefore every port transaction that
touches it — stays device-local. A cycle whose page demand overflows a home
shard raises :class:`PoolCapacityError` BEFORE any mutation, even when
other shards still have free pages (cross-shard spill would break
locality; the scheduler can evict or re-admit instead). Page tables stay
replicated host-side control plane.

With a real ``mesh``, the data plane runs under ``shard_map``: storage is
laid out ``P("kv", None)`` (``kv_pool_spec``), each device services the
request lanes whose global word addresses fall inside its shard (local
re-addressing + mask), and read ports psum their lane results — exactly one
shard owns each address, so the sum is the gather. One sharded cycle is
still ONE traversal: all shards traverse concurrently, which is the paper's
multi-port discipline extended across independent memory channels.
``kv_shards`` without a mesh keeps the device-aware control plane (home
shards, per-shard free lists, the capacity precheck) over unsharded
storage — the cheap CI surface the allocation property tests run against.

**Refcounted copy-on-write page sharing** (the prefix-cache substrate):
pages are no longer exclusively owned — ``refcounts`` tracks how many page
tables reference each physical page, :meth:`free` DECREMENTS (a page only
returns to its shard's free list, and only then may be scrubbed, when the
last reference dies; earlier releases just detach), and a
content-addressed prefix index keyed on token-hash chains at page
granularity (:meth:`register_prefix` / :meth:`match_prefix` /
:meth:`attach_prefix`) lets a new sequence adopt an already-committed
prompt prefix by refcount bump instead of recomputing it. Sharing is
READ-ONLY by construction: a write whose word would land in a shared page
copy-on-writes it first (fresh page carved on the WRITER's home shard, the
live words copied through the same traversal's W port, only the writer's
table remapped — see :meth:`_cow_prepare`), so hazard analysis can treat
shared pages as read-shared/write-private. Shared pages pin to the shard
where they were first written and an attaching sequence's home FOLLOWS the
matched prefix (its unmatched tail is carved there too) — a full
least-loaded shard sheds load by sharing instead of raising
:class:`PoolCapacityError`. With no registrations the pool behaves
bit-identically to exclusive ownership.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MemorySpec, PortConfig, READ, WRITE, PortRequest,
                        empty_request, step, step_banked)
from repro.distributed.sharding import (KVShardPlan, compat_shard_map,
                                        kv_pool_spec, kv_shard_plan,
                                        shard_of_pages)
from repro.kernels.tiling import word_pad

# pool port indices
APPEND, ATTN_READ, BULK_FILL, SCRUB = 0, 1, 2, 3
# service order: appends > scrubs > bulk fills > reads (see module docstring)
_PRIORITY = (APPEND, SCRUB, BULK_FILL, ATTN_READ)
_ROLES = (WRITE, READ, WRITE, WRITE)

Stream = Union[dict, Sequence[dict], None]


class PoolCapacityError(MemoryError):
    """An admission's page demand exceeds its home shard's free page supply.

    Raised BEFORE any page-table or length mutation: a failed transaction
    leaves the pool exactly as it was, so the scheduler can retry the
    admission after evictions free pages. Under device-aware allocation the
    error names the full home shard even when OTHER shards still hold free
    pages — a sequence's pages never spill across shards."""


# root of every prefix hash chain (see PagedPool.register_prefix)
_PREFIX_ROOT = -1


def _chain_key(parent: int, page_toks: tuple) -> int:
    """Content-address of a page-granular prefix chain node: the hash of
    (parent chain key, this page's token tuple). Python's tuple-of-int hash
    is deterministic (PYTHONHASHSEED only perturbs str/bytes), so the chain
    is stable across processes — trace replays and subprocess oracles see
    the same index."""
    return hash((parent, page_toks))


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """A non-mutating :meth:`PagedPool.match_prefix` result: the registered
    pages a prompt's head can adopt by refcount bump. ``tokens`` counts the
    matched prefix (the LAST page may be partial — the matcher's own writes
    copy-on-write around its remaining words); ``full_pages`` is how many
    matched pages the adopter will never write (``tokens // page_tokens``
    — the count admission subtracts from worst-case page demand, since a
    partial tail page is offset by its own CoW replacement). All matched
    pages live on ``shard`` — shared pages pin where first written."""

    pages: tuple                       # matched page ids, chain order
    tokens: int                        # matched prefix length in tokens
    shard: int                         # the one shard holding every page
    full_pages: int                    # fully-matched pages (never written)


def _bucket(n: int, lo: int = 8) -> int:
    """Round a queue length up to a power of two (jit shape reuse)."""
    b = lo
    while b < n:
        b *= 2
    return b


def seq_tile_buckets(max_len: int, seq_tile: int) -> tuple[int, ...]:
    """The staging-cache lengths the engine's length-bounded dispatch can
    stage (and so the shapes its jitted decode / prefill-chunk steps retrace
    at): power-of-two counts of ``seq_tile`` tiles, the last PADDED up to
    ``ceil(max_len / seq_tile) * seq_tile`` so every staged length is a
    whole number of tiles (the kernels never fall back to degenerate
    tile-1 grids for awkward capacities).

    The single source of truth for the ladder: the engine's ``_stage_len``
    walks it and ``launch/serve.py`` validates ``--seq-tile`` against it at
    startup. Raises ValueError when ``seq_tile`` cannot tile a ``max_len``
    cache.
    """
    if seq_tile < 1:
        raise ValueError(f"seq_tile must be >= 1, got {seq_tile}")
    if seq_tile > max_len:
        raise ValueError(
            f"seq_tile ({seq_tile}) exceeds the model's S_max ({max_len}); "
            f"the smallest live bucket would overrun the cache")
    cap = -(-max_len // seq_tile) * seq_tile       # padded full capacity
    lens = []
    n = 1
    while n * seq_tile < cap:
        lens.append(n * seq_tile)
        n *= 2
    lens.append(cap)
    return tuple(lens)


@functools.partial(jax.jit, static_argnames=("spec", "config", "use_kernel",
                                             "interpret"))
def _pool_step(spec, config, storage, requests, *, use_kernel: bool,
               interpret: bool):
    if use_kernel:
        return step_banked(spec, config, storage, requests,
                           interpret=interpret)
    return step(spec, config, storage, requests)


@functools.lru_cache(maxsize=None)
def _sharded_pool_step(local_spec, config, mesh, kv_axis: str, wps: int,
                       use_kernel: bool, interpret: bool):
    """Jitted shard-mapped pool step: each shard services the request lanes
    whose global addresses land in its ``wps``-word range (local
    re-addressing; lanes owned by other shards are masked off — masked
    read lanes return 0), then read ports psum lane results across the
    ``kv`` axis. Exactly one shard owns each address, so the psum IS the
    gather, and the write/scrub lanes commit on their owner only."""
    from jax.sharding import PartitionSpec as P

    def body(storage, requests):
        sid = jax.lax.axis_index(kv_axis)
        lo = sid * wps
        local = tuple(
            PortRequest(addr=r.addr - lo, data=r.data,
                        mask=r.mask & (r.addr >= lo) & (r.addr < lo + wps))
            for r in requests)
        if use_kernel:
            st, outs = step_banked(local_spec, config, storage, local,
                                   interpret=interpret)
        else:
            st, outs = step(local_spec, config, storage, local)
        outs = [jax.lax.psum(o, kv_axis) if config.roles[p] == READ
                else o for p, o in enumerate(outs)]
        return st, outs

    smapped = compat_shard_map(
        body, mesh,
        in_specs=(P(kv_axis, None), (P(),) * 4),
        out_specs=(P(kv_axis, None), [P()] * 4))
    return jax.jit(smapped)


@dataclasses.dataclass
class PagedPool:
    """Physical pool + per-shard free lists + per-sequence page tables."""

    spec: MemorySpec
    page_tokens: int
    storage: jax.Array
    free_by_shard: list                # shard -> free page ids (device-aware)
    tables: dict                       # seq_id -> list[page_id]
    lengths: dict                      # seq_id -> tokens stored
    plan: KVShardPlan = None           # page-aligned shard geometry
    home: dict = dataclasses.field(default_factory=dict)  # seq_id -> shard
    mesh: Optional[object] = None      # jax Mesh with the kv axis (or None)
    kv_axis: str = "kv"
    spec_local: Optional[MemorySpec] = None   # per-shard geometry (mesh only)
    use_kernel: bool = False
    interpret: bool = True
    traversals: int = 0                # physical pool traversals serviced
    seq_tile: int = 0                  # words per accounting tile
    tile_reads: int = 0                # distinct R-port tiles touched
    tile_writes: int = 0               # distinct W-port tiles touched
    tile_reads_by_shard: list = dataclasses.field(default_factory=list)
    tile_writes_by_shard: list = dataclasses.field(default_factory=list)
    io_width: int = 0                  # caller-visible word width (the
                                       # storage word is lane-padded past it)
    mix_counts: dict = dataclasses.field(default_factory=dict)
                                       # PortConfig.describe() -> traversals
                                       # serviced with that port mix
    quarantine_by_shard: list = dataclasses.field(default_factory=list)
                                       # shard -> pages withheld from
                                       # allocation by a chaos squeeze
    refcounts: dict = dataclasses.field(default_factory=dict)
                                       # page -> tables referencing it (every
                                       # mapped page has an entry >= 1; free
                                       # and quarantined pages have none)
    prefix_index: dict = dataclasses.field(default_factory=dict)
                                       # parent chain key -> {page token
                                       # tuple -> page id} (content-addressed
                                       # prefix chains, page granularity)
    page_reg: dict = dataclasses.field(default_factory=dict)
                                       # page -> (parent, token tuple): its
                                       # index slot, dropped on last release
    prefix_lookups: int = 0            # match_prefix calls
    prefix_hits: int = 0               # attaches (>= 1 token adopted)
    prefix_attached_tokens: int = 0    # tokens adopted without recompute
    prefix_attached_pages: int = 0     # pages adopted by refcount bump
    cow_copies: int = 0                # shared tail pages remapped on write
    cow_words: int = 0                 # live words those remaps copied

    @classmethod
    def create(cls, *, n_pages: int, page_tokens: int, word_width: int,
               dtype=jnp.float32, num_banks: int = 8,
               use_kernel: bool = False, interpret: bool = True,
               seq_tile: int = 0, kv_shards: int = 1, mesh=None,
               kv_axis: str = "kv") -> "PagedPool":
        if mesh is not None:
            if kv_axis not in mesh.axis_names:
                raise ValueError(
                    f"mesh {mesh.axis_names} has no {kv_axis!r} axis")
            mesh_n = int(mesh.shape[kv_axis])
            if kv_shards not in (1, mesh_n):
                raise ValueError(
                    f"kv_shards={kv_shards} disagrees with the mesh's "
                    f"{mesh_n}-way {kv_axis!r} axis")
            kv_shards = mesh_n
        # page-aligned shard plan: rounds the pool UP to whole pages/shard
        plan = kv_shard_plan(kv_shards, n_pages=n_pages,
                             page_tokens=page_tokens)
        num_words = plan.num_words
        while num_words % num_banks:
            num_banks //= 2                       # geometry guard
        num_banks = max(num_banks, 1)
        # Mosaic lane alignment: the STORAGE word is padded to a whole lane
        # count (word_pad) so the banked kernel's [wpb, W] tiles keep a
        # 128-multiple minor dim at CI's small word widths too; callers keep
        # reading/writing ``word_width``-wide vectors (the pad lanes are
        # zero and cropped on the way out)
        spec = MemorySpec(num_words=num_words,
                          word_width=word_pad(word_width), dtype=dtype,
                          num_banks=num_banks)
        storage = spec.init_storage()
        spec_local = None
        if mesh is not None and kv_shards > 1:
            from jax.sharding import NamedSharding
            pspec = kv_pool_spec(mesh, num_words=num_words,
                                 page_tokens=page_tokens, axis=kv_axis)
            storage = jax.device_put(storage, NamedSharding(mesh, pspec))
            wps = plan.words_per_shard
            nb_local = num_banks
            while wps % nb_local:
                nb_local //= 2
            spec_local = MemorySpec(num_words=wps,
                                    word_width=spec.word_width, dtype=dtype,
                                    num_banks=max(nb_local, 1))
        return cls(spec=spec, page_tokens=page_tokens, storage=storage,
                   free_by_shard=[list(range(s * plan.pages_per_shard,
                                             (s + 1) * plan.pages_per_shard))
                                  for s in range(kv_shards)],
                   tables={}, lengths={}, plan=plan, mesh=mesh,
                   kv_axis=kv_axis, spec_local=spec_local,
                   use_kernel=use_kernel, interpret=interpret,
                   seq_tile=seq_tile or page_tokens,
                   tile_reads_by_shard=[0] * kv_shards,
                   tile_writes_by_shard=[0] * kv_shards,
                   io_width=word_width,
                   quarantine_by_shard=[[] for _ in range(kv_shards)])

    # ---- shard geometry ------------------------------------------------------
    @property
    def kv_shards(self) -> int:
        return self.plan.n_shards

    @property
    def words_per_shard(self) -> int:
        return self.plan.words_per_shard

    @property
    def free_pages(self) -> list:
        """All free page ids (shard-major) — the legacy single-list view."""
        return [p for fl in self.free_by_shard for p in fl]

    @property
    def free_page_count(self) -> int:
        return sum(len(fl) for fl in self.free_by_shard)

    @property
    def quarantined_pages(self) -> tuple:
        """Pages withheld from allocation by a fault-injection squeeze
        (sorted; empty outside chaos runs)."""
        return tuple(sorted(p for q in self.quarantine_by_shard for p in q))

    def quarantine(self, n_per_shard: int,
                   keep_free: Optional[Sequence[int]] = None) -> list:
        """Fault injection: withhold up to ``n_per_shard`` FREE pages per
        shard from allocation (an admission-time capacity squeeze — the
        chaos harness's knob). Only free pages are taken, and a
        ``keep_free`` floor (per shard) protects pages the engine has
        conservatively reserved for in-flight sequences' worst-case
        growth, so a squeeze pressures ADMISSION — parked/retried/shed at
        the queue — without ever making an already-admitted sequence's
        append fail mid-stream. Returns the page ids actually taken;
        :meth:`release_quarantine` gives them back."""
        if n_per_shard < 0:
            raise ValueError(f"n_per_shard must be >= 0, got {n_per_shard}")
        keep = list(keep_free) if keep_free is not None \
            else [0] * self.kv_shards
        if len(keep) != self.kv_shards:
            raise ValueError(
                f"keep_free has {len(keep)} entries for {self.kv_shards} "
                f"shards")
        taken = []
        for s, fl in enumerate(self.free_by_shard):
            n = min(n_per_shard, max(0, len(fl) - keep[s]))
            for _ in range(n):
                p = fl.pop()
                if self.refcounts.get(p, 0):
                    # free lists never hold mapped pages; a refcounted page
                    # here means the pool's books are corrupt — refuse the
                    # squeeze rather than withhold words sequences still read
                    fl.append(p)
                    raise ValueError(
                        f"quarantine refused page {p}: refcount "
                        f"{self.refcounts[p]} > 0 (tables still reference "
                        f"it, yet it sat on shard {s}'s free list)")
                self.quarantine_by_shard[s].append(p)
                taken.append(p)
        return taken

    def release_quarantine(self) -> list:
        """Return every quarantined page to its owning shard's free list
        (the squeeze's scheduled end). Returns the released page ids."""
        released = []
        for s, q in enumerate(self.quarantine_by_shard):
            self.free_by_shard[s].extend(q)
            released.extend(q)
            q.clear()
        return released

    def home_of(self, seq: int) -> Optional[int]:
        """The shard a sequence's pages live on (None before admission)."""
        return self.home.get(seq)

    def _home_loads(self) -> list:
        loads = [0] * self.kv_shards
        for s in self.home.values():
            loads[s] += 1
        return loads

    def _pick_home(self, loads: list, free_counts: list) -> int:
        """THE home-selection policy — least live sequences, then most free
        pages, then lowest shard id. The transactional precheck simulates
        admissions through this same function, so the shard it validates is
        always the shard the commit path assigns."""
        return min(range(self.kv_shards),
                   key=lambda s: (loads[s], -free_counts[s], s))

    def assign_home(self, seq: int) -> int:
        """Pick (or return) a sequence's home shard. Idempotent; callers may
        pre-assign at admission so the engine can group compute by shard
        before the first page is carved."""
        got = self.home.get(seq)
        if got is not None:
            return got
        shard = self._pick_home(self._home_loads(),
                                [len(fl) for fl in self.free_by_shard])
        self.home[seq] = shard
        return shard

    def peek_home(self, seq: int) -> int:
        """The shard :meth:`assign_home` WOULD pick (or has picked) for a
        sequence, without committing anything — the admission precheck's
        view."""
        got = self.home.get(seq)
        if got is not None:
            return got
        return self._pick_home(self._home_loads(),
                               [len(fl) for fl in self.free_by_shard])

    def admission_precheck(self, seq: int, total_tokens: int,
                           reserved_by_shard: Optional[Sequence[int]] = None,
                           *, prefix: Optional[PrefixMatch] = None) -> int:
        """Raise :class:`PoolCapacityError` unless a sequence's WORST-CASE
        page demand (``total_tokens`` words over its whole lifetime) fits
        its home shard's free list right now, minus ``reserved_by_shard``
        pages the caller has already promised to other in-flight
        sequences. Non-mutating — no home assignment, no page pops — so
        the engine can probe at admission time, PARK the request on
        failure, and retry after evictions free pages (the recovery path
        that replaces an uncatchable mid-cycle capacity failure). Returns
        the home shard the probe validated against.

        With a ``prefix`` match (a fresh sequence adopting shared pages),
        the probe moves to the PREFIX's shard — the sequence's home will
        follow the matched pages — and demand shrinks to the unmatched
        tail: ``ceil(total_tokens / page_tokens) - prefix.full_pages``.
        Only FULLY-matched pages subtract; a partially-matched tail page
        is offset by the fresh page its copy-on-write replacement will
        carve. This is how a request that would overflow the least-loaded
        shard still admits against a fuller shard that already holds its
        prompt."""
        if prefix is not None and self.tables.get(seq):
            raise ValueError(
                f"seq {seq} already holds pages — prefix-aware prechecks "
                f"are for fresh admissions only")
        if prefix is not None:
            shard = prefix.shard
            need = max(0, -(-total_tokens // self.page_tokens)
                       - prefix.full_pages)
        else:
            shard = self.peek_home(seq)
            held = len(self.tables.get(seq, []))
            need = max(0, -(-(self.lengths.get(seq, 0) + total_tokens)
                            // self.page_tokens) - held)
        reserved = reserved_by_shard[shard] if reserved_by_shard is not None \
            else 0
        avail = len(self.free_by_shard[shard]) - reserved
        if need > avail:
            quarantined = len(self.quarantine_by_shard[shard])
            matched = f", {prefix.tokens} prefix tokens matched" \
                if prefix is not None else ""
            raise PoolCapacityError(
                f"admission precheck: seq {seq} needs {need} pages on home "
                f"shard {shard} for its worst-case {total_tokens} tokens"
                f"{matched} but only {max(avail, 0)} of the shard's "
                f"{len(self.free_by_shard[shard])} free pages are "
                f"unreserved ({reserved} reserved for in-flight sequences, "
                f"{quarantined} quarantined) — park and retry after "
                f"evictions, or shed")
        return shard

    def _tile_shard(self, tile: int) -> int:
        """Shard owning an accounting tile, attributed by its FIRST word.

        Exact whenever ``seq_tile`` divides ``words_per_shard`` (true for
        the power-of-two shard counts and tile sizes the launchers and CI
        use); for geometries where a ``seq_tile``-word window can straddle
        a boundary, the straddling tile counts toward the lower shard —
        an observability approximation only, never a data-placement one
        (pages, and therefore words, still never straddle)."""
        if self.kv_shards == 1:
            return 0
        return min((tile * self.seq_tile) // self.words_per_shard,
                   self.kv_shards - 1)

    def _count_tiles(self, tiles: set, counters: list) -> int:
        for t in tiles:
            counters[self._tile_shard(int(t))] += 1
        return len(tiles)

    # ---- control plane ------------------------------------------------------
    def _ensure_capacity(self, seq: int, new_tokens: int) -> None:
        table = self.tables.setdefault(seq, [])
        self.lengths.setdefault(seq, 0)
        need = -(-(self.lengths[seq] + new_tokens) // self.page_tokens)
        shard = self.assign_home(seq)
        free = self.free_by_shard[shard]
        while len(table) < need:
            if not free:
                raise PoolCapacityError(
                    f"seq {seq}: growing to {self.lengths[seq] + new_tokens} "
                    f"tokens needs {need} pages but only {len(table)} are "
                    f"mapped and home shard {shard}'s free list is empty "
                    f"({self.free_page_count} pages free pool-wide — pages "
                    f"never straddle shards)")
            p = free.pop()
            self.refcounts[p] = 1
            table.append(p)

    def _check_capacity(self, write_streams: Sequence[dict],
                        read_streams: Sequence[dict]) -> None:
        """Transactional admission check, run BEFORE any table mutation:
        each sequence's page demand must fit its HOME shard's free list
        (simulated per shard, in stream order, so multi-sequence admissions
        see the same home-assignment the commit path will make), and every
        read position must fall inside the words its sequence will have
        mapped once this cycle's writes land (reads are serviced after
        writes, so same-cycle append+read of a fresh page is legal)."""
        demand: dict = {}
        for s in write_streams:
            seq = s["seq"]
            demand[seq] = demand.get(seq, 0) + int(s["vectors"].shape[0])
        sim_free = [len(fl) for fl in self.free_by_shard]
        loads = self._home_loads()
        staged_homes: dict = {}
        projected = {}
        for seq, new_tokens in demand.items():
            held = len(self.tables.get(seq, []))
            pages = max(held,
                        -(-(self.lengths.get(seq, 0) + new_tokens)
                          // self.page_tokens))
            projected[seq] = pages
            need = pages - held
            if new_tokens:
                # a shared tail page is write-private: this cycle's commit
                # will copy-on-write it, carving ONE page beyond table growth
                need += self.pending_cow_pages(seq)
            shard = self.home.get(seq)
            if shard is None:
                shard = self._pick_home(loads, sim_free)
                staged_homes[seq] = shard
                loads[shard] += 1
            if need > sim_free[shard]:
                elsewhere = sum(sim_free) - sim_free[shard]
                raise PoolCapacityError(
                    f"admission of {demand[seq]} tokens for seq {seq} needs "
                    f"{need} new pages on home shard {shard} but only "
                    f"{sim_free[shard]} of its {self.plan.pages_per_shard} "
                    f"are free ({elsewhere} free pages on other shards are "
                    f"unusable — pages never straddle shards; evict "
                    f"sequences or raise the pool size)")
            sim_free[shard] -= need
        for s in read_streams:
            seq = s["seq"]
            pages = projected.get(seq, len(self.tables.get(seq, [])))
            pos = np.asarray(s["positions"])
            if not pages:
                raise IndexError(f"seq {seq} has no pages mapped")
            if pos.size and (pos.min() < 0
                             or pos.max() >= pages * self.page_tokens):
                raise IndexError(
                    f"seq {seq}: positions [{pos.min()}, {pos.max()}] outside "
                    f"the {pages * self.page_tokens} words its page table "
                    f"maps this cycle")
        # the WHOLE cycle validated (capacity and reads): commit the staged
        # home assignments (metadata only — the page mutations follow in
        # _write_req via _ensure_capacity, which reuses exactly these homes).
        # Committing last keeps the transactional contract: a refused cycle
        # leaves the pool, home map included, exactly as it was.
        self.home.update(staged_homes)

    def _addr(self, seq: int, token_idx: np.ndarray) -> np.ndarray:
        table = self.tables.get(seq)
        if not table:
            raise IndexError(f"seq {seq} has no pages mapped")
        token_idx = np.asarray(token_idx)
        mapped = len(table) * self.page_tokens
        if token_idx.size and (token_idx.min() < 0
                               or token_idx.max() >= mapped):
            raise IndexError(
                f"seq {seq}: positions [{token_idx.min()}, {token_idx.max()}]"
                f" outside the {mapped} words mapped by its page table")
        table = np.asarray(table)
        return (table[token_idx // self.page_tokens] * self.page_tokens
                + token_idx % self.page_tokens)

    def free(self, seq: int) -> list:
        """Release a sequence's CLAIM on its pages: each page's refcount
        drops by one, and only pages reaching ZERO return to their owning
        shards' free lists. Returns exactly those dead pages (so the caller
        scrubs only physically-unreferenced words through port D in the
        same macro-cycle); pages other sequences still reference DETACH —
        their words survive untouched for the tables, and prefix-index
        entries, still mapping them. A dead page also leaves the prefix
        index, so matches never resolve to recycled storage."""
        pages = self.tables.pop(seq, [])
        self.lengths.pop(seq, None)
        self.home.pop(seq, None)
        dead = []
        for p in pages:
            rc = self.refcounts.get(p, 1) - 1
            if rc > 0:
                self.refcounts[p] = rc
                continue
            self.refcounts.pop(p, None)
            self._deregister_page(p)
            self.free_by_shard[self.plan.shard_of_page(p)].append(p)
            dead.append(p)
        return dead

    # ---- prefix sharing (refcounted copy-on-write) ---------------------------
    def page_refcount(self, page: int) -> int:
        """How many page tables reference a page (0 = free/quarantined)."""
        return self.refcounts.get(page, 0)

    def _deregister_page(self, page: int) -> None:
        reg = self.page_reg.pop(page, None)
        if reg is None:
            return
        parent, key = reg
        kids = self.prefix_index.get(parent)
        if kids and kids.get(key) == page:
            del kids[key]
            if not kids:
                del self.prefix_index[parent]

    def pending_cow_pages(self, seq: int) -> int:
        """1 when the sequence's NEXT write must copy-on-write a shared
        tail page — one extra page its home shard must hold beyond plain
        table growth — else 0. Admission reservations and the transactional
        capacity checks both consult this, so a squeeze or a crowded shard
        can never strand an attached sequence mid-append. Always 0 when
        nothing is shared (exclusive-ownership behavior unchanged)."""
        length = self.lengths.get(seq, 0)
        off = length % self.page_tokens
        if not off:
            return 0
        table = self.tables.get(seq, [])
        idx = length // self.page_tokens
        if idx >= len(table):
            return 0
        return 1 if self.refcounts.get(table[idx], 1) > 1 else 0

    def register_prefix(self, seq: int, tokens: Sequence[int]) -> int:
        """Index a sequence's COMMITTED prompt KV for future admissions:
        each page covered by ``tokens`` joins the content-addressed chain
        under the hash of (parent chain key, the page's token tuple), plus
        at most one sub-page tail entry ending the chain. First
        registration wins — an identical chain already indexed keeps its
        pages (that is the dedup), and the walk continues along the
        existing chain so extensions converge. Returns how many pages this
        call newly indexed. The words must already be in the pool
        (``lengths`` covers ``tokens``) — the engine registers at prefill
        completion, inside the macro-cycle that commits the final chunk."""
        toks = tuple(int(t) for t in tokens)
        committed = self.lengths.get(seq, 0)
        if committed < len(toks):
            raise ValueError(
                f"seq {seq}: cannot register a {len(toks)}-token prefix — "
                f"only {committed} tokens committed")
        table = self.tables.get(seq, [])
        parent = _PREFIX_ROOT
        new = 0
        for i in range(0, len(toks), self.page_tokens):
            key = toks[i:i + self.page_tokens]
            kids = self.prefix_index.setdefault(parent, {})
            page = table[i // self.page_tokens]
            if key not in kids and page not in self.page_reg:
                kids[key] = page
                self.page_reg[page] = (parent, key)
                new += 1
            if not kids:
                del self.prefix_index[parent]      # keep the index sparse
            if len(key) < self.page_tokens:
                break                              # partial tail ends chains
            parent = _chain_key(parent, key)
        return new

    def match_prefix(self, tokens: Sequence[int],
                     limit: Optional[int] = None) -> Optional[PrefixMatch]:
        """Walk the prefix index down a prompt's hash chain: full
        registered pages match page-at-a-time, then the walk may end on ONE
        partial match — the longest registered page head agreeing with the
        remaining tokens (valid because word ``i`` of a page depends only
        on tokens ``0..i`` of the whole prefix under causal attention; the
        matcher's own writes copy-on-write around the rest). Matching never
        crosses shards (chains are home-pinned by construction; a foreign
        page ends the walk). Non-mutating; returns None when nothing
        matched. ``limit`` caps matched tokens — the engine passes
        ``len(prompt) - 1`` so at least one prompt position is always
        recomputed (the first generated token needs its logits)."""
        toks = tuple(int(t) for t in tokens)
        lim = len(toks) if limit is None else min(limit, len(toks))
        self.prefix_lookups += 1
        pages: list = []
        matched = 0
        parent = _PREFIX_ROOT
        shard = None
        while matched + self.page_tokens <= lim:
            key = toks[matched:matched + self.page_tokens]
            page = self.prefix_index.get(parent, {}).get(key)
            if page is None:
                break
            s = self.plan.shard_of_page(page)
            if shard is None:
                shard = s
            elif s != shard:
                break
            pages.append(page)
            matched += self.page_tokens
            parent = _chain_key(parent, key)
        rest = toks[matched:lim]
        if rest:
            best = None                            # (match len, page id)
            for key, page in self.prefix_index.get(parent, {}).items():
                if shard is not None \
                        and self.plan.shard_of_page(page) != shard:
                    continue
                j = 0
                while j < len(rest) and j < len(key) and key[j] == rest[j]:
                    j += 1
                # longest head wins; page id breaks ties deterministically
                if j and (best is None or (-j, page) < (-best[0], best[1])):
                    best = (j, page)
            if best is not None:
                j, page = best
                if shard is None:
                    shard = self.plan.shard_of_page(page)
                pages.append(page)
                matched += j
        if not matched:
            return None
        return PrefixMatch(pages=tuple(pages), tokens=matched, shard=shard,
                           full_pages=matched // self.page_tokens)

    def attach_prefix(self, seq: int, match: PrefixMatch) -> int:
        """Attach a FRESH sequence to matched prefix pages by refcount bump
        — no words move, no pages pop. The sequence's home becomes the
        shard holding the prefix (shared pages pin where first written, and
        the unmatched tail will be carved there too), which is what lets a
        full least-loaded shard shed load by sharing. Returns that shard.
        Must precede any allocation for the sequence."""
        if self.tables.get(seq):
            raise ValueError(f"seq {seq} already holds pages — prefix "
                             f"attach must precede allocation")
        if not match.pages:
            raise ValueError(f"seq {seq}: empty prefix match")
        shard = shard_of_pages(self.plan, match.pages)
        if shard != match.shard:
            raise ValueError(
                f"seq {seq}: match claims shard {match.shard} but its pages "
                f"live on shard {shard}")
        self.tables[seq] = list(match.pages)
        self.lengths[seq] = match.tokens
        self.home[seq] = shard
        for p in match.pages:
            self.refcounts[p] = self.refcounts.get(p, 0) + 1
        self.prefix_hits += 1
        self.prefix_attached_tokens += match.tokens
        self.prefix_attached_pages += len(match.pages)
        return shard

    def gather_words(self, seq: int, positions) -> np.ndarray:
        """Host-side staging gather of a sequence's committed words,
        cropped to the caller-visible ``io_width``. This is how the engine
        refills a prefill staging cache from ATTACHED prefix pages whose
        KV it never computed — control-plane staging like the CoW source
        read, not a ported traversal (the pool's ports only carry words
        the model is writing or attending this macro-cycle)."""
        addr = self._addr(seq, np.asarray(positions))
        got = np.asarray(self.storage[jnp.asarray(addr)], np.float32)
        return got[:, :self.io_width]

    def _cow_prepare(self, seq: int, new_tokens: int):
        """Copy-on-write remap for a write stream: when the sequence's next
        word would land in a page OTHER tables still reference (refcount >
        1), carve a fresh page from the FRONT of its home shard's free list
        — growth pops the BACK, and the split keeps page identities stable
        between the scheduler's footprint projection and this commit
        whatever the traversal grouping — move this sequence's refcount to
        the fresh page, and remap ONLY its table entry. Returns the
        ``(old_words, new_words)`` address arrays whose live words the
        caller copies through the same traversal's W port, or None when no
        copy is needed. The shared page itself is never written again:
        sharing is read-only by construction, which is exactly the
        write-private contract the scheduler's hazard analysis assumes."""
        if new_tokens <= 0:
            return None
        length = self.lengths.get(seq, 0)
        off = length % self.page_tokens
        idx = length // self.page_tokens
        table = self.tables.get(seq, [])
        if not off or idx >= len(table):
            return None
        old = table[idx]
        if self.refcounts.get(old, 1) <= 1:
            return None
        shard = self.assign_home(seq)
        free = self.free_by_shard[shard]
        if not free:
            raise PoolCapacityError(
                f"seq {seq}: copy-on-write of shared page {old} needs a "
                f"fresh page on home shard {shard} but its free list is "
                f"empty — the capacity checks should have counted "
                f"pending_cow_pages")
        fresh = free.pop(0)
        self.refcounts[old] -= 1
        self.refcounts[fresh] = 1
        table[idx] = fresh
        self.cow_copies += 1
        self.cow_words += off
        words = np.arange(off)
        return (old * self.page_tokens + words,
                fresh * self.page_tokens + words)

    # ---- footprint projection (scheduler support) ----------------------------
    def mapped_pages(self, seq: int) -> tuple:
        """The pages a sequence currently owns (empty before admission)."""
        return tuple(self.tables.get(seq, ()))

    def project_write_pages(self, demands: Sequence[tuple]) -> list:
        """Non-mutating page-footprint projection for ordered write demands.

        ``demands`` is ``[(seq, n_tokens), ...]`` in the order the commit
        path will service them (prefills before appends, stream order within
        each — the same order :meth:`cycle` grows tables in). Returns one
        ``frozenset`` of touched page ids per demand: the partially-filled
        tail page plus any pages the demand would pop from the sequence's
        home-shard free list (simulated against a copy, so table, length and
        free-list state are untouched). Exact because eviction's
        :meth:`free` has already run by the time the scheduler projects —
        the free lists the simulation copies are the ones the commit pops
        from. A demand that would exhaust its simulated free list stops
        popping (the real commit's capacity precheck raises first, before
        any traversal issues).

        Share-aware: a demand whose tail page is SHARED (refcount > 1)
        projects the fresh page its copy-on-write will carve — from the
        FRONT of the free list, mirroring :meth:`_cow_prepare` — and NOT
        the shared page, so the scheduler sees the PHYSICAL write
        footprint: shared pages are read-shared/write-private, and their
        readers co-schedule with the CoW writer hazard-free."""
        sim_free = [list(fl) for fl in self.free_by_shard]
        sim_table: dict = {}
        sim_len: dict = {}
        out = []
        for seq, t in demands:
            table = sim_table.setdefault(seq, list(self.tables.get(seq, ())))
            length = sim_len.setdefault(seq, self.lengths.get(seq, 0))
            # idempotent: the engine pre-assigns homes at admission, so this
            # only reads (and matches the shard the commit path will pop)
            shard = self.assign_home(seq)
            pages = set()
            off = length % self.page_tokens
            idx = length // self.page_tokens
            if (t and off and idx < len(table)
                    and self.refcounts.get(table[idx], 1) > 1
                    and sim_free[shard]):
                p = sim_free[shard].pop(0)
                table[idx] = p
                pages.add(p)
            need = -(-(length + t) // self.page_tokens)
            while len(table) < need and sim_free[shard]:
                p = sim_free[shard].pop()
                table.append(p)
                pages.add(p)
            lo = length // self.page_tokens
            hi = min(need, len(table))
            pages.update(table[lo:hi])
            sim_len[seq] = length + t
            out.append(frozenset(pages))
        return out

    # ---- data plane: one macro-cycle -----------------------------------------
    def cycle(self, *, append: Stream = None, read: Stream = None,
              prefill: Stream = None,
              scrub: Optional[Sequence[int]] = None,
              priority: Optional[Sequence[int]] = None) -> dict:
        """Service up to four logical streams in ONE pool traversal.

        append:  {"seq": int, "vectors": [T, W]} or list — decode appends
        read:    {"seq": int, "positions": int array} or list — attn gathers
        prefill: {"seq": int, "vectors": [T, W]} or list — bulk prompt fills
        scrub:   page ids to zero (port D — eviction)
        priority: full port-priority permutation for THIS traversal (the
                  schedule's per-cycle decision); defaults to the legacy
                  fixed service order ``_PRIORITY``.
        Returns {"read": [Q, W] | list thereof | None} mirroring the input
        shape of ``read``.

        Sharded pools (a real mesh) run the traversal under ``shard_map``:
        every shard concurrently services its own address range and read
        lanes psum — still ONE traversal of (now distributed) storage.
        """
        read_was_dict = isinstance(read, dict)
        appends = self._as_streams(append)
        reads = self._as_streams(read)
        prefills = self._as_streams(prefill)
        scrub = list(scrub) if scrub else []
        priority = _PRIORITY if priority is None else tuple(priority)

        # program order: bulk prefills grow tables before decode appends,
        # matching the scheduler's footprint projection
        self._check_capacity(prefills + appends, reads)

        # copy-on-write remaps commit here (prefills before appends, the
        # projection's order): each shared tail page a write stream would
        # touch is replaced by a fresh home-shard page whose live words
        # ride the SAME traversal's W port as extra lanes
        cow_fill = [c for c in (self._cow_prepare(s["seq"],
                                                  int(s["vectors"].shape[0]))
                                for s in prefills) if c is not None]
        cow_app = [c for c in (self._cow_prepare(s["seq"],
                                                 int(s["vectors"].shape[0]))
                               for s in appends) if c is not None]

        lanes = [0, 0, 0, 0]
        lanes[APPEND] = (sum(s["vectors"].shape[0] for s in appends)
                         + sum(len(o) for o, _ in cow_app))
        lanes[ATTN_READ] = sum(len(s["positions"]) for s in reads)
        lanes[BULK_FILL] = (sum(s["vectors"].shape[0] for s in prefills)
                            + sum(len(o) for o, _ in cow_fill))
        lanes[SCRUB] = len(scrub) * self.page_tokens
        if not any(lanes):
            # no traffic: still mirror the read input shape (one result per
            # stream) so stream->result pairing survives empty gathers
            if not reads:
                return {"read": None}
            empty = jnp.zeros((0, self.io_width), self.spec.dtype)
            return {"read": empty if read_was_dict
                    else [empty for _ in reads]}
        q = _bucket(max(lanes))

        reqs = [empty_request(q, self.spec.word_width, self.spec.dtype)
                for _ in range(4)]
        w_tiles: set = set()               # distinct W-port tiles this cycle
        r_tiles: set = set()               # distinct R-port tiles this cycle

        def _write_req(streams, cow=()):
            addr = np.zeros(q, np.int32)
            data = np.zeros((q, self.spec.word_width), np.float32)
            mask = np.zeros(q, bool)
            at = 0
            for old, new in cow:
                # CoW copy lanes: the shared page's live words, gathered
                # host-side (it cannot be a ported read — the copy must
                # land in the same traversal), written to the fresh page.
                # Disjoint from the stream's own words (those start at the
                # copied offset), so lane order never matters.
                vals = np.asarray(self.storage[jnp.asarray(old)],
                                  np.float32)
                n = len(new)
                addr[at:at + n] = new
                data[at:at + n] = vals
                mask[at:at + n] = True
                at += n
            for s in streams:
                seq, vec = s["seq"], np.asarray(s["vectors"], np.float32)
                t = vec.shape[0]
                self._ensure_capacity(seq, t)
                idx = np.arange(self.lengths[seq], self.lengths[seq] + t)
                addr[at:at + t] = self._addr(seq, idx)
                data[at:at + t, :vec.shape[1]] = vec    # pad lanes stay zero
                mask[at:at + t] = True
                self.lengths[seq] += t
                at += t
            w_tiles.update(np.unique(addr[:at] // self.seq_tile).tolist())
            return PortRequest(addr=jnp.asarray(addr),
                               data=jnp.asarray(data, self.spec.dtype),
                               mask=jnp.asarray(mask))

        if prefills:
            reqs[BULK_FILL] = _write_req(prefills, cow_fill)
        if appends:
            reqs[APPEND] = _write_req(appends, cow_app)
        if scrub:
            addr = np.zeros(q, np.int32)
            mask = np.zeros(q, bool)
            words = (np.asarray(scrub)[:, None] * self.page_tokens
                     + np.arange(self.page_tokens)[None, :]).reshape(-1)
            addr[: len(words)] = words
            mask[: len(words)] = True
            w_tiles.update(np.unique(words // self.seq_tile).tolist())
            reqs[SCRUB] = PortRequest(
                addr=jnp.asarray(addr),
                data=jnp.zeros((q, self.spec.word_width), self.spec.dtype),
                mask=jnp.asarray(mask))
        slices = []
        if reads:
            addr = np.zeros(q, np.int32)
            mask = np.zeros(q, bool)
            at = 0
            for s in reads:
                pos = np.asarray(s["positions"])
                addr[at:at + len(pos)] = self._addr(s["seq"], pos)
                mask[at:at + len(pos)] = True
                slices.append((at, at + len(pos)))
                at += len(pos)
            r_tiles.update(np.unique(addr[:at] // self.seq_tile).tolist())
            reqs[ATTN_READ] = PortRequest(
                addr=jnp.asarray(addr),
                data=jnp.zeros((q, self.spec.word_width), self.spec.dtype),
                mask=jnp.asarray(mask))

        cfg = PortConfig(enabled=(bool(appends), bool(reads), bool(prefills),
                                  bool(scrub)),
                         roles=_ROLES, priority=priority)
        self.mix_counts[cfg.describe()] = self.mix_counts.get(
            cfg.describe(), 0) + 1
        if self.mesh is not None and self.kv_shards > 1:
            fn = _sharded_pool_step(self.spec_local, cfg, self.mesh,
                                    self.kv_axis, self.words_per_shard,
                                    self.use_kernel, self.interpret)
            self.storage, out = fn(self.storage, tuple(reqs))
        else:
            self.storage, out = _pool_step(self.spec, cfg, self.storage,
                                           tuple(reqs),
                                           use_kernel=self.use_kernel,
                                           interpret=self.interpret)
        self.traversals += 1
        self.tile_writes += self._count_tiles(w_tiles,
                                              self.tile_writes_by_shard)
        self.tile_reads += self._count_tiles(r_tiles,
                                             self.tile_reads_by_shard)
        if not reads:
            return {"read": None}
        got = [out[ATTN_READ][a:b, :self.io_width] for a, b in slices]
        return {"read": got[0] if read_was_dict else got}

    def cycle_batch(self, groups: Sequence[tuple]) -> list:
        """Issue one macro-cycle's SCHEDULE of traversals: ``groups`` is an
        ordered sequence of ``(streams, priority)`` pairs — each ``streams``
        a dict of :meth:`cycle` keyword streams, each ``priority`` that
        traversal's full port permutation (or None for the legacy order).

        The capacity/read precheck is TRANSACTIONAL ACROSS THE WHOLE BATCH:
        every co-scheduled write (prefills then appends, group order) and
        every read is validated against simulated free lists BEFORE the
        first traversal commits, so a refused macro-cycle leaves the pool
        untouched even when the failing demand sits in a later traversal.
        The traversals then issue through :func:`repro.core.fsm.walk_schedule`
        — the schedule-driven generalization of the old fixed walk — each
        with its own :class:`~repro.core.PortConfig`. Returns one
        :meth:`cycle` result dict per group, in order."""
        from repro.core import fsm

        groups = [(dict(streams), None if prio is None else tuple(prio))
                  for streams, prio in groups]
        writes: list = []
        reads: list = []
        for streams, _ in groups:
            writes += self._as_streams(streams.get("prefill"))
            writes += self._as_streams(streams.get("append"))
            reads += self._as_streams(streams.get("read"))
        if not groups:
            return []
        self._check_capacity(writes, reads)

        schedule = []
        for streams, prio in groups:
            cfg = PortConfig(
                enabled=(bool(streams.get("append")),
                         bool(streams.get("read")),
                         bool(streams.get("prefill")),
                         bool(streams.get("scrub"))),
                roles=_ROLES,
                priority=_PRIORITY if prio is None else prio)
            schedule.append((cfg, streams))

        def service(outs, streams, cfg):
            outs.append(self.cycle(priority=cfg.priority, **streams))
            return outs

        return fsm.walk_schedule(schedule, [], service)

    @staticmethod
    def _as_streams(stream: Stream) -> list:
        if stream is None:
            return []
        if isinstance(stream, dict):
            return [stream]
        return list(stream)

    @property
    def utilization(self) -> float:
        total = self.spec.num_words // self.page_tokens
        return 1.0 - self.free_page_count / total
