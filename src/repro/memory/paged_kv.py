"""Paged KV cache on the multi-port memory — the paper's technique as the
serving memory manager.

The physical pool is ONE word-addressable MultiPortMemory (a word = one
token's full KV footprint: K and V vectors for every layer); sequences own
pages of ``page_tokens`` words through a page table, exactly like vLLM's
paged attention — except the pool is accessed through the paper's
configurable ports:

    port A (W): decode append     — one word per active sequence
    port B (R): attention reads   — gathers of page-resident words
    port C (W): prefill bulk fill — admitted prompts' pages in one shot
    port D (W): eviction scrub    — freed pages zeroed

One :meth:`cycle` call is ONE physical traversal of the pool servicing every
enabled port, in the engine's FSM order (priority ``A > D > C > B``): decode
appends land first, eviction scrubs reclaim pages before bulk prefill can
reuse them, and attention reads observe everything written earlier in the
same macro-cycle (the paper's same-cycle W->R visibility). ``traversals``
counts physical traversals — the serving engine benchmark divides it by
generated tokens to measure claim C1 at the system level. ``tile_reads`` /
``tile_writes`` additionally count the DISTINCT ``seq_tile``-word tiles each
traversal actually touches per port role, so a traversal over a short live
sequence is visibly cheaper than one over a full-capacity sequence — the
length-bounded-traversal discipline measured at the pool level.

Each port stream accepts a single ``{"seq": ...}`` dict or a LIST of them
(multi-sequence transactions): the pool packs all streams of a port into one
vectorized request queue, so e.g. every active slot's decode append is one
port-A transaction.

``use_kernel=True`` backs the data plane with ``core.step_banked`` (the
Pallas one-traversal kernel; ``interpret=`` executes it in Python on CPU
CI), ``use_kernel=False`` keeps the jnp oracle ``core.step``. The page-table
bookkeeping stays host-side (python ints — it is control plane, like the
engine's scheduler).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MemorySpec, PortConfig, READ, WRITE, PortRequest,
                        empty_request, step, step_banked)
from repro.kernels.tiling import word_pad

# pool port indices
APPEND, ATTN_READ, BULK_FILL, SCRUB = 0, 1, 2, 3
# service order: appends > scrubs > bulk fills > reads (see module docstring)
_PRIORITY = (APPEND, SCRUB, BULK_FILL, ATTN_READ)
_ROLES = (WRITE, READ, WRITE, WRITE)

Stream = Union[dict, Sequence[dict], None]


class PoolCapacityError(MemoryError):
    """An admission's page demand exceeds the pool's free page supply.

    Raised BEFORE any page-table or length mutation: a failed transaction
    leaves the pool exactly as it was, so the scheduler can retry the
    admission after evictions free pages."""


def _bucket(n: int, lo: int = 8) -> int:
    """Round a queue length up to a power of two (jit shape reuse)."""
    b = lo
    while b < n:
        b *= 2
    return b


def seq_tile_buckets(max_len: int, seq_tile: int) -> tuple[int, ...]:
    """The staging-cache lengths the engine's length-bounded dispatch can
    stage (and so the shapes its jitted decode / prefill-chunk steps retrace
    at): power-of-two counts of ``seq_tile`` tiles, the last PADDED up to
    ``ceil(max_len / seq_tile) * seq_tile`` so every staged length is a
    whole number of tiles (the kernels never fall back to degenerate
    tile-1 grids for awkward capacities).

    The single source of truth for the ladder: the engine's ``_stage_len``
    walks it and ``launch/serve.py`` validates ``--seq-tile`` against it at
    startup. Raises ValueError when ``seq_tile`` cannot tile a ``max_len``
    cache.
    """
    if seq_tile < 1:
        raise ValueError(f"seq_tile must be >= 1, got {seq_tile}")
    if seq_tile > max_len:
        raise ValueError(
            f"seq_tile ({seq_tile}) exceeds the model's S_max ({max_len}); "
            f"the smallest live bucket would overrun the cache")
    cap = -(-max_len // seq_tile) * seq_tile       # padded full capacity
    lens = []
    n = 1
    while n * seq_tile < cap:
        lens.append(n * seq_tile)
        n *= 2
    lens.append(cap)
    return tuple(lens)


@functools.partial(jax.jit, static_argnames=("spec", "config", "use_kernel",
                                             "interpret"))
def _pool_step(spec, config, storage, requests, *, use_kernel: bool,
               interpret: bool):
    if use_kernel:
        return step_banked(spec, config, storage, requests,
                           interpret=interpret)
    return step(spec, config, storage, requests)


@dataclasses.dataclass
class PagedPool:
    """Physical pool + free list + per-sequence page tables."""

    spec: MemorySpec
    page_tokens: int
    storage: jax.Array
    free_pages: list
    tables: dict                       # seq_id -> list[page_id]
    lengths: dict                      # seq_id -> tokens stored
    use_kernel: bool = False
    interpret: bool = True
    traversals: int = 0                # physical pool traversals serviced
    seq_tile: int = 0                  # words per accounting tile
    tile_reads: int = 0                # distinct R-port tiles touched
    tile_writes: int = 0               # distinct W-port tiles touched
    io_width: int = 0                  # caller-visible word width (the
                                       # storage word is lane-padded past it)

    @classmethod
    def create(cls, *, n_pages: int, page_tokens: int, word_width: int,
               dtype=jnp.float32, num_banks: int = 8,
               use_kernel: bool = False, interpret: bool = True,
               seq_tile: int = 0) -> "PagedPool":
        num_words = n_pages * page_tokens
        while num_words % num_banks:
            num_banks //= 2                       # geometry guard
        # Mosaic lane alignment: the STORAGE word is padded to a whole lane
        # count (word_pad) so the banked kernel's [wpb, W] tiles keep a
        # 128-multiple minor dim at CI's small word widths too; callers keep
        # reading/writing ``word_width``-wide vectors (the pad lanes are
        # zero and cropped on the way out)
        spec = MemorySpec(num_words=num_words,
                          word_width=word_pad(word_width), dtype=dtype,
                          num_banks=max(num_banks, 1))
        return cls(spec=spec, page_tokens=page_tokens,
                   storage=spec.init_storage(),
                   free_pages=list(range(n_pages)), tables={}, lengths={},
                   use_kernel=use_kernel, interpret=interpret,
                   seq_tile=seq_tile or page_tokens, io_width=word_width)

    # ---- control plane ------------------------------------------------------
    def _ensure_capacity(self, seq: int, new_tokens: int) -> None:
        table = self.tables.setdefault(seq, [])
        self.lengths.setdefault(seq, 0)
        need = -(-(self.lengths[seq] + new_tokens) // self.page_tokens)
        while len(table) < need:
            if not self.free_pages:
                raise PoolCapacityError(
                    f"seq {seq}: growing to {self.lengths[seq] + new_tokens} "
                    f"tokens needs {need} pages but only {len(table)} are "
                    f"mapped and the free list is empty")
            table.append(self.free_pages.pop())

    def _check_capacity(self, write_streams: Sequence[dict],
                        read_streams: Sequence[dict]) -> None:
        """Transactional admission check, run BEFORE any table mutation:
        the cycle's total page demand must fit the free list, and every read
        position must fall inside the words its sequence will have mapped
        once this cycle's writes land (reads are serviced after writes, so
        same-cycle append+read of a fresh page is legal)."""
        demand: dict = {}
        for s in write_streams:
            seq = s["seq"]
            demand[seq] = demand.get(seq, 0) + int(s["vectors"].shape[0])
        need = 0
        projected = {}
        for seq, new_tokens in demand.items():
            held = len(self.tables.get(seq, []))
            pages = max(held,
                        -(-(self.lengths.get(seq, 0) + new_tokens)
                          // self.page_tokens))
            projected[seq] = pages
            need += pages - held
        if need > len(self.free_pages):
            raise PoolCapacityError(
                f"admission of {sum(demand.values())} tokens across "
                f"{len(demand)} sequence(s) needs {need} new pages but only "
                f"{len(self.free_pages)} of {self.spec.num_words // self.page_tokens} "
                f"are free — evict sequences or raise the pool size")
        for s in read_streams:
            seq = s["seq"]
            pages = projected.get(seq, len(self.tables.get(seq, [])))
            pos = np.asarray(s["positions"])
            if not pages:
                raise IndexError(f"seq {seq} has no pages mapped")
            if pos.size and (pos.min() < 0
                             or pos.max() >= pages * self.page_tokens):
                raise IndexError(
                    f"seq {seq}: positions [{pos.min()}, {pos.max()}] outside "
                    f"the {pages * self.page_tokens} words its page table "
                    f"maps this cycle")

    def _addr(self, seq: int, token_idx: np.ndarray) -> np.ndarray:
        table = self.tables.get(seq)
        if not table:
            raise IndexError(f"seq {seq} has no pages mapped")
        token_idx = np.asarray(token_idx)
        mapped = len(table) * self.page_tokens
        if token_idx.size and (token_idx.min() < 0
                               or token_idx.max() >= mapped):
            raise IndexError(
                f"seq {seq}: positions [{token_idx.min()}, {token_idx.max()}]"
                f" outside the {mapped} words mapped by its page table")
        table = np.asarray(table)
        return (table[token_idx // self.page_tokens] * self.page_tokens
                + token_idx % self.page_tokens)

    def free(self, seq: int) -> list:
        """Release a sequence's pages; returns the freed page ids (so the
        caller can scrub them through port D in the same macro-cycle)."""
        pages = self.tables.pop(seq, [])
        self.free_pages.extend(pages)
        self.lengths.pop(seq, None)
        return pages

    # ---- data plane: one macro-cycle -----------------------------------------
    def cycle(self, *, append: Stream = None, read: Stream = None,
              prefill: Stream = None,
              scrub: Optional[Sequence[int]] = None) -> dict:
        """Service up to four logical streams in ONE pool traversal.

        append:  {"seq": int, "vectors": [T, W]} or list — decode appends
        read:    {"seq": int, "positions": int array} or list — attn gathers
        prefill: {"seq": int, "vectors": [T, W]} or list — bulk prompt fills
        scrub:   page ids to zero (port D — eviction)
        Returns {"read": [Q, W] | list thereof | None} mirroring the input
        shape of ``read``.
        """
        read_was_dict = isinstance(read, dict)
        appends = self._as_streams(append)
        reads = self._as_streams(read)
        prefills = self._as_streams(prefill)
        scrub = list(scrub) if scrub else []

        self._check_capacity(appends + prefills, reads)

        lanes = [0, 0, 0, 0]
        lanes[APPEND] = sum(s["vectors"].shape[0] for s in appends)
        lanes[ATTN_READ] = sum(len(s["positions"]) for s in reads)
        lanes[BULK_FILL] = sum(s["vectors"].shape[0] for s in prefills)
        lanes[SCRUB] = len(scrub) * self.page_tokens
        if not any(lanes):
            # no traffic: still mirror the read input shape (one result per
            # stream) so stream->result pairing survives empty gathers
            if not reads:
                return {"read": None}
            empty = jnp.zeros((0, self.io_width), self.spec.dtype)
            return {"read": empty if read_was_dict
                    else [empty for _ in reads]}
        q = _bucket(max(lanes))

        reqs = [empty_request(q, self.spec.word_width, self.spec.dtype)
                for _ in range(4)]
        w_tiles: set = set()               # distinct W-port tiles this cycle
        r_tiles: set = set()               # distinct R-port tiles this cycle

        def _write_req(streams):
            addr = np.zeros(q, np.int32)
            data = np.zeros((q, self.spec.word_width), np.float32)
            mask = np.zeros(q, bool)
            at = 0
            for s in streams:
                seq, vec = s["seq"], np.asarray(s["vectors"], np.float32)
                t = vec.shape[0]
                self._ensure_capacity(seq, t)
                idx = np.arange(self.lengths[seq], self.lengths[seq] + t)
                addr[at:at + t] = self._addr(seq, idx)
                data[at:at + t, :vec.shape[1]] = vec    # pad lanes stay zero
                mask[at:at + t] = True
                self.lengths[seq] += t
                at += t
            w_tiles.update(np.unique(addr[:at] // self.seq_tile).tolist())
            return PortRequest(addr=jnp.asarray(addr),
                               data=jnp.asarray(data, self.spec.dtype),
                               mask=jnp.asarray(mask))

        if appends:
            reqs[APPEND] = _write_req(appends)
        if prefills:
            reqs[BULK_FILL] = _write_req(prefills)
        if scrub:
            addr = np.zeros(q, np.int32)
            mask = np.zeros(q, bool)
            words = (np.asarray(scrub)[:, None] * self.page_tokens
                     + np.arange(self.page_tokens)[None, :]).reshape(-1)
            addr[: len(words)] = words
            mask[: len(words)] = True
            w_tiles.update(np.unique(words // self.seq_tile).tolist())
            reqs[SCRUB] = PortRequest(
                addr=jnp.asarray(addr),
                data=jnp.zeros((q, self.spec.word_width), self.spec.dtype),
                mask=jnp.asarray(mask))
        slices = []
        if reads:
            addr = np.zeros(q, np.int32)
            mask = np.zeros(q, bool)
            at = 0
            for s in reads:
                pos = np.asarray(s["positions"])
                addr[at:at + len(pos)] = self._addr(s["seq"], pos)
                mask[at:at + len(pos)] = True
                slices.append((at, at + len(pos)))
                at += len(pos)
            r_tiles.update(np.unique(addr[:at] // self.seq_tile).tolist())
            reqs[ATTN_READ] = PortRequest(
                addr=jnp.asarray(addr),
                data=jnp.zeros((q, self.spec.word_width), self.spec.dtype),
                mask=jnp.asarray(mask))

        cfg = PortConfig(enabled=(bool(appends), bool(reads), bool(prefills),
                                  bool(scrub)),
                         roles=_ROLES, priority=_PRIORITY)
        self.storage, out = _pool_step(self.spec, cfg, self.storage,
                                       tuple(reqs),
                                       use_kernel=self.use_kernel,
                                       interpret=self.interpret)
        self.traversals += 1
        self.tile_writes += len(w_tiles)
        self.tile_reads += len(r_tiles)
        if not reads:
            return {"read": None}
        got = [out[ATTN_READ][a:b, :self.io_width] for a, b in slices]
        return {"read": got[0] if read_was_dict else got}

    @staticmethod
    def _as_streams(stream: Stream) -> list:
        if stream is None:
            return []
        if isinstance(stream, dict):
            return [stream]
        return list(stream)

    @property
    def utilization(self) -> float:
        total = self.spec.num_words // self.page_tokens
        return 1.0 - len(self.free_pages) / total
