"""repro.train subpackage."""
