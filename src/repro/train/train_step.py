"""Train step factory: loss -> grads -> (compress) -> clip -> optimizer.

Features wired here:
  * gradient accumulation (``microbatches``) via lax.scan — each microbatch's
    backward overlaps the next microbatch's collectives on TPU (XLA async);
  * optional cross-pod int8 error-feedback gradient compression
    (distributed/compression.py): per-pod grads via vmap(grad) over a
    pod-sharded leading axis;
  * optimizer selection (adamw / adamw8bit / adafactor);
  * donation-friendly: call via jit(..., donate_argnums=0).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import compression as C
from repro.models import loss_fn
from repro.optim import AdamWConfig, make_optimizer, warmup_cosine

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"
    adamw: AdamWConfig = AdamWConfig()
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1
    grad_compression: Optional[str] = None      # None | "int8_ef"
    n_pods: int = 1


def init_train_state(params: PyTree, tcfg: TrainConfig) -> dict:
    opt_init, _, _ = make_optimizer(tcfg.optimizer, tcfg.adamw)
    state = {"params": params, "opt": opt_init(params)}
    if tcfg.grad_compression == "int8_ef":
        state["ef"] = C.init_ef_state(params, tcfg.n_pods)
    return state


def _split_micro(batch: dict, m: int) -> dict:
    return {k: v.reshape(m, v.shape[0] // m, *v.shape[1:])
            for k, v in batch.items()}


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig) -> Callable:
    """Returns step(state, batch) -> (state', metrics)."""

    def loss(p, b):
        l, metrics = loss_fn(p, cfg, b)
        return l, metrics

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def compute_grads(params, batch):
        if tcfg.grad_compression == "int8_ef":
            # per-pod gradients: [n_pods, local, ...] batch, vmapped grad
            pb = _split_micro(batch, tcfg.n_pods)
            (l, metrics), grads = jax.vmap(grad_fn, in_axes=(None, 0))(params, pb)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(0), metrics)
            return grads, metrics           # leaves [n_pods, ...]
        if tcfg.microbatches > 1:
            mb = _split_micro(batch, tcfg.microbatches)

            def body(acc, b):
                (l, metrics), g = grad_fn(params, b)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, metrics
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(body, zero, mb)
            grads = jax.tree_util.tree_map(
                lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda x: x.mean(0), metrics)
            return grads, metrics
        (l, metrics), grads = grad_fn(params, batch)
        return grads, metrics

    _, opt_update, _ = make_optimizer(tcfg.optimizer, tcfg.adamw)

    def step(state: dict, batch: dict) -> tuple[dict, dict]:
        params = state["params"]
        grads, metrics = compute_grads(params, batch)
        new_state = dict(state)
        if tcfg.grad_compression == "int8_ef":
            grads, new_ef = C.compressed_mean_tree(grads, state["ef"])
            new_state["ef"] = new_ef
        lr = warmup_cosine(state["opt"]["step"], peak_lr=tcfg.peak_lr,
                           warmup_steps=tcfg.warmup_steps,
                           total_steps=tcfg.total_steps)
        new_params, new_opt, opt_stats = opt_update(grads, state["opt"],
                                                    params, lr)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = dict(metrics, lr=lr, **opt_stats)
        return new_state, metrics

    return step
