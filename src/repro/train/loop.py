"""Training runner: checkpointed, heartbeat-monitored, straggler-aware,
restartable loop around a jitted train step.

Restart semantics: on any failure the runner restores the latest checkpoint
and resumes from its step. Because batches are pure functions of the step
index, a restarted run consumes exactly the data it would have — no loader
state to recover (tests/train/test_restart.py asserts bit-identical losses).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint import ckpt
from repro.distributed.fault import (FailureInjector, Heartbeat,
                                     InjectedFailure, StragglerDetector)

PyTree = Any


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    keep_last: int = 3
    max_restarts: int = 3
    async_ckpt: bool = True
    heartbeat_dir: Optional[str] = None
    worker: str = "w0"


class TrainingRunner:
    def __init__(self, step_fn: Callable, init_state: PyTree,
                 get_batch: Callable[[int], dict], rcfg: RunnerConfig,
                 *, injector: Optional[FailureInjector] = None,
                 straggler: Optional[StragglerDetector] = None):
        self.step_fn = step_fn
        self.init_state = init_state
        self.get_batch = get_batch
        self.rcfg = rcfg
        self.injector = injector
        self.straggler = straggler or StragglerDetector()
        self.saver = ckpt.AsyncSaver() if rcfg.async_ckpt else None
        self.heartbeat = (Heartbeat(rcfg.heartbeat_dir, rcfg.worker)
                          if rcfg.heartbeat_dir else None)
        self.history: list[dict] = []
        self.restarts = 0

    # -- checkpoint plumbing -------------------------------------------------
    def _save(self, step: int, state: PyTree) -> None:
        if self.saver is not None:
            self.saver.save(self.rcfg.ckpt_dir, step, state,
                            keep_last=self.rcfg.keep_last)
        else:
            ckpt.save(self.rcfg.ckpt_dir, step, state,
                      keep_last=self.rcfg.keep_last)

    def _restore_or_init(self) -> tuple[PyTree, int]:
        last = ckpt.latest_step(self.rcfg.ckpt_dir)
        if last is None:
            return self.init_state, 0
        state, manifest = ckpt.restore(self.rcfg.ckpt_dir, self.init_state,
                                       step=last)
        return state, manifest["step"] + 1

    # -- main loop -----------------------------------------------------------
    def run(self, n_steps: int) -> PyTree:
        state, start = self._restore_or_init()
        step = start
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                batch = self.get_batch(step)
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics)
                dt = time.perf_counter() - t0

                if self.heartbeat:
                    self.heartbeat.beat(step)
                lagging = self.straggler.record(step, dt)
                self.history.append(
                    {"step": step, "dt": dt, "straggler": lagging,
                     **{k: float(v) for k, v in metrics.items()}})
                if step % self.rcfg.ckpt_every == 0:
                    self._save(step, state)
                step += 1
            except InjectedFailure:
                self.restarts += 1
                if self.restarts > self.rcfg.max_restarts:
                    raise
                if self.saver is not None:
                    self.saver.wait()
                state, step = self._restore_or_init()
        if self.saver is not None:
            self._save(n_steps - 1, state)
            self.saver.wait()
        else:
            self._save(n_steps - 1, state)
        return state
