"""repro: configurable multi-port memory architecture for TPU-native JAX systems.

Reproduction + beyond-paper optimization of:
  "Configurable Multi-Port Memory Architecture for High-Speed Data Communication"
  (Dhakad & Vishvakarma, 2024).

The paper's circuit-level insight -- virtualize one physical access channel into N
configurable logical ports by priority-ordered time multiplexing -- is adapted to the
TPU memory hierarchy: one HBM<->VMEM tile traversal services N logical port queues.
"""

__version__ = "0.1.0"
