"""Deterministic synthetic data pipeline, sharded across the mesh.

Design for fault tolerance: a batch is a PURE FUNCTION of (seed, step) — no
iterator state to checkpoint, and a restarted (or elastically re-sized) job
regenerates exactly the token stream it would have seen. Straggler-mitigation
hooks live at this level too (see distributed/fault.py): a replica that
misses the step deadline can be served the next step's batch without
coordination, because batches are addressable by step.

Two tasks:
  * "chain":  x_{t+1} = (a * x_t + b) mod V with per-sequence (a, b) —
              learnable structure (loss visibly decreases within ~100 steps).
  * "uniform": i.i.d. tokens — throughput benchmarking only.

For embeddings-mode architectures (vlm/audio) the stub frontend maps token
ids through a FIXED random projection table (not trained — it stands in for
the modality encoder).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    task: str = "chain"
    # stub-frontend projection table size (embeddings mode)
    frontend_vocab: int = 4096


def _chain_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    # small multiplier pool: keeps the chain structure inferable from a short
    # context, so loss drops within the convergence tests' 40-step budget
    a = rng.integers(1, min(vocab, 17), (batch, 1))
    b = rng.integers(0, vocab, (batch, 1))
    x0 = rng.integers(0, vocab, (batch, 1))
    toks = np.empty((batch, seq + 1), np.int32)
    toks[:, :1] = x0
    for t in range(seq):
        toks[:, t + 1] = (toks[:, t] * a[:, 0] + b[:, 0]) % vocab
    return toks


def make_batch(cfg: ArchConfig, data_cfg: DataConfig, step: int,
               batch: int, seq: int) -> dict:
    """Host-side numpy batch for (step); deterministic."""
    rng = np.random.default_rng(
        np.random.SeedSequence([data_cfg.seed, step, 0xC0FFEE]))
    if data_cfg.task == "chain":
        toks = _chain_batch(rng, batch, seq, cfg.vocab)
    else:
        toks = rng.integers(0, cfg.vocab, (batch, seq + 1)).astype(np.int32)
    inputs_ids, labels = toks[:, :-1], toks[:, 1:].astype(np.int32)

    out = {"labels": labels}
    if cfg.input_mode == "tokens":
        out["inputs"] = inputs_ids
    else:
        # stub frontend: fixed random projection of ids -> embeddings
        table = _frontend_table(cfg, data_cfg)
        out["inputs"] = table[inputs_ids % table.shape[0]]
    if cfg.pos_embed == "mrope":
        pos = np.broadcast_to(np.arange(seq)[None, :, None],
                              (batch, seq, 3)).astype(np.int32)
        out["positions"] = np.ascontiguousarray(pos)
    return out


_FRONTEND_CACHE: dict = {}


def _frontend_table(cfg: ArchConfig, data_cfg: DataConfig) -> np.ndarray:
    key = (cfg.arch_id, cfg.d_model, data_cfg.frontend_vocab)
    if key not in _FRONTEND_CACHE:
        rng = np.random.default_rng(np.random.SeedSequence([data_cfg.seed, 7]))
        _FRONTEND_CACHE[key] = (rng.standard_normal(
            (data_cfg.frontend_vocab, cfg.d_model)) / np.sqrt(cfg.d_model)
        ).astype(np.float32)
    return _FRONTEND_CACHE[key]


class ShardedLoader:
    """Places (seed, step)-addressable batches onto the mesh."""

    def __init__(self, cfg: ArchConfig, data_cfg: DataConfig, *, batch: int,
                 seq: int, shardings: Optional[dict] = None):
        self.cfg, self.data_cfg = cfg, data_cfg
        self.batch, self.seq = batch, seq
        self.shardings = shardings

    def get(self, step: int) -> dict:
        host = make_batch(self.cfg, self.data_cfg, step, self.batch, self.seq)
        if self.shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {k: jax.device_put(v, self.shardings[k]) for k, v in host.items()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get(step)
            step += 1
