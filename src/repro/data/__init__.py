"""repro.data subpackage."""
