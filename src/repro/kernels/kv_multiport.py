"""Pallas TPU kernel: fused decode append+attend over a multi-port KV cache.

The end-to-end carrier of the paper's claim C1 in the serving path. Decoding
one token conventionally costs TWO full traversals of the sequence-length KV
cache tiles:

  pass 1 (write port): scatter-append the new token's K,V at ``cache_len``;
  pass 2 (read port):  gather + attention over positions [0, cache_len].

This kernel configures the cache as a 2-port memory (1W + 1R per the paper's
"any R/W combination") and services both ports in ONE traversal: while each
KV tile is VMEM-resident, the tile containing ``cache_len`` takes the append
(W slot, higher priority) and every tile feeds the online-softmax attention
accumulation (R slot) — W-before-R visibility exactly as the wrapper's FSM
orders same-cycle traffic, so attention sees the just-appended token.

Grid: (batch, seq_tiles); accumulators in VMEM scratch, persisted across the
inner (seq_tiles) grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota(n: int, dtype=jnp.int32) -> jax.Array:
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


def _kernel(len_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
            out_k_ref, out_v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, seq_tile: int, n_tiles: int, scale: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    p = len_ref[0, 0]                                     # append position
    tile_start = t * seq_tile
    pos = tile_start + _iota(seq_tile)                    # global positions [T]

    k_tile = k_ref[0]                                     # [T, Hkv, D]
    v_tile = v_ref[0]

    # --- W slot (priority A): append new token if it lands in this tile -----
    hit = (pos == p)                                      # [T]
    k_tile = jnp.where(hit[:, None, None], new_k_ref[0][None], k_tile)
    v_tile = jnp.where(hit[:, None, None], new_v_ref[0][None], v_tile)
    out_k_ref[0] = k_tile                                 # write-through (aliased)
    out_v_ref[0] = v_tile

    # --- R slot (priority B): attention over valid positions (<= p) ---------
    q = q_ref[0]                                          # [Hkv, G, D]
    f32 = jnp.float32
    s = jnp.einsum("hgd,thd->hgt", q.astype(f32), k_tile.astype(f32)) * scale
    valid = (pos <= p)[None, None, :]                     # new token included
    s = jnp.where(valid, s, -jnp.inf)

    m_prev = m_scr[...]                                   # [Hkv, G]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    # guard: fully-masked tile keeps m at -inf; exp(-inf - -inf) -> use where
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
    pr = jnp.exp(s - m_new[..., None])
    pr = jnp.where(valid, pr, 0.0)
    l_new = l_scr[...] * alpha + pr.sum(axis=-1)
    acc = acc_scr[...] * alpha[..., None]
    acc = acc + jnp.einsum("hgt,thd->hgd", pr, v_tile.astype(f32))

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(t == n_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def fused_append_attend(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                        new_k: jax.Array, new_v: jax.Array,
                        cache_len: jax.Array, *, seq_tile: int = 128,
                        interpret: bool = True
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step for a batch of sequences.

    Args:
      q:        [B, H, D] query for the new token (H = Hkv * G).
      cache_k:  [B, S, Hkv, D]; cache_v same. S must divide by seq_tile.
      new_k/v:  [B, Hkv, D] the new token's K,V (appended in-kernel).
      cache_len:[B] int32 — current length; the new token is written at this
                position and attended to (post-append length is cache_len+1).

    Returns:
      (attn_out [B, H, D], cache_k', cache_v') — caches updated in place.
    """
    b, s, hkv, d = cache_k.shape
    h = q.shape[1]
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    g = h // hkv
    seq_tile = min(seq_tile, s)
    assert s % seq_tile == 0, (s, seq_tile)
    n_tiles = s // seq_tile
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, d)
    lens = cache_len.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, seq_tile=seq_tile, n_tiles=n_tiles,
                               scale=scale)
    out_k, out_v, out = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),                 # len
            pl.BlockSpec((1, hkv, g, d), lambda bb, t: (bb, 0, 0, 0)),   # q
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, hkv, d), lambda bb, t: (bb, 0, 0)),         # new_k
            pl.BlockSpec((1, hkv, d), lambda bb, t: (bb, 0, 0)),         # new_v
        ],
        out_specs=[
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, hkv, g, d), lambda bb, t: (bb, 0, 0, 0)),   # out
        ],
        out_shape=[
            jax.ShapeDtypeStruct(cache_k.shape, cache_k.dtype),
            jax.ShapeDtypeStruct(cache_v.shape, cache_v.dtype),
            jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),          # m
            pltpu.VMEM((hkv, g), jnp.float32),          # l
            pltpu.VMEM((hkv, g, d), jnp.float32),       # acc
        ],
        input_output_aliases={2: 0, 3: 1},              # caches in-place
        interpret=interpret,
    )(lens, qg, cache_k, cache_v, new_k, new_v)
    return out.reshape(b, h, d), out_k, out_v
