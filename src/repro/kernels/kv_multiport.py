"""Pallas TPU kernel: fused decode append+attend over a multi-port KV cache.

The end-to-end carrier of the paper's claim C1 in the serving path. Decoding
one token conventionally costs TWO full traversals of the sequence-length KV
cache tiles:

  pass 1 (write port): scatter-append the new token's K,V at ``cache_len``;
  pass 2 (read port):  gather + attention over positions [0, cache_len].

This kernel configures the cache as a 2-port memory (1W + 1R per the paper's
"any R/W combination") and services both ports in ONE traversal: while each
KV tile is VMEM-resident, the tile containing ``cache_len`` takes the append
(W slot, higher priority) and every tile feeds the online-softmax attention
accumulation (R slot) — W-before-R visibility exactly as the wrapper's FSM
orders same-cycle traffic, so attention sees the just-appended token.

The traversal is LENGTH-BOUNDED two ways, so per-token read traffic scales
with the live sequence length instead of the allocated capacity:

  * ``live_len`` (static) slices the cache to a bucketed live prefix before
    launching, bounding the outer grid to ``ceil(live_len / seq_tile)``
    tiles; the suffix passes through untouched.
  * per-sequence, tiles wholly past ``cache_len`` skip the W/R service
    under ``pl.when`` (``length_mask=True``) and copy their cache block
    through unchanged — every output block is written on every grid step,
    so the kernel is safe under compiled Mosaic's output-revolving buffers,
    not just interpret-mode aliasing. A skipped tile is exactly a no-op of
    the online softmax (fully-masked tiles keep m/l/acc unchanged), so
    bounded and unbounded traversals agree bit-for-bit.
  * a sentinel ``cache_len = -1`` marks a DEAD batch row (the engine's
    padded slots): no tile is serviced at all and the attention output is
    zeros — so serviced-tile counts stay exact under batch padding.

Grid: (batch, seq_tiles); accumulators in VMEM scratch, persisted across the
inner (seq_tiles) grid dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import fit_seq_tile, iota, restore_live, slice_live


def _kernel(len_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
            out_k_ref, out_v_ref, o_ref, t_ref, m_scr, l_scr, acc_scr,
            n_scr, *, seq_tile: int, n_tiles: int, scale: float,
            length_mask: bool):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    p = len_ref[0, 0]                                     # append position
    tile_start = t * seq_tile
    # length bound: a tile whose first position is past the append slot holds
    # neither the W-port landing site nor any valid R-port position; a dead
    # row (p < 0, batch padding) has no live tile at all
    touched = (tile_start <= p) if length_mask else (p >= 0)

    @pl.when(touched)
    def _service():
        n_scr[0, 0] += 1                                  # serviced-tile count
        pos = tile_start + iota(seq_tile)                 # global positions [T]

        k_tile = k_ref[0]                                 # [T, Hkv, D]
        v_tile = v_ref[0]

        # --- W slot (priority A): append new token if it lands in this tile -
        hit = (pos == p)                                  # [T]
        k_tile = jnp.where(hit[:, None, None], new_k_ref[0][None], k_tile)
        v_tile = jnp.where(hit[:, None, None], new_v_ref[0][None], v_tile)
        out_k_ref[0] = k_tile                             # write-thru (aliased)
        out_v_ref[0] = v_tile

        # --- R slot (priority B): attention over valid positions (<= p) -----
        q = q_ref[0]                                      # [Hkv, G, D]
        f32 = jnp.float32
        s = jnp.einsum("hgd,thd->hgt", q.astype(f32),
                       k_tile.astype(f32)) * scale
        valid = (pos <= p)[None, None, :]                 # new token included
        s = jnp.where(valid, s, -jnp.inf)

        m_prev = m_scr[...]                               # [Hkv, G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # guard: fully-masked tile keeps m at -inf; exp(-inf - -inf) -> where
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        pr = jnp.exp(s - m_new[..., None])
        pr = jnp.where(valid, pr, 0.0)
        l_new = l_scr[...] * alpha + pr.sum(axis=-1)
        acc = acc_scr[...] * alpha[..., None]
        acc = acc + jnp.einsum("hgt,thd->hgd", pr, v_tile.astype(f32))

        m_scr[...] = m_new
        l_scr[...] = l_new
        acc_scr[...] = acc

    @pl.when(jnp.logical_not(touched))
    def _pass_through():
        # every output block is written every grid step: compiled Mosaic
        # recycles output VMEM buffers, so an unwritten block would copy
        # back stale data — the skip saves the W/R service, not the copy
        out_k_ref[0] = k_ref[0]
        out_v_ref[0] = v_ref[0]

    @pl.when(t == n_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        t_ref[0, 0] = n_scr[0, 0]


def fused_append_attend(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                        new_k: jax.Array, new_v: jax.Array,
                        cache_len: jax.Array, *, seq_tile: int = 128,
                        live_len: int | None = None, length_mask: bool = True,
                        return_tiles: bool = False, interpret: bool = True
                        ) -> tuple[jax.Array, ...]:
    """One decode step for a batch of sequences.

    Args:
      q:        [B, H, D] query for the new token (H = Hkv * G).
      cache_k:  [B, S, Hkv, D]; cache_v same. When S is not a multiple of
                seq_tile the tile is clamped to the largest divisor.
      new_k/v:  [B, Hkv, D] the new token's K,V (appended in-kernel).
      cache_len:[B] int32 — current length; the new token is written at this
                position and attended to (post-append length is cache_len+1).
                A NEGATIVE length marks a dead (padded) batch row: nothing
                is written or read for it and its attention output is zeros.
      live_len: static bound on ``max(cache_len) + 1`` — only cache tiles
                below it are traversed; the suffix [live_len, S) is returned
                untouched. Callers bucket it (powers of two of seq_tile) so
                retraces stay logarithmic.
      length_mask: skip tiles past each sequence's own append position under
                ``pl.when`` (False restores the unbounded traversal — the
                benchmark's comparator).
      return_tiles: also return the KERNEL-MEASURED count of serviced tiles
                per sequence ([B] int32) — the ground truth the host-side
                tile accounting is pinned against in tests.

    Returns:
      (attn_out [B, H, D], cache_k', cache_v') — caches updated in place —
      plus the serviced-tile counts when ``return_tiles``.
    """
    b, s, hkv, d = cache_k.shape
    h = q.shape[1]
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    g = h // hkv

    full_k, full_v = cache_k, cache_v
    cache_k, cache_v, bound = slice_live(cache_k, cache_v, live_len)
    seq_tile = fit_seq_tile(bound, seq_tile)
    n_tiles = bound // seq_tile
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, d)
    lens = cache_len.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, seq_tile=seq_tile, n_tiles=n_tiles,
                               scale=scale, length_mask=length_mask)
    out_k, out_v, out, tiles = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),                 # len
            pl.BlockSpec((1, hkv, g, d), lambda bb, t: (bb, 0, 0, 0)),   # q
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, hkv, d), lambda bb, t: (bb, 0, 0)),         # new_k
            pl.BlockSpec((1, hkv, d), lambda bb, t: (bb, 0, 0)),         # new_v
        ],
        out_specs=[
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, hkv, g, d), lambda bb, t: (bb, 0, 0, 0)),   # out
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),    # serviced tiles
        ],
        out_shape=[
            jax.ShapeDtypeStruct(cache_k.shape, cache_k.dtype),
            jax.ShapeDtypeStruct(cache_v.shape, cache_v.dtype),
            jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),          # m
            pltpu.VMEM((hkv, g), jnp.float32),          # l
            pltpu.VMEM((hkv, g, d), jnp.float32),       # acc
            pltpu.VMEM((1, 1), jnp.int32),              # serviced tiles
        ],
        input_output_aliases={2: 0, 3: 1},              # caches in-place
        interpret=interpret,
    )(lens, qg, cache_k, cache_v, new_k, new_v)
    out_k, out_v = restore_live(full_k, full_v, out_k, out_v)
    if return_tiles:
        return out.reshape(b, h, d), out_k, out_v, tiles[:, 0]
    return out.reshape(b, h, d), out_k, out_v
