"""Pallas TPU kernel: fused decode append+attend over a multi-port KV cache.

The end-to-end carrier of the paper's claim C1 in the serving path. Decoding
one token conventionally costs TWO full traversals of the sequence-length KV
cache tiles:

  pass 1 (write port): scatter-append the new token's K,V at ``cache_len``;
  pass 2 (read port):  gather + attention over positions [0, cache_len].

This kernel configures the cache as a 2-port memory (1W + 1R per the paper's
"any R/W combination") and services both ports in ONE traversal: while each
KV tile is VMEM-resident, the tile containing ``cache_len`` takes the append
(W slot, higher priority) and every tile feeds the online-softmax attention
accumulation (R slot) — W-before-R visibility exactly as the wrapper's FSM
orders same-cycle traffic, so attention sees the just-appended token.

Geometry is Mosaic-ready (the paper's point that an algorithmic multi-port
memory only pays off once its geometry matches the target array):

  * the cache is traversed in WORD layout ``[B, Sp, hkv * Dp]`` (see
    ``tiling.pack_words``): tiles are ``[seq_tile, word]`` with the minor
    dim a 128-lane multiple (``word_pad``) and per-head columns on lane
    boundaries; q/out ride as 3-D ``[B, Hp, Dp]`` blocks (the old rank-5
    ``[1, C, Hkv, G, D]`` shapes do not lower);
  * per-sequence append positions live in SMEM via scalar prefetch
    (``PrefetchScalarGridSpec``), not in a vector block.

The traversal is LENGTH-BOUNDED three ways, so per-token read traffic scales
with the live sequence length instead of the allocated capacity:

  * ``dynamic_grid=True``: the inner grid bound is a RUNTIME scalar — the
    live-tile count ``ceil((max(cache_len) + 1) / seq_tile)`` computed from
    the prefetched lengths — so ONE trace services every cache length
    (``pl.num_programs(1)`` closes the traversal); tiles past the bound are
    never launched and their (aliased) cache blocks stay untouched.
  * ``live_len`` (static) slices the cache to a bucketed live prefix before
    launching — the retrace-per-bucket fallback the engine keeps for
    ``dynamic_grid=False``.
  * per-sequence, tiles wholly past ``cache_len`` skip the W/R service
    under ``pl.when`` (``length_mask=True``) and copy their cache block
    through unchanged (every LAUNCHED output block is written on every grid
    step, so the kernel is safe under compiled Mosaic's output-revolving
    buffers). A skipped tile is exactly a no-op of the online softmax, so
    bounded, bucketed and dynamic-grid traversals agree bit-for-bit.
  * a sentinel ``cache_len = -1`` marks a DEAD batch row (the engine's
    padded slots): no tile is serviced at all and the attention output is
    zeros — so serviced-tile counts stay exact under batch padding.

Grid: (batch, live_tiles); accumulators in VMEM scratch, persisted across
the inner grid dimension.

SPLIT-KV FLASH-DECODE (``num_kv_splits > 1``): the serial R-port walk above
makes one long sequence bound the whole batch's step latency — its live
tiles form a single dependent accumulation chain. The split path breaks the
chain in two stages, the single-device half of sequence-parallel decode:

  * stage 1 partitions each sequence's OWN live range into
    ``num_kv_splits`` contiguous runs of ``ceil(live_tiles / splits)``
    tiles (per-row bounds from the prefetched length, so ragged batches
    split evenly); each run is an independent partial online-softmax
    emitting ``(acc, m, l)`` into per-split outputs laid out on the same
    word geometry (``[B, splits * Hp, Dp]`` acc + ``[B, splits * Hp,
    LANE]`` stats). The W-port append, the ``pl.when`` tile skip and the
    dead-row sentinel all carry over unchanged — the append tile belongs
    to exactly one split, skipped tiles are no-ops of that split's
    softmax, and a dead row leaves every split empty (``m = -inf``).
  * stage 2 is a cheap LSE-combine over the splits (running-max rescale:
    ``acc *= exp(m_old - m_new)``), one program per batch row.

Per-step latency becomes O(live_tiles / splits) + O(splits) instead of
O(live_tiles); serviced-tile counts are IDENTICAL to the serial walk (the
same tiles are touched, just on parallel chains), so the engine's
accounting and the ``--enforce-tile-bound`` gate hold verbatim.
``num_kv_splits=1`` dispatches the serial kernel itself — the bit-exact
oracle the property suite pins the split path against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (LANE, SUBLANE, clamp_seq_tile, iota,
                                  live_tile_bound, pack_words, pad_dim,
                                  restore_live, slice_live, unpack_words,
                                  word_pad)


def _kernel(len_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
            out_k_ref, out_v_ref, o_ref, t_ref, m_scr, l_scr, acc_scr,
            n_scr, *, seq_tile: int, hkv: int, g: int, dp: int,
            scale: float, length_mask: bool):
    bb = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)          # static OR the dynamic live bound
    h = hkv * g

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    p = len_ref[bb]                                       # append pos (SMEM)
    tile_start = t * seq_tile
    # length bound: a tile whose first position is past the append slot holds
    # neither the W-port landing site nor any valid R-port position; a dead
    # row (p < 0, batch padding) has no live tile at all
    touched = (tile_start <= p) if length_mask else (p >= 0)

    @pl.when(touched)
    def _service():
        n_scr[0, 0] += 1                                  # serviced-tile count
        f32 = jnp.float32
        pos = tile_start + iota(seq_tile)                 # global positions [T]

        k_tile = k_ref[0]                                 # [T, hkv * Dp]
        v_tile = v_ref[0]

        # --- W slot (priority A): append new token if it lands in this tile -
        hit = (pos == p)                                  # [T]
        k_tile = jnp.where(hit[:, None], new_k_ref[0, 0][None, :], k_tile)
        v_tile = jnp.where(hit[:, None], new_v_ref[0, 0][None, :], v_tile)
        out_k_ref[0] = k_tile                             # write-thru (aliased)
        out_v_ref[0] = v_tile

        # --- R slot (priority B): attention over valid positions (<= p) -----
        # per-kv-head scores on lane-aligned word columns (unrolled over the
        # small static hkv; each slice is a [G, Dp] x [Dp, T] MXU matmul)
        q = q_ref[0].astype(f32)                          # [Hp, Dp]
        dots = (((1,), (1,)), ((), ()))
        s = jnp.concatenate(
            [jax.lax.dot_general(q[hk * g:(hk + 1) * g],
                                 k_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                                 dots, preferred_element_type=f32)
             for hk in range(hkv)], axis=0) * scale       # [H, T]
        valid = (pos <= p)[None, :]                       # new token included
        s = jnp.where(valid, s, -jnp.inf)

        m_prev = m_scr[:, 0]                              # [H]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        # guard: fully-masked tile keeps m at -inf; exp(-inf - -inf) -> where
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        pr = jnp.exp(s - m_new[:, None])
        pr = jnp.where(valid, pr, 0.0)                    # [H, T]
        l_scr[:, 0] = l_scr[:, 0] * alpha + pr.sum(axis=-1)
        pv = jnp.concatenate(
            [jax.lax.dot_general(pr[hk * g:(hk + 1) * g],
                                 v_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
             for hk in range(hkv)], axis=0)               # [H, Dp]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[:, 0] = m_new

    @pl.when(jnp.logical_not(touched))
    def _pass_through():
        # every LAUNCHED output block is written every grid step: compiled
        # Mosaic recycles output VMEM buffers, so an unwritten block would
        # copy back stale data — the skip saves the W/R service, not the copy
        out_k_ref[0] = k_ref[0]
        out_v_ref[0] = v_ref[0]

    @pl.when(t == n_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, 0], 1e-30)[:, None]
        res = (acc_scr[...] / denom).astype(o_ref.dtype)  # [H, Dp]
        hp = o_ref.shape[1]
        if hp > h:                                        # head-pad rows
            res = jnp.concatenate(
                [res, jnp.zeros((hp - h, dp), o_ref.dtype)], axis=0)
        o_ref[0] = res
        t_ref[bb, 0] = n_scr[0, 0]


def _split_kernel(len_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
                  out_k_ref, out_v_ref, acc_ref, stats_ref, t_ref,
                  m_scr, l_scr, acc_scr, n_scr, *, seq_tile: int, hkv: int,
                  g: int, dp: int, scale: float, length_mask: bool,
                  num_kv_splits: int):
    """Stage 1 of split-KV decode: the serial kernel's W/R service with the
    online-softmax state FANNED OUT over ``num_kv_splits`` independent
    accumulator banks. Tile ``t`` of a row whose post-append live range is
    ``row_tiles`` tiles feeds bank ``t // ceil(row_tiles / splits)`` — a
    per-row contiguous partition, so ragged batches split each row's OWN
    length evenly rather than the batch max. Nothing else moves: the W-port
    append lands in whichever bank owns its tile, skipped tiles pass the
    cache through untouched, and a dead row (``p < 0``) leaves every bank
    at its ``m = -inf`` init. The final grid step spills all banks as
    per-split ``(acc, m, l)`` partials for the combine kernel."""
    bb = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)          # static OR the dynamic live bound
    h = hkv * g
    ns = num_kv_splits

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    p = len_ref[bb]                                       # append pos (SMEM)
    tile_start = t * seq_tile
    touched = (tile_start <= p) if length_mask else (p >= 0)

    # owner bank: per-ROW contiguous split of the row's own live tiles
    row_tiles = live_tile_bound(p + 1, seq_tile)
    per_split = jnp.maximum(live_tile_bound(row_tiles, ns), 1)
    row0 = jnp.clip(t // per_split, 0, ns - 1) * h

    @pl.when(touched)
    def _service():
        n_scr[0, 0] += 1                                  # serviced-tile count
        f32 = jnp.float32
        pos = tile_start + iota(seq_tile)                 # global positions [T]

        k_tile = k_ref[0]                                 # [T, hkv * Dp]
        v_tile = v_ref[0]

        # --- W slot (priority A): append new token if it lands in this tile -
        hit = (pos == p)                                  # [T]
        k_tile = jnp.where(hit[:, None], new_k_ref[0, 0][None, :], k_tile)
        v_tile = jnp.where(hit[:, None], new_v_ref[0, 0][None, :], v_tile)
        out_k_ref[0] = k_tile                             # write-thru (aliased)
        out_v_ref[0] = v_tile

        # --- R slot (priority B): partial softmax into the OWNER bank ------
        q = q_ref[0].astype(f32)                          # [Hp, Dp]
        dots = (((1,), (1,)), ((), ()))
        s = jnp.concatenate(
            [jax.lax.dot_general(q[hk * g:(hk + 1) * g],
                                 k_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                                 dots, preferred_element_type=f32)
             for hk in range(hkv)], axis=0) * scale       # [H, T]
        valid = (pos <= p)[None, :]                       # new token included
        s = jnp.where(valid, s, -jnp.inf)

        m_prev = m_scr[pl.ds(row0, h), 0]                 # [H]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        pr = jnp.exp(s - m_new[:, None])
        pr = jnp.where(valid, pr, 0.0)                    # [H, T]
        l_scr[pl.ds(row0, h), 0] = (l_scr[pl.ds(row0, h), 0] * alpha
                                    + pr.sum(axis=-1))
        pv = jnp.concatenate(
            [jax.lax.dot_general(pr[hk * g:(hk + 1) * g],
                                 v_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=f32)
             for hk in range(hkv)], axis=0)               # [H, Dp]
        acc_scr[pl.ds(row0, h), :] = (acc_scr[pl.ds(row0, h), :]
                                      * alpha[:, None] + pv)
        m_scr[pl.ds(row0, h), 0] = m_new

    @pl.when(jnp.logical_not(touched))
    def _pass_through():
        out_k_ref[0] = k_ref[0]
        out_v_ref[0] = v_ref[0]

    @pl.when(t == n_tiles - 1)
    def _finalize():
        # spill every bank as (acc, m, l) partials on the word geometry:
        # acc [ns*Hp, Dp]; stats [ns*Hp, LANE] with col 0 = m, col 1 = l.
        # Head-pad rows carry m = -inf / l = 0 so the combine sees them as
        # empty, same as a bank no tile ever fed.
        hp = acc_ref.shape[1] // ns
        accs, stats = [], []
        for si in range(ns):
            a = acc_scr[si * h:(si + 1) * h, :]
            m = m_scr[si * h:(si + 1) * h, 0]
            l = l_scr[si * h:(si + 1) * h, 0]
            if hp > h:
                a = jnp.concatenate(
                    [a, jnp.zeros((hp - h, dp), a.dtype)], axis=0)
                m = jnp.concatenate(
                    [m, jnp.full((hp - h,), -jnp.inf, m.dtype)], axis=0)
                l = jnp.concatenate(
                    [l, jnp.zeros((hp - h,), l.dtype)], axis=0)
            accs.append(a)
            stats.append(jnp.concatenate(
                [m[:, None], l[:, None],
                 jnp.zeros((hp, LANE - 2), jnp.float32)], axis=1))
        acc_ref[0] = jnp.concatenate(accs, axis=0)
        stats_ref[0] = jnp.concatenate(stats, axis=0)
        t_ref[bb, 0] = n_scr[0, 0]


def _combine_kernel(acc_ref, stats_ref, o_ref, *, num_kv_splits: int):
    """Stage 2 of split-KV decode: LSE-combine the per-split partials with
    the running-max rescale (``acc *= exp(m_old - m_new)``). One program per
    batch row; O(splits) work against stage 1's O(live_tiles / splits). An
    empty split (``m = -inf``) contributes weight 0, and a fully-dead row
    (every split empty) divides 0 by the 1e-30 floor — zeros, exactly the
    serial kernel's dead-row output."""
    hp, dp = o_ref.shape[1], o_ref.shape[2]
    m_run = jnp.full((hp,), -jnp.inf, jnp.float32)
    l_run = jnp.zeros((hp,), jnp.float32)
    a_run = jnp.zeros((hp, dp), jnp.float32)
    for si in range(num_kv_splits):
        m_s = stats_ref[0, si * hp:(si + 1) * hp, 0]
        l_s = stats_ref[0, si * hp:(si + 1) * hp, 1]
        a_s = acc_ref[0, si * hp:(si + 1) * hp, :]
        m_new = jnp.maximum(m_run, m_s)
        # guard: both-empty keeps m at -inf without exp(-inf - -inf) = nan
        safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - safe), 0.0)
        beta = jnp.where(jnp.isfinite(m_s), jnp.exp(m_s - safe), 0.0)
        a_run = a_run * alpha[:, None] + a_s * beta[:, None]
        l_run = l_run * alpha + l_s * beta
        m_run = m_new
    o_ref[0] = (a_run
                / jnp.maximum(l_run, 1e-30)[:, None]).astype(o_ref.dtype)


def fused_append_attend(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                        new_k: jax.Array, new_v: jax.Array,
                        cache_len: jax.Array, *, seq_tile: int = 128,
                        live_len: int | None = None, length_mask: bool = True,
                        dynamic_grid: bool = False, num_kv_splits: int = 1,
                        return_tiles: bool = False, interpret: bool = True
                        ) -> tuple[jax.Array, ...]:
    """One decode step for a batch of sequences.

    Args:
      q:        [B, H, D] query for the new token (H = Hkv * G).
      cache_k:  [B, S, Hkv, D]; cache_v same. S is zero-padded up to a whole
                tile count before the traversal (and cropped after), so
                awkward capacities keep aligned tiles instead of degrading
                the tile size.
      new_k/v:  [B, Hkv, D] the new token's K,V (appended in-kernel).
      cache_len:[B] int32 — current length; the new token is written at this
                position and attended to (post-append length is cache_len+1).
                A NEGATIVE length marks a dead (padded) batch row: nothing
                is written or read for it and its attention output is zeros.
      live_len: static bound on ``max(cache_len) + 1`` — only cache tiles
                below it are traversed; the suffix [live_len, S) is returned
                untouched. Callers bucket it (powers of two of seq_tile) so
                retraces stay logarithmic. Ignored under ``dynamic_grid``.
      length_mask: skip tiles past each sequence's own append position under
                ``pl.when`` (False restores the unbounded traversal — the
                benchmark's comparator).
      dynamic_grid: bound the traversal grid with the RUNTIME live-tile
                count ``ceil((max(cache_len) + 1) / seq_tile)`` instead of a
                static prefix — one trace services every cache length.
                Requires ``length_mask`` (the per-sequence skip is what
                keeps rows shorter than the batch max exact).
      num_kv_splits: > 1 switches to the two-stage split-KV path (see the
                module docstring): stage 1 accumulates each row's live tiles
                into ``num_kv_splits`` independent partial-softmax banks,
                stage 2 LSE-combines them. 1 (the default) launches the
                serial kernel itself — the bit-exact oracle. Serviced-tile
                counts and cache updates are identical either way.
      return_tiles: also return the KERNEL-MEASURED count of serviced tiles
                per sequence ([B] int32) — the ground truth the host-side
                tile accounting is pinned against in tests.

    Returns:
      (attn_out [B, H, D], cache_k', cache_v') — caches updated in place —
      plus the serviced-tile counts when ``return_tiles``.
    """
    b, s, hkv, d = cache_k.shape
    h = q.shape[1]
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    g = h // hkv
    if dynamic_grid and not length_mask:
        raise ValueError("dynamic_grid requires length_mask=True: rows "
                         "shorter than the batch max rely on the tile skip")

    dp = word_pad(d)
    hp = word_pad(h, SUBLANE)
    wp = hkv * dp
    scale = 1.0 / (d ** 0.5)
    seq_tile = clamp_seq_tile(s, seq_tile)

    # word layout: [B, Sp, hkv * Dp], Sp a whole tile count
    ck_w = pack_words(cache_k, seq_tile)
    cv_w = pack_words(cache_v, seq_tile)
    full_k, full_v = ck_w, cv_w
    if not dynamic_grid:
        live = None if live_len is None else word_pad(live_len, seq_tile)
        ck_w, cv_w, bound = slice_live(ck_w, cv_w, live)
    else:
        bound = ck_w.shape[1]
    grid_tiles = bound // seq_tile

    lens = cache_len.astype(jnp.int32)
    if dynamic_grid:
        # live bound from the scalar lengths: one trace, any cache length;
        # the post-append live range is [0, max(len) + 1) exclusive
        n_tiles = jnp.clip(live_tile_bound(jnp.max(lens) + 1, seq_tile),
                           1, grid_tiles)
    else:
        n_tiles = grid_tiles

    qp = pad_dim(pad_dim(q, 2, dp), 1, hp)                # [B, Hp, Dp]
    nk_w = pad_dim(new_k, 2, dp).reshape(b, 1, wp)        # [B, 1, wp]
    nv_w = pad_dim(new_v, 2, dp).reshape(b, 1, wp)

    ns = max(1, int(num_kv_splits))
    per_b = lambda bb, t, L: (bb, 0, 0)       # noqa: E731 — batch-resident
    per_tile = lambda bb, t, L: (bb, t, 0)    # noqa: E731 — cache traversal
    if ns == 1:
        kernel = functools.partial(_kernel, seq_tile=seq_tile, hkv=hkv, g=g,
                                   dp=dp, scale=scale,
                                   length_mask=length_mask)
        # block SHAPES come from the same geometry table the Mosaic lint test
        # checks (decode_block_specs) — the lint cannot drift from the launch
        blocks = {nm: blk
                  for nm, blk, _ in decode_block_specs(b, bound, h, hkv, d,
                                                       seq_tile)}
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,                        # lens -> SMEM
            grid=(b, n_tiles),
            in_specs=[
                pl.BlockSpec(blocks["q"], per_b),
                pl.BlockSpec(blocks["cache_k"], per_tile),
                pl.BlockSpec(blocks["cache_v"], per_tile),
                pl.BlockSpec(blocks["new_k"], per_b),
                pl.BlockSpec(blocks["new_v"], per_b),
            ],
            out_specs=[
                pl.BlockSpec(blocks["out_k"], per_tile),
                pl.BlockSpec(blocks["out_v"], per_tile),
                pl.BlockSpec(blocks["attn_out"], per_b),
                # serviced-tile counts: [B, LANE] int32 so the accounting
                # output is itself (8,128)-tileable (col 0 carries the count)
                pl.BlockSpec(blocks["tiles"], lambda bb, t, L: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((h, 1), jnp.float32),        # m
                pltpu.VMEM((h, 1), jnp.float32),        # l
                pltpu.VMEM((h, dp), jnp.float32),       # acc
                pltpu.VMEM((1, 1), jnp.int32),          # serviced tiles
            ],
        )
        out_k, out_v, out, tiles = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct(ck_w.shape, ck_w.dtype),
                jax.ShapeDtypeStruct(cv_w.shape, cv_w.dtype),
                jax.ShapeDtypeStruct((b, hp, dp), q.dtype),
                jax.ShapeDtypeStruct((b, LANE), jnp.int32),
            ],
            input_output_aliases={2: 0, 3: 1},          # caches in-place
            interpret=interpret,
        )(lens, qp, ck_w, cv_w, nk_w, nv_w)
    else:
        # two-stage split-KV: the launch geometry comes from the split
        # extension of the same lint-checked table
        blocks = {nm: blk
                  for nm, blk, _ in split_block_specs(b, bound, h, hkv, d,
                                                      seq_tile, ns)}
        kernel = functools.partial(_split_kernel, seq_tile=seq_tile, hkv=hkv,
                                   g=g, dp=dp, scale=scale,
                                   length_mask=length_mask, num_kv_splits=ns)
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,                        # lens -> SMEM
            grid=(b, n_tiles),
            in_specs=[
                pl.BlockSpec(blocks["q"], per_b),
                pl.BlockSpec(blocks["cache_k"], per_tile),
                pl.BlockSpec(blocks["cache_v"], per_tile),
                pl.BlockSpec(blocks["new_k"], per_b),
                pl.BlockSpec(blocks["new_v"], per_b),
            ],
            out_specs=[
                pl.BlockSpec(blocks["out_k"], per_tile),
                pl.BlockSpec(blocks["out_v"], per_tile),
                pl.BlockSpec(blocks["acc_partial"], per_b),
                pl.BlockSpec(blocks["lse_partial"], per_b),
                pl.BlockSpec(blocks["tiles"], lambda bb, t, L: (0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((ns * h, 1), jnp.float32),   # m, per bank
                pltpu.VMEM((ns * h, 1), jnp.float32),   # l, per bank
                pltpu.VMEM((ns * h, dp), jnp.float32),  # acc, per bank
                pltpu.VMEM((1, 1), jnp.int32),          # serviced tiles
            ],
        )
        out_k, out_v, acc, stats, tiles = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct(ck_w.shape, ck_w.dtype),
                jax.ShapeDtypeStruct(cv_w.shape, cv_w.dtype),
                jax.ShapeDtypeStruct((b, ns * hp, dp), jnp.float32),
                jax.ShapeDtypeStruct((b, ns * hp, LANE), jnp.float32),
                jax.ShapeDtypeStruct((b, LANE), jnp.int32),
            ],
            input_output_aliases={2: 0, 3: 1},          # caches in-place
            interpret=interpret,
        )(lens, qp, ck_w, cv_w, nk_w, nv_w)
        out = pl.pallas_call(
            functools.partial(_combine_kernel, num_kv_splits=ns),
            grid=(b,),
            in_specs=[
                pl.BlockSpec(blocks["acc_partial"], lambda bb: (bb, 0, 0)),
                pl.BlockSpec(blocks["lse_partial"], lambda bb: (bb, 0, 0)),
            ],
            out_specs=pl.BlockSpec(blocks["attn_out"], lambda bb: (bb, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, hp, dp), q.dtype),
            interpret=interpret,
        )(acc, stats)
    out_k, out_v = restore_live(full_k, full_v, out_k, out_v)
    out_k = unpack_words(out_k, s, hkv, d)
    out_v = unpack_words(out_v, s, hkv, d)
    out = out[:, :h, :d]
    if return_tiles:
        return out, out_k, out_v, tiles[:, 0]
    return out, out_k, out_v


def decode_block_specs(b: int, s: int, h: int, hkv: int, d: int,
                       seq_tile: int) -> list[tuple[str, tuple, tuple]]:
    """The decode kernel's block geometry as (name, block_shape, array_shape)
    triples — the surface the Mosaic geometry-lint test checks across the
    engine's bucket ladder (and the dynamic-grid full-capacity launch)."""
    dp = word_pad(d)
    hp = word_pad(h, SUBLANE)
    wp = hkv * dp
    sp = word_pad(s, seq_tile)
    tile = max(1, min(seq_tile, sp))
    return [
        ("q", (1, hp, dp), (b, hp, dp)),
        ("cache_k", (1, tile, wp), (b, sp, wp)),
        ("cache_v", (1, tile, wp), (b, sp, wp)),
        ("new_k", (1, 1, wp), (b, 1, wp)),
        ("new_v", (1, 1, wp), (b, 1, wp)),
        ("out_k", (1, tile, wp), (b, sp, wp)),
        ("out_v", (1, tile, wp), (b, sp, wp)),
        ("attn_out", (1, hp, dp), (b, hp, dp)),
        ("tiles", (b, LANE), (b, LANE)),
    ]


def split_block_specs(b: int, s: int, h: int, hkv: int, d: int,
                      seq_tile: int, num_kv_splits: int
                      ) -> list[tuple[str, tuple, tuple]]:
    """The split-KV launch geometry: the serial decode table plus the
    stage-1 partial outputs / stage-2 inputs. The per-split banks stack on
    the head axis (``num_kv_splits * Hp`` rows), so both extra arrays keep
    a lane-aligned minor dim (Dp for acc, LANE for the (m, l) stats) and a
    SUBLANE-aligned second-minor — same lint surface, one more knob."""
    ns = max(1, int(num_kv_splits))
    dp = word_pad(d)
    hp = word_pad(h, SUBLANE)
    return decode_block_specs(b, s, h, hkv, d, seq_tile) + [
        ("acc_partial", (1, ns * hp, dp), (b, ns * hp, dp)),
        ("lse_partial", (1, ns * hp, LANE), (b, ns * hp, LANE)),
    ]
