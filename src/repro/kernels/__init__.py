"""Pallas TPU kernels for the hot spots the paper's technique optimizes.

  multiport_sram — banked N-port memory step (the wrapper itself)
  kv_multiport   — fused decode append+attend over the multi-port KV cache
  flash_attention— tiled causal attention (training/prefill substrate)

Each kernel has a jit wrapper in ops.py and a pure-jnp oracle in ref.py;
tests/kernels/ sweeps shapes and dtypes against the oracles in interpret mode.
"""
