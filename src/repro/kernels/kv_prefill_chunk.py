"""Pallas TPU kernel: fused chunked-prefill append+attend over the multi-port
KV cache — the length-bounded traversal for the PREFILL port.

The chunked-prefill analogue of ``kv_multiport.fused_append_attend``: one
mid-prefill macro-cycle conventionally pays a scatter pass (write the chunk's
K,V at ``[offset, offset+chunk_len)``) plus a DENSE read of the entire
``S_max`` staging cache for the chunk's attention. This kernel configures the
cache as a 2-port (1W+1R) memory and services both ports in one length-
bounded traversal:

  W port (priority A): each cache tile takes the chunk rows whose destination
      ``offset + row`` lands inside it (routed by a one-hot matmul so the
      scatter lowers through the MXU, no gather needed);
  R port (priority B): every LIVE tile feeds the chunk's online-softmax
      attention — same-cycle W->R visibility, so queries see their own and
      earlier rows of the just-written chunk.

Length bounding is the point: only tiles ``[0, ceil((offset+chunk_len) /
seq_tile))`` are serviced — tiles wholly past a sequence's last query
position skip the W/R service under ``pl.when`` and copy their cache block
through unchanged (every output block is written on every grid step, so the
kernel is safe under compiled Mosaic's output-revolving buffers, not just
interpret-mode aliasing) — per-chunk read traffic scales with the LIVE
sequence length, not the allocated ``S_max``. A sentinel ``offset = -1``
marks a dead (padded) batch row: no tile is serviced for it at all.
Callers additionally bound the outer grid by slicing the cache to a
bucketed live prefix (see ``live_len``).

Grid: (batch, seq_tiles); per-row accumulators in VMEM scratch persist
across the inner (seq_tiles) dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import fit_seq_tile, iota, restore_live, slice_live


def _kernel(off_ref, clen_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
            out_k_ref, out_v_ref, o_ref, t_ref, m_scr, l_scr, acc_scr,
            n_scr, *, seq_tile: int, n_tiles: int, chunk: int, scale: float):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    off = off_ref[0, 0]
    cl = clen_ref[0, 0]
    tile_start = t * seq_tile
    # last position any query row attends to: padded rows (row >= chunk_len)
    # replicate position ``offset``, live rows reach offset + chunk_len - 1;
    # a dead batch row (offset < 0) has no live tile at all
    qpos_max = off + jnp.maximum(cl - 1, 0)
    touched = (tile_start <= qpos_max) & (off >= 0)

    @pl.when(touched)
    def _service():
        n_scr[0, 0] += 1                                  # serviced-tile count
        f32 = jnp.float32
        pos = tile_start + iota(seq_tile)                 # global [T]
        rel = pos - off                                   # chunk row per slot
        row = iota(chunk)

        # --- W port (priority A): land the chunk rows that map to this tile.
        # One-hot routing matrix [T, C] -> the scatter is an MXU matmul.
        w_hit = (rel >= 0) & (rel < cl)                   # [T]
        route = ((rel[:, None] == row[None, :])
                 & w_hit[:, None]).astype(f32)            # [T, C]
        k_new = jnp.einsum("tc,chd->thd", route, new_k_ref[0].astype(f32))
        v_new = jnp.einsum("tc,chd->thd", route, new_v_ref[0].astype(f32))
        k_tile = jnp.where(w_hit[:, None, None],
                           k_new.astype(k_ref.dtype), k_ref[0])
        v_tile = jnp.where(w_hit[:, None, None],
                           v_new.astype(v_ref.dtype), v_ref[0])
        out_k_ref[0] = k_tile                             # aliased write-thru
        out_v_ref[0] = v_tile

        # --- R port (priority B): causal online-softmax over the live tile.
        q = q_ref[0].astype(f32)                          # [C, Hkv, G, D]
        s = jnp.einsum("chgd,thd->chgt", q, k_tile.astype(f32)) * scale
        qpos = jnp.where(row < cl, off + row, off)        # [C]
        valid = pos[None, :] <= qpos[:, None]             # [C, T]
        vmask = valid[:, None, None, :]
        s = jnp.where(vmask, s, -jnp.inf)

        m_prev = m_scr[...]                               # [C, Hkv, G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        pr = jnp.exp(s - m_new[..., None])
        pr = jnp.where(vmask, pr, 0.0)
        l_scr[...] = l_scr[...] * alpha + pr.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[..., None]
                        + jnp.einsum("chgt,thd->chgd", pr, v_tile.astype(f32)))
        m_scr[...] = m_new

    @pl.when(jnp.logical_not(touched))
    def _pass_through():
        # every output block is written every grid step (compiled Mosaic
        # recycles output VMEM buffers; an unwritten block would copy back
        # stale data) — the skip saves the W/R service, not the copy
        out_k_ref[0] = k_ref[0]
        out_v_ref[0] = v_ref[0]

    @pl.when(t == n_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        t_ref[0, 0] = n_scr[0, 0]


def fused_chunk_append_attend(q: jax.Array, cache_k: jax.Array,
                              cache_v: jax.Array, new_k: jax.Array,
                              new_v: jax.Array, offset: jax.Array,
                              chunk_len: jax.Array, *, seq_tile: int = 128,
                              live_len: int | None = None,
                              return_tiles: bool = False,
                              interpret: bool = True
                              ) -> tuple[jax.Array, ...]:
    """One chunked-prefill step for a batch of mid-prefill sequences.

    Args:
      q:         [B, C, H, D] chunk queries (H = Hkv * G); rows past
                 ``chunk_len`` are padding (their outputs are garbage-but-
                 finite, exactly like the jnp oracle).
      cache_k/v: [B, S, Hkv, D] staging caches.
      new_k/v:   [B, C, Hkv, D] the chunk's K,V (rope already applied).
      offset:    [B] int32 — each sequence's cache write offset. A NEGATIVE
                 offset marks a dead (padded) batch row: nothing is written
                 or read for it and its attention output is zeros.
      chunk_len: [B] int32 — valid rows of each sequence's chunk.
      seq_tile:  tile size; clamped to the largest divisor of the traversed
                 length when it does not divide evenly.
      live_len:  static bound on the live prefix ``max(offset + chunk_len)``
                 — only cache tiles below it are traversed; the suffix
                 ``[live_len, S)`` is returned untouched.
      return_tiles: also return the KERNEL-MEASURED count of serviced tiles
                 per sequence ([B] int32) — the ground truth the host-side
                 tile accounting is pinned against in tests.

    Returns: (attn_out [B, C, H, D], cache_k', cache_v') plus the
    serviced-tile counts when ``return_tiles``.
    """
    b, s, hkv, d = cache_k.shape
    c = q.shape[1]
    h = q.shape[2]
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    g = h // hkv

    full_k, full_v = cache_k, cache_v
    cache_k, cache_v, bound = slice_live(cache_k, cache_v, live_len)
    seq_tile = fit_seq_tile(bound, seq_tile)
    n_tiles = bound // seq_tile
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, c, hkv, g, d)
    offs = offset.reshape(b, 1).astype(jnp.int32)
    clens = chunk_len.reshape(b, 1).astype(jnp.int32)

    kernel = functools.partial(_kernel, seq_tile=seq_tile, n_tiles=n_tiles,
                               chunk=c, scale=scale)
    out_k, out_v, out, tiles = pl.pallas_call(
        kernel,
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),                # off
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),                # clen
            pl.BlockSpec((1, c, hkv, g, d), lambda bb, t: (bb, 0, 0, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, c, hkv, d), lambda bb, t: (bb, 0, 0, 0)),  # newk
            pl.BlockSpec((1, c, hkv, d), lambda bb, t: (bb, 0, 0, 0)),  # newv
        ],
        out_specs=[
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, seq_tile, hkv, d), lambda bb, t: (bb, t, 0, 0)),
            pl.BlockSpec((1, c, hkv, g, d), lambda bb, t: (bb, 0, 0, 0, 0)),
            pl.BlockSpec((1, 1), lambda bb, t: (bb, 0)),    # serviced tiles
        ],
        out_shape=[
            jax.ShapeDtypeStruct(cache_k.shape, cache_k.dtype),
            jax.ShapeDtypeStruct(cache_v.shape, cache_v.dtype),
            jax.ShapeDtypeStruct((b, c, hkv, g, d), q.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((c, hkv, g), jnp.float32),          # m
            pltpu.VMEM((c, hkv, g), jnp.float32),          # l
            pltpu.VMEM((c, hkv, g, d), jnp.float32),       # acc
            pltpu.VMEM((1, 1), jnp.int32),                 # serviced tiles
        ],
        input_output_aliases={3: 0, 4: 1},                 # caches in-place
        interpret=interpret,
    )(offs, clens, qg, cache_k, cache_v, new_k, new_v)

    out_k, out_v = restore_live(full_k, full_v, out_k, out_v)
    if return_tiles:
        return out.reshape(b, c, h, d), out_k, out_v, tiles[:, 0]
    return out.reshape(b, c, h, d), out_k, out_v
