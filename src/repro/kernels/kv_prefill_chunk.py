"""Pallas TPU kernel: fused chunked-prefill append+attend over the multi-port
KV cache — the length-bounded traversal for the PREFILL port.

The chunked-prefill analogue of ``kv_multiport.fused_append_attend``: one
mid-prefill macro-cycle conventionally pays a scatter pass (write the chunk's
K,V at ``[offset, offset+chunk_len)``) plus a DENSE read of the entire
``S_max`` staging cache for the chunk's attention. This kernel configures the
cache as a 2-port (1W+1R) memory and services both ports in one length-
bounded traversal:

  W port (priority A): each cache tile takes the chunk rows whose destination
      ``offset + row`` lands inside it (routed by a one-hot matmul so the
      scatter lowers through the MXU, no gather needed);
  R port (priority B): every LIVE tile feeds the chunk's online-softmax
      attention — same-cycle W->R visibility, so queries see their own and
      earlier rows of the just-written chunk.

Geometry is Mosaic-ready: the cache rides in WORD layout ``[B, Sp, hkv*Dp]``
(tiles ``[seq_tile, word]``, minor dim lane-padded via ``word_pad``, per-head
columns on lane boundaries), the q/out blocks are rank-4 ``[1, C, Hp, Dp]``
(the old rank-5 ``[1, C, Hkv, G, D]`` blocks do not lower), and the
per-sequence offset / chunk-length scalars ride in SMEM via scalar prefetch.

Length bounding is the point: only tiles ``[0, ceil((offset+chunk_len) /
seq_tile))`` are serviced — tiles wholly past a sequence's last query
position skip the W/R service under ``pl.when`` and copy their cache block
through unchanged (every LAUNCHED output block is written on every grid
step, so the kernel is safe under compiled Mosaic's output-revolving
buffers) — per-chunk read traffic scales with the LIVE sequence length, not
the allocated ``S_max``. A sentinel ``offset = -1`` marks a dead (padded)
batch row: no tile is serviced for it at all. Callers additionally bound
the outer grid either statically (``live_len`` prefix slicing — the
bucketed fallback) or dynamically (``dynamic_grid=True``: the grid bound is
the runtime live-tile count from the prefetched scalars, so one trace
services every live length).

Grid: (batch, live_tiles); per-row accumulators in VMEM scratch persist
across the inner dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import (LANE, SUBLANE, clamp_seq_tile, iota,
                                  live_tile_bound, pack_words, pad_dim,
                                  restore_live, slice_live, unpack_words,
                                  word_pad)


def _kernel(off_ref, clen_ref, q_ref, k_ref, v_ref, new_k_ref, new_v_ref,
            out_k_ref, out_v_ref, o_ref, t_ref, m_scr, l_scr, acc_scr,
            n_scr, *, seq_tile: int, hkv: int, g: int, dp: int, chunk: int,
            scale: float):
    bb = pl.program_id(0)
    t = pl.program_id(1)
    n_tiles = pl.num_programs(1)          # static OR the dynamic live bound
    h = hkv * g

    @pl.when(t == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    off = off_ref[bb]                                     # SMEM scalars
    cl = clen_ref[bb]
    tile_start = t * seq_tile
    # last position any query row attends to: padded rows (row >= chunk_len)
    # replicate position ``offset``, live rows reach offset + chunk_len - 1;
    # a dead batch row (offset < 0) has no live tile at all
    qpos_max = off + jnp.maximum(cl - 1, 0)
    touched = (tile_start <= qpos_max) & (off >= 0)

    @pl.when(touched)
    def _service():
        n_scr[0, 0] += 1                                  # serviced-tile count
        f32 = jnp.float32
        pos = tile_start + iota(seq_tile)                 # global [T]
        rel = pos - off                                   # chunk row per slot
        cp = new_k_ref.shape[1]                           # padded chunk rows
        roww = iota(cp)

        # --- W port (priority A): land the chunk rows that map to this tile.
        # One-hot routing matrix [T, Cp] -> the whole-word scatter is one
        # MXU matmul against the packed [Cp, word] chunk.
        w_hit = (rel >= 0) & (rel < cl)                   # [T]
        route = ((rel[:, None] == roww[None, :])
                 & w_hit[:, None]).astype(f32)            # [T, Cp]
        k_new = jax.lax.dot(route, new_k_ref[0].astype(f32),
                            preferred_element_type=f32)   # [T, word]
        v_new = jax.lax.dot(route, new_v_ref[0].astype(f32),
                            preferred_element_type=f32)
        k_tile = jnp.where(w_hit[:, None], k_new.astype(k_ref.dtype), k_ref[0])
        v_tile = jnp.where(w_hit[:, None], v_new.astype(v_ref.dtype), v_ref[0])
        out_k_ref[0] = k_tile                             # aliased write-thru
        out_v_ref[0] = v_tile

        # --- R port (priority B): causal online-softmax over the live tile.
        # per-kv-head scores on lane-aligned word columns (unrolled over the
        # small static hkv)
        q = q_ref[0].astype(f32)                          # [C, Hp, Dp]
        s = jnp.concatenate(
            [jax.lax.dot_general(
                q[:, hk * g:(hk + 1) * g, :],
                k_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                (((2,), (1,)), ((), ())), preferred_element_type=f32)
             for hk in range(hkv)], axis=1) * scale       # [C, H, T]
        row = iota(chunk)
        qpos = jnp.where(row < cl, off + row, off)        # [C]
        valid = pos[None, :] <= qpos[:, None]             # [C, T]
        vmask = valid[:, None, :]
        s = jnp.where(vmask, s, -jnp.inf)

        m_prev = m_scr[...]                               # [C, H]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        pr = jnp.exp(s - m_new[..., None])
        pr = jnp.where(vmask, pr, 0.0)                    # [C, H, T]
        l_scr[...] = l_scr[...] * alpha + pr.sum(axis=-1)
        pv = jnp.concatenate(
            [jax.lax.dot_general(
                pr[:, hk * g:(hk + 1) * g, :],
                v_tile[:, hk * dp:(hk + 1) * dp].astype(f32),
                (((2,), (0,)), ((), ())), preferred_element_type=f32)
             for hk in range(hkv)], axis=1)               # [C, H, Dp]
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new

    @pl.when(jnp.logical_not(touched))
    def _pass_through():
        # every LAUNCHED output block is written every grid step (compiled
        # Mosaic recycles output VMEM buffers; an unwritten block would copy
        # back stale data) — the skip saves the W/R service, not the copy
        out_k_ref[0] = k_ref[0]
        out_v_ref[0] = v_ref[0]

    @pl.when(t == n_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        res = (acc_scr[...] / denom).astype(o_ref.dtype)  # [C, H, Dp]
        hp = o_ref.shape[2]
        if hp > h:                                        # head-pad rows
            res = jnp.concatenate(
                [res, jnp.zeros((chunk, hp - h, dp), o_ref.dtype)], axis=1)
        o_ref[0] = res
        t_ref[bb, 0] = n_scr[0, 0]


def fused_chunk_append_attend(q: jax.Array, cache_k: jax.Array,
                              cache_v: jax.Array, new_k: jax.Array,
                              new_v: jax.Array, offset: jax.Array,
                              chunk_len: jax.Array, *, seq_tile: int = 128,
                              live_len: int | None = None,
                              dynamic_grid: bool = False,
                              return_tiles: bool = False,
                              interpret: bool = True
                              ) -> tuple[jax.Array, ...]:
    """One chunked-prefill step for a batch of mid-prefill sequences.

    Args:
      q:         [B, C, H, D] chunk queries (H = Hkv * G); rows past
                 ``chunk_len`` are padding (their outputs are garbage-but-
                 finite, exactly like the jnp oracle).
      cache_k/v: [B, S, Hkv, D] staging caches. S is zero-padded up to a
                 whole tile count before the traversal (and cropped after).
      new_k/v:   [B, C, Hkv, D] the chunk's K,V (rope already applied).
      offset:    [B] int32 — each sequence's cache write offset. A NEGATIVE
                 offset marks a dead (padded) batch row: nothing is written
                 or read for it and its attention output is zeros.
      chunk_len: [B] int32 — valid rows of each sequence's chunk.
      seq_tile:  tile size (capacities that are not tile multiples are
                 padded, keeping the tile aligned).
      live_len:  static bound on the live prefix ``max(offset + chunk_len)``
                 — only cache tiles below it are traversed; the suffix
                 ``[live_len, S)`` is returned untouched. Ignored under
                 ``dynamic_grid``.
      dynamic_grid: bound the traversal grid with the RUNTIME live-tile
                 count instead — one trace services every live length.
      return_tiles: also return the KERNEL-MEASURED count of serviced tiles
                 per sequence ([B] int32) — the ground truth the host-side
                 tile accounting is pinned against in tests.

    Returns: (attn_out [B, C, H, D], cache_k', cache_v') plus the
    serviced-tile counts when ``return_tiles``.
    """
    b, s, hkv, d = cache_k.shape
    c = q.shape[1]
    h = q.shape[2]
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    g = h // hkv

    dp = word_pad(d)
    hp = word_pad(h, SUBLANE)
    cp = word_pad(c, SUBLANE)
    wp = hkv * dp
    scale = 1.0 / (d ** 0.5)
    seq_tile = clamp_seq_tile(s, seq_tile)

    ck_w = pack_words(cache_k, seq_tile)                  # [B, Sp, wp]
    cv_w = pack_words(cache_v, seq_tile)
    full_k, full_v = ck_w, cv_w
    if not dynamic_grid:
        live = None if live_len is None else word_pad(live_len, seq_tile)
        ck_w, cv_w, bound = slice_live(ck_w, cv_w, live)
    else:
        bound = ck_w.shape[1]
    grid_tiles = bound // seq_tile

    offs = offset.astype(jnp.int32)
    clens = chunk_len.astype(jnp.int32)
    if dynamic_grid:
        # live bound from the prefetched scalars: dead rows contribute 0;
        # ``last`` is the exclusive end of each row's post-append range
        last = jnp.where(offs >= 0, offs + jnp.maximum(clens - 1, 0) + 1, 0)
        n_tiles = jnp.clip(live_tile_bound(jnp.max(last), seq_tile),
                           1, grid_tiles)
    else:
        n_tiles = grid_tiles

    qp = pad_dim(pad_dim(q, 3, dp), 2, hp)                # [B, C, Hp, Dp]
    nk_w = pad_dim(pad_dim(new_k, 3, dp).reshape(b, c, wp), 1, cp)
    nv_w = pad_dim(pad_dim(new_v, 3, dp).reshape(b, c, wp), 1, cp)

    kernel = functools.partial(_kernel, seq_tile=seq_tile, hkv=hkv, g=g,
                               dp=dp, chunk=c, scale=scale)
    # block SHAPES come from the same geometry table the Mosaic lint test
    # checks (chunk_block_specs) — the lint cannot drift from the launch
    blocks = {nm: blk
              for nm, blk, _ in chunk_block_specs(b, c, bound, h, hkv, d,
                                                  seq_tile)}
    per_b3 = lambda bb, t, O, C: (bb, 0, 0)       # noqa: E731
    per_b4 = lambda bb, t, O, C: (bb, 0, 0, 0)    # noqa: E731
    per_tile = lambda bb, t, O, C: (bb, t, 0)     # noqa: E731
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                            # offs, clens -> SMEM
        grid=(b, n_tiles),
        in_specs=[
            pl.BlockSpec(blocks["q"], per_b4),
            pl.BlockSpec(blocks["cache_k"], per_tile),
            pl.BlockSpec(blocks["cache_v"], per_tile),
            pl.BlockSpec(blocks["new_k"], per_b3),
            pl.BlockSpec(blocks["new_v"], per_b3),
        ],
        out_specs=[
            pl.BlockSpec(blocks["out_k"], per_tile),
            pl.BlockSpec(blocks["out_v"], per_tile),
            pl.BlockSpec(blocks["attn_out"], per_b4),
            # serviced-tile counts: [B, LANE] int32 so the accounting output
            # is itself (8,128)-tileable (col 0 carries the count)
            pl.BlockSpec(blocks["tiles"], lambda bb, t, O, C: (0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((c, h), jnp.float32),              # m
            pltpu.VMEM((c, h), jnp.float32),              # l
            pltpu.VMEM((c, h, dp), jnp.float32),          # acc
            pltpu.VMEM((1, 1), jnp.int32),                # serviced tiles
        ],
    )
    out_k, out_v, out, tiles = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(ck_w.shape, ck_w.dtype),
            jax.ShapeDtypeStruct(cv_w.shape, cv_w.dtype),
            jax.ShapeDtypeStruct((b, c, hp, dp), q.dtype),
            jax.ShapeDtypeStruct((b, LANE), jnp.int32),
        ],
        input_output_aliases={3: 0, 4: 1},                # caches in-place
        interpret=interpret,
    )(offs, clens, qp, ck_w, cv_w, nk_w, nv_w)

    out_k, out_v = restore_live(full_k, full_v, out_k, out_v)
    out_k = unpack_words(out_k, s, hkv, d)
    out_v = unpack_words(out_v, s, hkv, d)
    out = out[:, :, :h, :d]
    if return_tiles:
        return out, out_k, out_v, tiles[:, 0]
    return out, out_k, out_v


def chunk_block_specs(b: int, c: int, s: int, h: int, hkv: int, d: int,
                      seq_tile: int) -> list[tuple[str, tuple, tuple]]:
    """The chunk kernel's block geometry as (name, block_shape, array_shape)
    triples for the Mosaic geometry-lint test. Note every block is rank<=4:
    the old rank-5 ``[1, C, Hkv, G, D]`` q/out blocks are flattened to
    ``[1, C, Hp, Dp]``."""
    dp = word_pad(d)
    hp = word_pad(h, SUBLANE)
    cp = word_pad(c, SUBLANE)
    wp = hkv * dp
    sp = word_pad(s, seq_tile)
    tile = max(1, min(seq_tile, sp))
    return [
        ("q", (1, c, hp, dp), (b, c, hp, dp)),
        ("cache_k", (1, tile, wp), (b, sp, wp)),
        ("cache_v", (1, tile, wp), (b, sp, wp)),
        ("new_k", (1, cp, wp), (b, cp, wp)),
        ("new_v", (1, cp, wp), (b, cp, wp)),
        ("out_k", (1, tile, wp), (b, sp, wp)),
        ("out_v", (1, tile, wp), (b, sp, wp)),
        ("attn_out", (1, c, hp, dp), (b, c, hp, dp)),
        ("tiles", (b, LANE), (b, LANE)),
    ]
