"""Pallas TPU kernel: banked N-port memory step — one traversal, N ports.

This is the paper's wrapper realized at the HBM<->VMEM boundary. The storage
is banked ``[num_banks, words_per_bank, W]``; the grid walks banks; each grid
step stages ONE bank tile in VMEM and services every enabled port's traffic to
that bank, in priority order (the FSM walk unrolled — at most 4 slots).

The caller packs ONLY the enabled ports, already in service order (see
ops.multiport_step): disabled ports contribute zero DMA traffic and zero
compute, so the kernel's HBM footprint is storage + (enabled-port queues).

TPU adaptation notes (DESIGN.md §2):
  * gather/scatter are realized as one-hot matmuls — MXU-friendly and free of
    dynamic-index hazards (a 65nm address decoder becomes a one-hot row; the
    sense amplifier becomes a [Q, wpb] x [wpb, W] matmul).
  * the bandwidth claim C1 falls out structurally: the baseline macro makes one
    full HBM traversal per enabled port; this kernel makes exactly one
    traversal regardless of the enabled-port count.
  * BlockSpec tiling: words_per_bank x W tiles; pick W as a multiple of 128
    (lane width) and words_per_bank as a multiple of 8 (sublane) for alignment;
    the VMEM working set per step is (wpb*W + P_eff*Q*(W+3)) words.

Priority semantics (claim C3) hold per bank; banks partition the address
space, so cross-bank ordering is immaterial.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.ports import WRITE


def _iota(n: int, dtype=jnp.int32) -> jax.Array:
    # 1-D iota via 2-D broadcasted_iota (TPU requires >=2D iota).
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


def _kernel(bank_ref, local_ref, data_ref, mask_ref, storage_ref,
            out_storage_ref, reads_ref, *, roles: tuple[int, ...],
            words_per_bank: int):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        reads_ref[...] = jnp.zeros_like(reads_ref)

    tile = storage_ref[0]                                   # [wpb, W]
    dtype = tile.dtype
    wpb = words_per_bank
    row_ids = _iota(wpb)                                    # [wpb]

    for slot, role in enumerate(roles):                     # FSM walk, unrolled
        lane_m = mask_ref[slot] & (bank_ref[slot] == b)     # [Q]
        # one-hot address decode: sel[q, w] == lane q targets word w of this bank
        sel = (local_ref[slot][:, None] == row_ids[None, :]) & lane_m[:, None]
        sel_f = sel.astype(dtype)
        if role == WRITE:
            written = sel.any(axis=0)                       # [wpb]
            newvals = jax.lax.dot(sel_f.T, data_ref[slot],
                                  preferred_element_type=dtype)
            tile = jnp.where(written[:, None], newvals, tile)
        else:
            got = jax.lax.dot(sel_f, tile, preferred_element_type=dtype)
            reads_ref[slot] = reads_ref[slot] + got

    out_storage_ref[0] = tile


def multiport_sram_step(storage_banked: jax.Array, bank_id: jax.Array,
                        local_addr: jax.Array, data: jax.Array,
                        mask: jax.Array, *, roles: tuple[int, ...],
                        interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """One macro-cycle over banked storage.

    Args:
      storage_banked: [num_banks, words_per_bank, W].
      bank_id/local_addr: int32 [P_eff, Q] precomputed addr decomposition for
            the ENABLED ports only, stacked in service (priority) order.
      data: [P_eff, Q, W] write payloads (same order).
      mask: bool [P_eff, Q]; write masks must already be deduped
            (last-wins) by the caller — see ops.multiport_step.
      roles: READ/WRITE per packed slot, in service order (jit
            specialization key).

    Returns:
      (storage_banked', reads[P_eff, Q, W]) — reads are zeros for write slots.
    """
    nb, wpb, w = storage_banked.shape
    p_eff, q = bank_id.shape
    assert p_eff == len(roles)

    kernel = functools.partial(_kernel, roles=tuple(roles), words_per_bank=wpb)
    out_storage, reads = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((p_eff, q), lambda b: (0, 0)),        # bank_id
            pl.BlockSpec((p_eff, q), lambda b: (0, 0)),        # local_addr
            pl.BlockSpec((p_eff, q, w), lambda b: (0, 0, 0)),  # data
            pl.BlockSpec((p_eff, q), lambda b: (0, 0)),        # mask
            pl.BlockSpec((1, wpb, w), lambda b: (b, 0, 0)),    # storage tile
        ],
        out_specs=[
            pl.BlockSpec((1, wpb, w), lambda b: (b, 0, 0)),    # storage out
            pl.BlockSpec((p_eff, q, w), lambda b: (0, 0, 0)),  # reads
        ],
        out_shape=[
            jax.ShapeDtypeStruct(storage_banked.shape, storage_banked.dtype),
            jax.ShapeDtypeStruct((p_eff, q, w), storage_banked.dtype),
        ],
        input_output_aliases={4: 0},                           # storage in-place
        interpret=interpret,
    )(bank_id, local_addr, data, mask, storage_banked)
    return out_storage, reads
