"""Pallas TPU kernel: causal flash attention (training / prefill hot spot).

Online-softmax tiled attention with GQA support. Grid (B, Hkv, Sq/Tq, Sk/Tk);
running max/denominator/accumulator live in VMEM scratch across the innermost
(key-tile) grid dimension. Key tiles entirely above the causal diagonal are
masked (see perf log in EXPERIMENTS.md §Perf for the tighter variant that
skips them via a tile-level `pl.when` guard, saving the matmuls but not the
tile loads).

Block sizes default to 128x128 (MXU-aligned); d_head up to 256 per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _iota(n: int, dtype=jnp.int32) -> jax.Array:
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, q_tile: int, k_tile: int, n_k_tiles: int, scale: float,
            causal: bool):
    tq = pl.program_id(2)
    tk = pl.program_id(3)

    @pl.when(tk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _compute():
        f32 = jnp.float32
        q = q_ref[0, 0].astype(f32)                  # [G, Tq, D]
        k = k_ref[0, 0].astype(f32)                  # [Tk, D]
        v = v_ref[0, 0].astype(f32)                  # [Tk, D]
        s = jnp.einsum("gqd,kd->gqk", q, k) * scale  # [G, Tq, Tk]
        if causal:
            qpos = tq * q_tile + _iota(q_tile)
            kpos = tk * k_tile + _iota(k_tile)
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None], s, -jnp.inf)

        m_prev = m_scr[...]                          # [G, Tq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_new), 0.0)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1)
        acc_scr[...] = (acc_scr[...] * alpha[..., None]
                        + jnp.einsum("gqk,kd->gqd", p, v))
        m_scr[...] = m_new

    if causal:
        # Tiles fully above the diagonal contribute nothing: skip the matmuls.
        pl.when(tq * q_tile + q_tile - 1 >= tk * k_tile)(_compute)
    else:
        _compute()

    @pl.when(tk == n_k_tiles - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_tile: int = 128,
                    k_tile: int = 128, interpret: bool = True) -> jax.Array:
    """Tiled attention.

    Args:
      q: [B, H, Sq, D] (H = Hkv * G); k, v: [B, Hkv, Sk, D].

    Returns: [B, H, Sq, D].
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    q_tile = min(q_tile, sq)
    k_tile = min(k_tile, sk)
    assert sq % q_tile == 0 and sk % k_tile == 0
    n_q, n_k = sq // q_tile, sk // k_tile
    scale = 1.0 / (d ** 0.5)

    qg = q.reshape(b, hkv, g, sq, d)

    kernel = functools.partial(_kernel, q_tile=q_tile, k_tile=k_tile,
                               n_k_tiles=n_k, scale=scale, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, g, q_tile, d), lambda bb, hh, tq, tk: (bb, hh, 0, tq, 0)),
            pl.BlockSpec((1, 1, k_tile, d), lambda bb, hh, tq, tk: (bb, hh, tk, 0)),
            pl.BlockSpec((1, 1, k_tile, d), lambda bb, hh, tq, tk: (bb, hh, tk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, q_tile, d),
                               lambda bb, hh, tq, tk: (bb, hh, 0, tq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, q_tile), jnp.float32),
            pltpu.VMEM((g, q_tile), jnp.float32),
            pltpu.VMEM((g, q_tile, d), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, h, sq, d)
