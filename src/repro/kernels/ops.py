"""Public jit'd wrappers around the Pallas kernels.

Each wrapper owns the request preprocessing (address decomposition, write
dedup) so the kernel bodies stay pure data movement + matmul, and exposes an
``interpret`` flag: True (default) executes the kernel body in Python on CPU;
on TPU deployments pass False to lower through Mosaic.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.multiport import MemorySpec, _dedup_last_wins
from repro.core.ports import MAX_PORTS, READ, WRITE, PortConfig, PortRequest
from repro.kernels import flash_attention as fa
from repro.kernels import kv_multiport as kvmp
from repro.kernels import kv_prefill_chunk as kvpc
from repro.kernels import multiport_sram as mps


def multiport_step(spec: MemorySpec, config: PortConfig, storage: jax.Array,
                   requests: Sequence[PortRequest], *, interpret: bool = True
                   ) -> tuple[jax.Array, list[jax.Array]]:
    """Kernel-backed macro-cycle with the same contract as core.multiport.step.

    Only the ENABLED ports' queues are packed and shipped to the kernel (in
    service order), so disabled ports cost zero HBM traffic — the C1 property
    at the request-metadata level: storage traversal bytes are constant in the
    port count, and queue bytes scale only with the ports actually enabled.
    """
    q = requests[0].queue_len
    for r in requests:
        if r.queue_len != q:
            raise ValueError("all port queues must share one queue length")

    wpb = spec.words_per_bank
    order = config.service_order()                    # enabled, priority order
    addrs, datas, masks = [], [], []
    for p in order:
        r = requests[p]
        m = r.mask
        if config.roles[p] == WRITE:
            m = _dedup_last_wins(r.addr, m)          # last-wins in queue order
        # clip OOB to an always-masked sentinel
        in_range = (r.addr >= 0) & (r.addr < spec.num_words)
        m = m & in_range
        addrs.append(jnp.where(m, r.addr, 0))
        datas.append(r.data.astype(spec.dtype))
        masks.append(m)

    addr = jnp.stack(addrs)                           # [P_eff, Q]
    data = jnp.stack(datas)                           # [P_eff, Q, W]
    mask = jnp.stack(masks)                           # [P_eff, Q]
    bank_id = addr // wpb
    local = addr % wpb

    banked = storage.reshape(spec.num_banks, wpb, spec.word_width)
    banked, packed = mps.multiport_sram_step(
        banked, bank_id.astype(jnp.int32), local.astype(jnp.int32), data, mask,
        roles=tuple(config.roles[p] for p in order), interpret=interpret)
    reads = [jnp.zeros((q, spec.word_width), spec.dtype)
             for _ in range(MAX_PORTS)]
    for slot, p in enumerate(order):
        if config.roles[p] == READ:
            reads[p] = packed[slot]
    return banked.reshape(spec.num_words, spec.word_width), reads


def _kv_shard_wrap(kernel, mesh, mesh_axis: str, batch: int, n_in: int,
                   n_out: int):
    """Wrap a fused KV kernel launch in ``shard_map`` over the batch axis of
    every operand: each device services ITS sequences with its own SMEM
    scalar prefetch (the shard's cache_len/offset/chunk_len slice) and its
    own dynamic live-tile bound — ``jnp.max`` over the shard-local lengths
    inside the mapped body — so a device holding short sequences traverses
    fewer tiles than one holding long sequences. Returns the kernel
    unchanged when the mesh is absent or trivial."""
    if mesh is None:
        return kernel
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    n = int(mesh.shape[mesh_axis])
    if n == 1:
        return kernel
    if batch % n:
        raise ValueError(
            f"kv-sharded kernel launch needs the batch ({batch}) to divide "
            f"across the {n}-way {mesh_axis!r} axis — pad the staged batch "
            f"to a whole number of rows per device")
    return compat_shard_map(kernel, mesh,
                            in_specs=(P(mesh_axis),) * n_in,
                            out_specs=(P(mesh_axis),) * n_out)


@functools.partial(jax.jit, static_argnames=("seq_tile", "live_len",
                                             "length_mask", "dynamic_grid",
                                             "num_kv_splits", "interpret",
                                             "mesh", "mesh_axis", "port_mix"))
def fused_decode_attention(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                           new_k: jax.Array, new_v: jax.Array,
                           cache_len: jax.Array, *, seq_tile: int = 128,
                           live_len: int | None = None,
                           length_mask: bool = True,
                           dynamic_grid: bool = False,
                           num_kv_splits: int = 1,
                           interpret: bool = True,
                           mesh=None, mesh_axis: str = "kv",
                           port_mix: str = "wr"):
    """Scheduled-port-mix decode step. See kv_multiport.py.

    ``port_mix`` is the compute-side port-mix decision made by the engine's
    macro-cycle scheduler: ``"wr"`` (a 1W+1R traversal is schedulable) runs
    the fused append+attend kernel — ONE length-bounded VMEM traversal
    services both ports with same-cycle W->R visibility; ``"w+r"`` (port
    budget of 1: the W and R ports cannot share a traversal) degrades to
    the two-pass oracle — append traversal then dense attend traversal
    (``mesh``/masking flags are fused-path concerns and are ignored there).

    ``dynamic_grid=True`` bounds the traversal with the runtime live-tile
    count instead of the static ``live_len`` prefix — one trace serves every
    cache length. ``num_kv_splits > 1`` runs the two-stage split-KV path
    (grid-parallel partial attention + LSE combine; 1 is the serial
    bit-exact oracle) — the ``"w+r"`` two-pass oracle has no traversal to
    split and ignores it. ``mesh`` (with a ``mesh_axis`` axis) runs the
    traversal under ``shard_map`` over the batch axis: per-shard SMEM
    scalars, per-shard live-tile bounds (see ``_kv_shard_wrap``); both
    split stages live inside the wrapped launch, so per-shard split bounds
    come from the shard-local lengths for free."""
    if port_mix == "w+r":
        from repro.kernels import ref
        return ref.decode_attention_ref(q, cache_k, cache_v, new_k, new_v,
                                        cache_len)
    if port_mix != "wr":
        raise ValueError(f"unknown port_mix: {port_mix!r}")
    kernel = functools.partial(kvmp.fused_append_attend, seq_tile=seq_tile,
                               live_len=live_len, length_mask=length_mask,
                               dynamic_grid=dynamic_grid,
                               num_kv_splits=num_kv_splits,
                               interpret=interpret)
    kernel = _kv_shard_wrap(kernel, mesh, mesh_axis, q.shape[0],
                            n_in=6, n_out=3)
    return kernel(q, cache_k, cache_v, new_k, new_v, cache_len)


@functools.partial(jax.jit, static_argnames=("seq_tile", "live_len",
                                             "dynamic_grid", "interpret",
                                             "mesh", "mesh_axis", "port_mix"))
def fused_prefill_chunk_attention(q: jax.Array, cache_k: jax.Array,
                                  cache_v: jax.Array, new_k: jax.Array,
                                  new_v: jax.Array, offset: jax.Array,
                                  chunk_len: jax.Array, *,
                                  seq_tile: int = 128,
                                  live_len: int | None = None,
                                  dynamic_grid: bool = False,
                                  interpret: bool = True,
                                  mesh=None, mesh_axis: str = "kv",
                                  port_mix: str = "wr"):
    """Scheduled-port-mix chunked-prefill step.

    See kv_prefill_chunk.py; like the decode wrapper, ``port_mix="wr"``
    runs the fused 1W+1R length-bounded traversal and ``"w+r"`` (1-port
    budget) degrades to the two-pass oracle
    ``ref.prefill_chunk_attention_ref`` — scatter traversal then dense
    attend traversal.
    ``dynamic_grid=True`` bounds the traversal with the runtime live-tile
    count instead of the static ``live_len`` prefix. ``mesh`` shards the
    traversal over the batch axis exactly like the decode wrapper."""
    if port_mix == "w+r":
        from repro.kernels import ref
        return ref.prefill_chunk_attention_ref(q, cache_k, cache_v, new_k,
                                               new_v, offset, chunk_len)
    if port_mix != "wr":
        raise ValueError(f"unknown port_mix: {port_mix!r}")
    kernel = functools.partial(kvpc.fused_chunk_append_attend,
                               seq_tile=seq_tile, live_len=live_len,
                               dynamic_grid=dynamic_grid, interpret=interpret)
    kernel = _kv_shard_wrap(kernel, mesh, mesh_axis, q.shape[0],
                            n_in=7, n_out=3)
    return kernel(q, cache_k, cache_v, new_k, new_v, offset, chunk_len)


@functools.partial(jax.jit, static_argnames=("causal", "q_tile", "k_tile", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_tile: int = 128, k_tile: int = 128,
                    interpret: bool = True) -> jax.Array:
    return fa.flash_attention(q, k, v, causal=causal, q_tile=q_tile,
                              k_tile=k_tile, interpret=interpret)
