"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests).

These are deliberately simple, unfused implementations; numerical agreement is
asserted via assert_allclose over shape/dtype sweeps in tests/kernels/.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import multiport as mp
from repro.core.ports import PortConfig, PortRequest


def multiport_step_ref(spec: mp.MemorySpec, config: PortConfig,
                       storage: jax.Array, requests: Sequence[PortRequest]
                       ) -> tuple[jax.Array, list[jax.Array]]:
    """The executable semantic spec from core.multiport (sequential service)."""
    return mp.step(spec, config, storage, requests)


def decode_attention_ref(q: jax.Array, cache_k: jax.Array, cache_v: jax.Array,
                         new_k: jax.Array, new_v: jax.Array,
                         cache_len: jax.Array
                         ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Two-pass (single-port) decode: append, then attend. [B,H,D] out."""
    b, s, hkv, d = cache_k.shape
    h = q.shape[1]
    g = h // hkv
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, cache_len].set(new_k)
    cache_v = cache_v.at[bidx, cache_len].set(new_v)

    # bf16 operands + f32 accumulation: the 32k-token cache is read once per
    # pass with no f32 copy materialized (§Perf iteration on decode).
    qg = q.reshape(b, hkv, g, d)
    s_ = jnp.einsum("bhgd,bshd->bhgs", qg, cache_k,
                    preferred_element_type=jnp.float32) / (d ** 0.5)
    valid = (jnp.arange(s)[None] <= cache_len[:, None])[:, None, None, :]
    s_ = jnp.where(valid, s_, -jnp.inf)
    p = jax.nn.softmax(s_, axis=-1).astype(cache_v.dtype)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cache_v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, d).astype(q.dtype), cache_k, cache_v


def prefill_chunk_attention_ref(q: jax.Array, cache_k: jax.Array,
                                cache_v: jax.Array, new_k: jax.Array,
                                new_v: jax.Array, offset: jax.Array,
                                chunk_len: jax.Array
                                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Two-pass chunked-prefill oracle: scatter the chunk's K,V at
    [offset, offset+chunk_len), then attend causally over the WHOLE cache
    (the O(S_max) dense read the fused kernel's bounded traversal replaces).

    q: [B, C, H, D]; cache_k/v: [B, S, Hkv, D]; new_k/v: [B, C, Hkv, D];
    offset/chunk_len: [B]. Padded rows (>= chunk_len) replicate position
    ``offset`` so their softmax stays finite; outputs there are discarded by
    callers. Returns (out [B, C, H, D], cache_k', cache_v').
    """
    b, c, h, d = q.shape
    s_max = cache_k.shape[1]
    hkv = cache_k.shape[2]
    g = h // hkv
    rel = jnp.arange(c)
    positions = offset[:, None] + rel[None, :]                    # [B, C]
    valid = rel[None, :] < chunk_len[:, None]                     # [B, C]

    # W port: scatter valid chunk rows; padded lanes routed out of bounds.
    dest = jnp.where(valid, positions, s_max)
    bidx = jnp.arange(b)[:, None]
    cache_k = cache_k.at[bidx, dest].set(new_k.astype(cache_k.dtype),
                                         mode="drop")
    cache_v = cache_v.at[bidx, dest].set(new_v.astype(cache_v.dtype),
                                         mode="drop")

    # R port: dense causal attention over the updated cache.
    f32 = jnp.float32
    qg = q.reshape(b, c, hkv, g, d)
    scale = 1.0 / (d ** 0.5)
    sc = jnp.einsum("bchgd,bshd->bchgs", qg, cache_k.astype(qg.dtype),
                    preferred_element_type=f32) * scale
    kpos = jnp.arange(s_max)
    qpos = jnp.where(valid, positions, offset[:, None])
    mask = kpos[None, None, :] <= qpos[..., None]                 # [B, C, S]
    sc = jnp.where(mask[:, :, None, None, :], sc, -jnp.inf)
    pr = jax.nn.softmax(sc, axis=-1).astype(cache_v.dtype)
    oc = jnp.einsum("bchgs,bshd->bchgd", pr, cache_v,
                    preferred_element_type=f32)
    return oc.astype(q.dtype).reshape(b, c, h, d), cache_k, cache_v


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """Dense softmax attention with GQA. q:[B,H,Sq,D], k/v:[B,Hkv,Sk,D]."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)
