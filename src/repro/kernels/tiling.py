"""Shared tiling + Mosaic-geometry helpers for the length-bounded KV-cache
kernels (`kv_multiport` decode, `kv_prefill_chunk` chunked prefill).

Both kernels traverse the cache in ``seq_tile``-sized tiles. Two geometry
disciplines live here:

* **(8, 128)/f32 alignment.** Compiled Mosaic tiles the last two dims of
  every block as (SUBLANE, LANE) = (8, 128) for f32. The kernels therefore
  operate on a WORD layout: a cache tile is ``[seq_tile, word]`` where the
  word packs every KV head's vector padded to the lane width
  (``word = hkv * word_pad(head_dim)``), so the minor dim is always a
  128-multiple and per-head slices land on lane boundaries. ``word_pad``
  rounds CI's small head dims (8/16 words) up to a full lane — small word
  widths still run, they just ride zero lanes that are cropped on the way
  out. ``pack_words`` / ``unpack_words`` are the (bit-exact) pad+flatten /
  crop round trip.

* **Live-prefix bounding.** The wrapper either slices the caches to a static
  ``live_len`` prefix before launching (the bucketed path — one retrace per
  ladder entry) or leaves the capacity alone and bounds the GRID itself with
  a scalar live-tile count (the dynamic-grid path — one trace for every
  cache length; see the kernel modules).
"""
from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

# Mosaic f32 tile: (sublane, lane) minor-dims minimum.
LANE = 128
SUBLANE = 8

_fit_warned: set = set()


def word_pad(n: int, unit: int = LANE) -> int:
    """Round a minor (lane) dim up to the Mosaic tile unit."""
    return -(-int(n) // unit) * unit


def live_tile_bound(last_exclusive, seq_tile: int):
    """Tiles covering positions ``[0, last_exclusive)`` — the ONE live-tile
    bound formula shared by the decode, chunked-prefill and split-KV
    traversals.

    ``last_exclusive`` is always the EXCLUSIVE end of the live range: the
    decode kernel passes ``max(cache_len) + 1`` (the append position is
    live after the in-traversal write), the chunk kernel passes
    ``max(offset + chunk_len)``, and the split-KV partial-attention path
    passes each row's own post-append length. The two kernels used to
    inline algebraically-equal but textually-different forms of this
    ceil-div (inclusive ``(last + tile) // tile`` vs exclusive
    ``(last + tile - 1) // tile``) — exactly how a future edit breaks one
    silently. Accepts ints and traced jnp scalars alike; callers clip the
    result to their grid capacity (and to >= 1 for all-dead batches)."""
    return (last_exclusive + seq_tile - 1) // seq_tile


def clamp_seq_tile(s: int, seq_tile: int) -> int:
    """The kernels' launch-time tile clamp ``max(1, min(seq_tile, s))`` —
    no longer silent. A configured tile larger than the traversed capacity
    diverges from what the launcher validated against the engine's
    ``final_stage_ladder`` (and from the host-side tile accounting), so the
    first time a given ``(s, seq_tile)`` pair clamps DOWN, a warning names
    both sizes through the same once-per-geometry machinery as
    :func:`fit_seq_tile`."""
    t = max(1, min(seq_tile, s))
    if t != seq_tile:
        key = ("clamp", s, seq_tile)
        if key not in _fit_warned:
            _fit_warned.add(key)
            warnings.warn(
                f"seq_tile {seq_tile} exceeds the traversed capacity {s}; "
                f"clamping to {t} — the launch geometry no longer matches "
                f"the validated --seq-tile (validate against "
                f"final_stage_ladder, or pass seq_tile <= capacity)",
                stacklevel=2)
    return t


def fit_seq_tile(s: int, seq_tile: int) -> int:
    """Largest divisor of ``s`` that is <= ``seq_tile``, preferring
    SUBLANE-aligned divisors (Mosaic sublane geometry) over raw size.

    The serving engine never relies on this fallback — its staging buckets
    are whole tile counts — but direct callers with awkward capacities
    degrade gracefully instead of crashing on a divisibility assert. The
    degradation is no longer silent: the first time a given (s, seq_tile)
    pair clamps, a warning names the fallback tile (a prime capacity
    degrades all the way to tile 1 — pad the capacity instead)."""
    t = max(1, min(seq_tile, s))
    if s % t == 0:
        return t
    divisors = [d for d in range(t, 0, -1) if s % d == 0]
    aligned = [d for d in divisors if d % SUBLANE == 0]
    pick = aligned[0] if aligned else divisors[0]
    key = (s, seq_tile)
    if key not in _fit_warned:
        _fit_warned.add(key)
        warnings.warn(
            f"seq_tile {seq_tile} does not divide capacity {s}; clamping to "
            f"the largest {'aligned ' if aligned else ''}divisor {pick}"
            + ("" if aligned else
               f" (not a multiple of {SUBLANE}: interpret-only geometry —"
               f" pad the capacity to a tile multiple instead)"),
            stacklevel=2)
    return pick


def iota(n: int, dtype=jnp.int32) -> jax.Array:
    """1-D iota via the TPU-legal 2-D broadcasted form."""
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


def pad_dim(x: jax.Array, axis: int, target: int) -> jax.Array:
    """Zero-pad one axis of ``x`` up to ``target`` (no-op when equal)."""
    n = x.shape[axis]
    if n == target:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - n)
    return jnp.pad(x, pads)


def pack_words(cache: jax.Array, seq_tile: int) -> jax.Array:
    """[B, S, Hkv, D] -> [B, Sp, Hkv * Dp] word layout.

    Each head's D vector is zero-padded to a whole lane count
    (``Dp = word_pad(D)``) so per-head column slices are lane-aligned, and
    the sequence dim is zero-padded to a whole tile count
    (``Sp = ceil(S / seq_tile) * seq_tile``) so the grid never needs a
    degenerate fit-down tile. Exact inverse: :func:`unpack_words`."""
    b, s, hkv, d = cache.shape
    dp = word_pad(d)
    sp = word_pad(s, seq_tile)
    cache = pad_dim(pad_dim(cache, 3, dp), 1, sp)
    return cache.reshape(b, sp, hkv * dp)


def unpack_words(words: jax.Array, s: int, hkv: int, d: int) -> jax.Array:
    """[B, Sp, Hkv * Dp] -> [B, S, Hkv, D]: crop the word layout back."""
    b, sp, w = words.shape
    dp = w // hkv
    return words.reshape(b, sp, hkv, dp)[:, :s, :, :d]


def slice_live(cache_k: jax.Array, cache_v: jax.Array,
               live_len: int | None) -> tuple[jax.Array, jax.Array, int]:
    """Bound two [B, S, ...] caches to the static live prefix.

    Returns (k_prefix, v_prefix, bound) where bound == S when live_len is
    None or does not actually shrink the cache."""
    s = cache_k.shape[1]
    bound = s if live_len is None else max(1, min(live_len, s))
    if bound < s:
        return cache_k[:, :bound], cache_v[:, :bound], bound
    return cache_k, cache_v, bound


def restore_live(full_k: jax.Array, full_v: jax.Array, out_k: jax.Array,
                 out_v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Splice computed prefixes back over the full caches (no-op when the
    traversal was unbounded). Rank-agnostic: works on the raw [B, S, Hkv, D]
    caches and on the packed [B, Sp, W] word layout alike."""
    if out_k.shape[1] < full_k.shape[1]:
        zeros = (0,) * full_k.ndim
        out_k = jax.lax.dynamic_update_slice(full_k, out_k, zeros)
        out_v = jax.lax.dynamic_update_slice(full_v, out_v, zeros)
    return out_k, out_v


def check_block(block: tuple, array: tuple) -> list[str]:
    """Mosaic lint for one block spec against its array shape.

    Returns a list of violations (empty == Mosaic-valid): rank must be <= 4
    (5-D blocks do not lower), the minor dim must be a LANE multiple, and
    the second-minor dim must be a SUBLANE multiple or span the full array
    dim (Mosaic's documented alternative)."""
    errs = []
    if len(block) != len(array):
        errs.append(f"block rank {len(block)} != array rank {len(array)}")
        return errs
    if len(block) > 4:
        errs.append(f"rank-{len(block)} block {block}: Mosaic lowers rank<=4")
    if len(block) >= 1 and block[-1] % LANE:
        # full-dim minor blocks only lower cleanly when lane-aligned too;
        # word_pad exists precisely so this never fires for the KV kernels
        errs.append(f"minor dim {block[-1]} of {block}: not a {LANE}-multiple")
    if len(block) >= 2 and block[-2] % SUBLANE and block[-2] != array[-2]:
        errs.append(
            f"second-minor dim {block[-2]} of {block}: not a "
            f"{SUBLANE}-multiple nor the full array dim {array[-2]}")
    return errs
