"""Shared tiling helpers for the length-bounded KV-cache kernels
(`kv_multiport` decode, `kv_prefill_chunk` chunked prefill).

Both kernels traverse the cache in ``seq_tile``-sized tiles and bound the
traversal to a static live prefix: the wrapper slices the caches to
``live_len`` words before launching (so the grid covers only live tiles)
and splices the computed prefix back afterwards, returning the suffix
untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fit_seq_tile(s: int, seq_tile: int) -> int:
    """Largest tile <= seq_tile that divides s (clamp instead of crash for
    capacities that are not tile-multiples). The serving engine never relies
    on this fallback — its staging buckets are whole tile counts — but
    direct kernel callers with awkward caches degrade gracefully."""
    t = max(1, min(seq_tile, s))
    while s % t:
        t -= 1
    return t


def iota(n: int, dtype=jnp.int32) -> jax.Array:
    """1-D iota via the TPU-legal 2-D broadcasted form."""
    return jax.lax.broadcasted_iota(dtype, (n, 1), 0)[:, 0]


def slice_live(cache_k: jax.Array, cache_v: jax.Array,
               live_len: int | None) -> tuple[jax.Array, jax.Array, int]:
    """Bound two [B, S, ...] caches to the static live prefix.

    Returns (k_prefix, v_prefix, bound) where bound == S when live_len is
    None or does not actually shrink the cache."""
    s = cache_k.shape[1]
    bound = s if live_len is None else max(1, min(live_len, s))
    if bound < s:
        return cache_k[:, :bound], cache_v[:, :bound], bound
    return cache_k, cache_v, bound


def restore_live(full_k: jax.Array, full_v: jax.Array, out_k: jax.Array,
                 out_v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Splice computed prefixes back over the full caches (no-op when the
    traversal was unbounded)."""
    if out_k.shape[1] < full_k.shape[1]:
        out_k = jax.lax.dynamic_update_slice(full_k, out_k, (0, 0, 0, 0))
        out_v = jax.lax.dynamic_update_slice(full_v, out_v, (0, 0, 0, 0))
    return out_k, out_v
