"""Paper Table II / Fig. 6 — bandwidth amplification vs enabled port count.

Two measurements per port count N in {1,2,3,4}:
  * storage-traversal bytes per macro-cycle, from the compiled kernel's
    cost_analysis: proposed (one traversal, all ports) vs the bare single-port
    macro (one traversal PER enabled port);
  * port transactions serviced per traversal — the paper's "memory access
    frequency" multiplier (250 MHz CLK -> N x 250 MHz effective).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MemorySpec, PortConfig, READ, WRITE, PortRequest, step
from repro.core.baselines import SinglePortNPass
from repro.kernels import ops

SPEC = MemorySpec(num_words=4096, word_width=128, num_banks=16)
Q = 256
ROLES = (WRITE, READ, READ, WRITE)


def _requests(rng) -> list[PortRequest]:
    out = []
    for _ in range(4):
        out.append(PortRequest(
            addr=jnp.asarray(rng.integers(0, SPEC.num_words, Q), jnp.int32),
            data=jnp.asarray(rng.normal(size=(Q, SPEC.word_width)), jnp.float32),
            mask=jnp.ones((Q,), bool)))
    return out


def _cfg(n: int) -> PortConfig:
    return PortConfig(enabled=tuple(i < n for i in range(4)), roles=ROLES)


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    reqs = _requests(rng)
    storage = jnp.zeros((SPEC.num_words, SPEC.word_width), jnp.float32)
    rows = []
    for n in range(1, 5):
        cfg = _cfg(n)
        # proposed wrapper: one pallas traversal services all N ports
        f = jax.jit(lambda s, r: ops.multiport_step(SPEC, cfg, s, r,
                                                    interpret=True))
        cost = f.lower(storage, reqs).compile().cost_analysis()
        if isinstance(cost, list):        # pre-0.5 JAX returns [dict]
            cost = cost[0]
        bytes_prop = float(cost.get("bytes accessed", 0.0))

        base = SinglePortNPass(SPEC)
        fb = jax.jit(lambda s, r: base.step(cfg, s, r))
        cost_b = fb.lower(storage, reqs).compile().cost_analysis()
        if isinstance(cost_b, list):
            cost_b = cost_b[0]
        bytes_base = float(cost_b.get("bytes accessed", 0.0))

        # wall time (CPU; interpret mode for the kernel — relative trend only)
        f(storage, reqs)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            f(storage, reqs)[0].block_until_ready()
        t_prop = (time.perf_counter() - t0) / 3
        fb(storage, reqs)[0].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(3):
            fb(storage, reqs)[0].block_until_ready()
        t_base = (time.perf_counter() - t0) / 3

        rows.append({
            "ports": n,
            "transactions_per_traversal": n * Q,
            "effective_access_multiplier": n,      # paper: N x 250 MHz
            "proposed_bytes": bytes_prop,
            "baseline_bytes": bytes_base,
            "bytes_ratio_base_over_prop": bytes_base / max(bytes_prop, 1),
            "us_proposed": t_prop * 1e6,
            "us_baseline_npass": t_base * 1e6,
        })
    return rows


def main() -> None:
    rows = run()
    print("# bandwidth amplification (paper Table II, claim C1)")
    print("ports,txn_per_traversal,eff_access_x,prop_bytes,base_bytes,"
          "bytes_ratio,us_prop,us_base")
    for r in rows:
        print(f"{r['ports']},{r['transactions_per_traversal']},"
              f"{r['effective_access_multiplier']},{r['proposed_bytes']:.3g},"
              f"{r['baseline_bytes']:.3g},{r['bytes_ratio_base_over_prop']:.2f},"
              f"{r['us_proposed']:.0f},{r['us_baseline_npass']:.0f}")


if __name__ == "__main__":
    main()
