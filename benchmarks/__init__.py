"""Benchmark harness — one module per paper table/figure.

  bandwidth.py   — Table II bandwidth amplification (claim C1, kernel level)
  footprint.py   — Tables I/II area analogue (claim C2)
  engine_bench.py— system-level C1: multi-port vs single-port serving engine
  kernels_bench.py — per-kernel micro costs (flash attention, fused decode)
  roofline.py    — §Roofline: three-term model from dry-run artifacts

Run everything: ``PYTHONPATH=src python -m benchmarks.run``.
"""
