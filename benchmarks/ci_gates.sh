#!/usr/bin/env bash
# The ONE home for the CI bench-gate invocations. bench-smoke and
# bench-serve (.github/workflows/ci.yml) both run through here, so gate
# flags live in this file instead of drifting apart across workflow YAML —
# and a local repro is the same command CI ran:
#
#     benchmarks/ci_gates.sh engine   # bench-engine/v6 ratio/tile/split gates
#     benchmarks/ci_gates.sh serve    # bench-serve/v3 latency-SLO +
#                                     # overload-sweep + prefix-mix gates
#     benchmarks/ci_gates.sh chaos    # seeded fault injection: invariant
#                                     # audits + survivor token identity
#
# All write their JSON record (BENCH_engine.json / BENCH_serve.json /
# BENCH_chaos.json) into the repo root BEFORE exiting non-zero, so CI
# uploads it on pass and fail. Gate semantics are documented in
# benchmarks/README.md.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src
# both benches exercise the data-parallel-KV surface on forced host devices
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

case "${1:?usage: ci_gates.sh engine|serve|chaos}" in
  engine)
    exec python benchmarks/engine_bench.py \
      --requests 6 --max-new 4 \
      --json BENCH_engine.json \
      --min-traversal-ratio 1.9 \
      --enforce-tile-bound --min-tile-ratio 3.9 \
      --enforce-single-trace --max-kv-balance 1.25 \
      --min-coschedule-frac 0.75 \
      --min-split-speedup 2.0
    ;;
  serve)
    # open-loop latency SLOs in virtual-clock ticks (deterministic:
    # seeded arrivals + tick-based clock). Thresholds sit between the
    # measured tails — ooo p99 TTFT 2.8 ticks / goodput 1.588 tok/tick vs
    # static 8.8 / 1.080 at this rate — so the gate both enforces the SLO
    # and keeps proving the configurable port mix is what meets it.
    # the overload sweep rides the same invocation: SUSTAINED
    # above-saturation rates (3x/6x the plateau for a fixed arrival
    # window, so the backlog never drains) where the protected engine
    # (deadline TTL + bounded queue + degradation controller) must hold
    # goodput within 20% of the pre-overload plateau (measured:
    # 1.11x/1.16x) while the no-shedding baseline collapses past the
    # band at the deepest rate (measured: 0.34x), sheds never touch the
    # engine, and survivor tokens stay identical to the pressure-free run
    # the prefix mix rides the same invocation: a paced shared-prefix
    # scenario where refcounted copy-on-write page sharing must dedup
    # prompt compute — computed/served ≤ 0.6 with the cache on
    # (measured: 0.52, hit rate 0.69) while the cache-off leg stays at
    # exactly 1.0 and greedy tokens stay bit-identical on/off, against
    # the static/reference oracle, and across the 1/2/4/8-device sweep
    exec python benchmarks/serve_bench.py \
      --requests 16 --arrival-rate 1.5 --seed 0 \
      --json BENCH_serve.json \
      --max-p99-ttft-cycles 5 --min-goodput 1.3 \
      --overload-sweep --overload-band 0.2 \
      --prefix-mix --max-computed-ratio 0.6 --min-prefix-hit-rate 0.5
    ;;
  chaos)
    # seeded fault injection (capacity squeezes, mid-stream cancels,
    # delayed retirement of the async decode) against the open-loop
    # engine: every fault is followed by the engine/pool invariant audit
    # (free lists partition capacity, no orphaned pages, tables
    # consistent — a violation exits non-zero) and survivors must
    # generate tokens identical to the fault-free run of the same
    # schedule
    exec python benchmarks/serve_bench.py \
      --seed 0 --chaos-seed 23 --chaos-only \
      --json BENCH_chaos.json
    ;;
  *)
    echo "unknown gate: $1 (want engine|serve|chaos)" >&2
    exit 2
    ;;
esac
