#!/usr/bin/env bash
# The ONE home for the CI bench-gate invocations. bench-smoke and
# bench-serve (.github/workflows/ci.yml) both run through here, so gate
# flags live in this file instead of drifting apart across workflow YAML —
# and a local repro is the same command CI ran:
#
#     benchmarks/ci_gates.sh engine   # bench-engine/v5 ratio/tile gates
#     benchmarks/ci_gates.sh serve    # bench-serve/v1 latency-SLO gates
#
# Both write their JSON record (BENCH_engine.json / BENCH_serve.json) into
# the repo root BEFORE exiting non-zero, so CI uploads it on pass and fail.
# Gate semantics are documented in benchmarks/README.md.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH=src
# both benches exercise the data-parallel-KV surface on forced host devices
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

case "${1:?usage: ci_gates.sh engine|serve}" in
  engine)
    exec python benchmarks/engine_bench.py \
      --requests 6 --max-new 4 \
      --json BENCH_engine.json \
      --min-traversal-ratio 1.9 \
      --enforce-tile-bound --min-tile-ratio 3.9 \
      --enforce-single-trace --max-kv-balance 1.25 \
      --min-coschedule-frac 0.75
    ;;
  serve)
    # open-loop latency SLOs in virtual-clock ticks (deterministic:
    # seeded arrivals + tick-based clock). Thresholds sit between the
    # measured tails — ooo p99 TTFT 2.8 ticks / goodput 1.588 tok/tick vs
    # static 8.8 / 1.080 at this rate — so the gate both enforces the SLO
    # and keeps proving the configurable port mix is what meets it.
    exec python benchmarks/serve_bench.py \
      --requests 16 --arrival-rate 1.5 --seed 0 \
      --json BENCH_serve.json \
      --max-p99-ttft-cycles 5 --min-goodput 1.3
    ;;
  *)
    echo "unknown gate: $1 (want engine|serve)" >&2
    exit 2
    ;;
esac
