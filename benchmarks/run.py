"""Run every benchmark. One section per paper table/figure; CSV lines of
``name,us_per_call,derived`` style. Roofline runs only when dry-run
artifacts exist (see repro.launch.dryrun)."""
from __future__ import annotations

import os
import traceback


def main() -> None:
    from benchmarks import bandwidth, engine_bench, footprint, kernels_bench

    sections = [
        ("bandwidth (Table II / C1)", bandwidth.main),
        ("footprint (Tables I-II / C2)", footprint.main),
        ("engine (system-level C1)", engine_bench.main),
        ("kernels (micro)", kernels_bench.main),
    ]
    if os.path.isdir("artifacts/dryrun") and os.listdir("artifacts/dryrun"):
        from benchmarks import roofline
        sections.append(("roofline (from dry-run artifacts)", roofline.main))

    failures = []
    for name, fn in sections:
        print(f"\n===== {name} =====")
        try:
            fn()
        except Exception:  # keep the harness going; fail at the end
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
