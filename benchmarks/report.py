"""Render EXPERIMENTS.md-ready markdown from dry-run artifacts:
§Roofline table (final code) and the hillclimb before/after comparison.

    PYTHONPATH=src python -m benchmarks.report [--baseline artifacts/dryrun_baseline]
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.roofline import analyze_record, load_all


def table(rows: list[dict]) -> str:
    out = ["| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | MFU@bottleneck |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR ||||||")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3g} | {r['memory_s']:.3g} "
            f"| {r['collective_s']:.3g} | {r['dominant']} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_mfu']:.4f} |")
    return "\n".join(out)


def compare(final_dir: str, base_dir: str, cells: list[str]) -> str:
    out = ["| cell | term | baseline | final | gain |",
           "|---|---|---|---|---|"]
    for cell in cells:
        fp = os.path.join(final_dir, cell + ".json")
        bp = os.path.join(base_dir, cell + ".json")
        if not (os.path.exists(fp) and os.path.exists(bp)):
            continue
        f = analyze_record(json.load(open(fp)))
        b = analyze_record(json.load(open(bp)))
        for term in ("compute_s", "memory_s", "collective_s"):
            gain = b[term] / max(f[term], 1e-12)
            out.append(f"| {cell} | {term[:-2]} | {b[term]:.3g} "
                       f"| {f[term]:.3g} | {gain:.1f}x |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--baseline", default="artifacts/dryrun_baseline")
    args = ap.parse_args()
    rows = load_all(args.out)
    print("## §Roofline (final code)\n")
    print(table(rows))
    n_err = sum("error" in r for r in rows)
    print(f"\n{len(rows) - n_err}/{len(rows)} cells ok\n")
    if os.path.isdir(args.baseline):
        print("## Hillclimb before/after (same analyzer where possible)\n")
        print(compare(args.out, args.baseline, [
            "zamba2-7b__train_4k__single",
            "llama3-405b__decode_32k__single",
            "deepseek-moe-16b__train_4k__single",
        ]))


if __name__ == "__main__":
    main()
