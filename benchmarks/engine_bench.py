"""System-level claim C1: the multi-port engine's fused (pallas) data plane
completes a request batch with ONE pool traversal per decode step where the
two-pass reference does >= 2, and the 4-port schedule finishes in fewer
macro-cycles (and less wall time) than single-port scheduling.

Reported per mode: macro-cycles, wall seconds, generated tokens,
cycles/token, physical pool traversals, traversals/token, and
traversals-per-decode-step (the headline C1 ratio: ~1 fused vs >= 2
reference).

A second section measures chunked batched prefill: admissions split into
fixed-size chunks share ONE bulk-write pool transaction per macro-cycle, so
prefill pool-traversals-per-admitted-token shrinks as the admission batch
grows — the multi-port scheduling win on the PREFILL port.

CI gate (see .github/workflows/ci.yml bench-smoke and benchmarks/README.md):

    python benchmarks/engine_bench.py --json BENCH_engine.json \
        --min-traversal-ratio 1.9

writes the ``bench-engine/v1`` record and exits non-zero if the fused-vs-
reference steady-decode traversal ratio drops below the gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine

MODES = (
    # (name, kernel_mode, single_port)
    ("pallas", "pallas", False),
    ("reference", "reference", False),
    ("single_port", "reference", True),
)

PREFILL_BATCHES = (1, 4, 8)


def _setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def run(n_requests: int = 8, max_new: int = 6) -> dict:
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(n_requests)]

    out = {}
    tokens_by_mode = {}
    for mode, kernel_mode, single in MODES:
        eng = MultiPortEngine(params, cfg, slots=4, max_len=64,
                              prefill_bucket=8, kernel_mode=kernel_mode,
                              single_port=single)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=5000)
        dt = time.perf_counter() - t0
        assert len(done) == n_requests
        toks = sum(len(r.generated) for r in done)
        tokens_by_mode[mode] = {r.rid: tuple(r.generated) for r in done}
        out[mode] = {
            "cycles": eng.cycles, "seconds": dt, "tokens": toks,
            "cycles_per_token": eng.cycles / toks,
            "pool_traversals": eng.pool_traversals,
            "traversals_per_token": eng.pool_traversals / toks,
            "traversals_per_decode": (eng.decode_traversals
                                      / max(eng.decode_steps, 1)),
            # steady state: decode cycles carrying both append + read ports
            "traversals_per_decode_steady": (eng.steady_decode_traversals
                                             / max(eng.steady_decode_steps,
                                                   1)),
        }
    # all modes must agree token-for-token (same greedy decode)
    assert (tokens_by_mode["pallas"] == tokens_by_mode["reference"]
            == tokens_by_mode["single_port"]), "modes disagree on tokens"
    out["cycle_ratio"] = (out["single_port"]["cycles"]
                          / out["pallas"]["cycles"])
    out["traversal_ratio"] = (
        out["reference"]["traversals_per_decode_steady"]
        / out["pallas"]["traversals_per_decode_steady"])
    return out


def run_prefill(batch_sizes=PREFILL_BATCHES, prompt_len: int = 24,
                chunk_tokens: int = 8) -> dict:
    """Chunked batched prefill: pool traversals per admitted prompt token as
    the concurrent admission batch grows (slot pool growing past the seed's
    4 along the way)."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    out = {"prompt_len": prompt_len, "chunk_tokens": chunk_tokens,
           "per_batch": {}}
    for n in batch_sizes:
        eng = MultiPortEngine(params, cfg, slots=1, max_slots=max(n, 1),
                              max_len=64, chunk_tokens=chunk_tokens)
        for _ in range(n):
            eng.submit(list(rng.integers(0, cfg.vocab, prompt_len)),
                       max_new=1)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=2000)
        dt = time.perf_counter() - t0
        assert len(done) == n
        out["per_batch"][str(n)] = {
            "seconds": dt,
            "prefill_tokens": eng.prefill_tokens,
            "prefill_cycles": eng.prefill_steps,
            "prefill_traversals": eng.prefill_traversals,
            "traversals_per_token": (eng.prefill_traversals
                                     / max(eng.prefill_tokens, 1)),
            "grown_slots": eng.n_slots,
        }
    return out


def report(r: dict, pf: dict) -> None:
    print("# serving engine: fused multi-port vs reference vs single-port "
          "(claim C1)")
    print("mode,cycles,seconds,tokens,cycles/token,pool_traversals,"
          "traversals/token,traversals/decode,traversals/decode(steady)")
    for m, _, _ in MODES:
        x = r[m]
        print(f"{m},{x['cycles']},{x['seconds']:.3f},{x['tokens']},"
              f"{x['cycles_per_token']:.2f},{x['pool_traversals']},"
              f"{x['traversals_per_token']:.2f},"
              f"{x['traversals_per_decode']:.2f},"
              f"{x['traversals_per_decode_steady']:.2f}")
    print(f"cycle_ratio,{r['cycle_ratio']:.2f}")
    print(f"traversal_ratio,{r['traversal_ratio']:.2f}")
    print()
    print("# chunked batched prefill: pool traversals per admitted token "
          f"(prompt_len={pf['prompt_len']}, chunk={pf['chunk_tokens']})")
    print("batch,prefill_cycles,prefill_traversals,prefill_tokens,"
          "traversals/token,grown_slots")
    for n, x in pf["per_batch"].items():
        print(f"{n},{x['prefill_cycles']},{x['prefill_traversals']},"
              f"{x['prefill_tokens']},{x['traversals_per_token']:.3f},"
              f"{x['grown_slots']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the bench-engine/v1 record (BENCH_engine.json)")
    ap.add_argument("--min-traversal-ratio", type=float, default=None,
                    help="exit non-zero if fused-vs-reference steady-decode "
                         "traversal ratio drops below this gate")
    args = ap.parse_args(argv)

    r = run(args.requests, args.max_new)
    pf = run_prefill()
    report(r, pf)

    if args.json:
        per_tok = [pf["per_batch"][str(n)]["traversals_per_token"]
                   for n in PREFILL_BATCHES]
        record = {
            "schema": "bench-engine/v1",
            "config": {"arch": "tinyllama-1.1b", "reduced": True,
                       "requests": args.requests, "max_new": args.max_new},
            "decode": {m: r[m] for m, _, _ in MODES},
            "cycle_ratio": r["cycle_ratio"],
            "traversal_ratio": r["traversal_ratio"],
            "prefill": pf,
            "gate": {
                "min_traversal_ratio": args.min_traversal_ratio,
                "traversal_ratio": r["traversal_ratio"],
                "prefill_traversals_per_token_monotonic":
                    all(a >= b for a, b in zip(per_tok, per_tok[1:])),
            },
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\nwrote {args.json}")

    if args.min_traversal_ratio is not None:
        if r["traversal_ratio"] < args.min_traversal_ratio:
            print(f"GATE FAIL: traversal_ratio {r['traversal_ratio']:.2f} < "
                  f"{args.min_traversal_ratio}", file=sys.stderr)
            sys.exit(1)
        print(f"GATE OK: traversal_ratio {r['traversal_ratio']:.2f} >= "
              f"{args.min_traversal_ratio}")


if __name__ == "__main__":
    main()
