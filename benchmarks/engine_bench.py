"""System-level claim C1: the multi-port engine's fused (pallas) data plane
completes a request batch with ONE pool traversal per decode step where the
two-pass reference does >= 2, and the 4-port schedule finishes in fewer
macro-cycles (and less wall time) than single-port scheduling.

Reported per mode: macro-cycles, wall seconds, generated tokens,
cycles/token, physical pool traversals, traversals/token,
traversals-per-decode-step (the headline C1 ratio: ~1 fused vs >= 2
reference), and seq_tile-tile reads per steady decode step (the
length-bounded-traversal metric: the fused kernel touches only live tiles).

A second section measures chunked batched prefill: admissions split into
fixed-size chunks share ONE bulk-write pool transaction per macro-cycle, so
prefill pool-traversals-per-admitted-token shrinks as the admission batch
grows — and the fused chunk kernel reads only live tiles per chunk where the
dense reference reads the whole S_max staging cache.

A third section sweeps decode tile reads against cache length: the
length-bounded kernel's read traffic tracks cache_len while the unbounded
kernel pays the full allocated capacity every step (>= 4x fewer tile reads
at cache_len = S_max/8).

A fourth section counts JIT TRACES across a cache-length sweep: the
dynamic-grid kernels (live bound read from SMEM at run time) serve every
cache length from ONE decode trace, where the bucketed fallback retraces
once per power-of-two stage-length bucket.

A fifth section measures DATA-PARALLEL KV: the paged
pool sharded page-aligned across a ``kv`` mesh (forced host devices on CPU
CI), kernels shard_map'd by home device. It reports the per-device steady-
decode tile-read balance (max device / per-device mean; 1.0 = ideal) and
re-checks the headline gates UNDER SHARDING: fused-vs-reference traversal
ratio, the tile budget, the bounded-vs-unbounded tile ratio, the
single-trace property, and token identity against the unsharded engine.
Needs > 1 visible device (``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` on CPU); with one device the section records itself as skipped
and the sharded gates no-op.

A sixth section (this schema revision) measures the CONFIGURABLE PORT MIX:
a mixed prefill+decode workload with STAGGERED prompt lengths keeps some
slots mid-prefill while others decode, and the dependency-tracked macro-
cycle scheduler (``schedule_mode='ooo'``) merges hazard-free phases —
eviction frees, bulk-fill prefill writes, decode append/read of disjoint
pages — into shared pool traversals with arbitrary 1-4-port mixes. It
reports pool traversals per macro-cycle and per token, the co-scheduled
fraction of multi-phase cycles, and the per-mix traversal histogram
(e.g. ``3-port[2W+1R|...]``) against the rigid one-traversal-per-phase
``'static'`` walk and against reduced port budgets (``max_ports`` = 2, 1).

A seventh section (this schema revision) measures SPLIT-KV FLASH-DECODE on
a LONG-CONTEXT workload: one near-capacity prompt among short ones makes a
single row's serial tile chain the critical path of every steady decode
step. ``num_kv_splits`` partitions each row's live range into grid-parallel
partial-attention banks (combined by a second LSE pass), so the critical
path shrinks to ``ceil(chain / splits) + 1`` while the tiles SERVICED stay
identical — the latency proxy (critical-path tiles per steady decode step)
is what improves, the bandwidth accounting is unchanged, and greedy decode
stays token-identical at every split count.

CI gate (see .github/workflows/ci.yml bench-smoke and benchmarks/README.md):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/engine_bench.py --json BENCH_engine.json \
        --min-traversal-ratio 1.9 --enforce-tile-bound --min-tile-ratio 3.9 \
        --enforce-single-trace --max-kv-balance 1.25 \
        --min-coschedule-frac 0.75 --min-split-speedup 2.0

writes the ``bench-engine/v6`` record and exits non-zero if the fused-vs-
reference steady-decode traversal ratio, the steady-decode tile budget
(ceil((cache_len+1)/seq_tile) per step), the bounded-vs-unbounded tile
ratio at cache_len = S_max/8, the single-trace property of the dynamic-grid
decode path, the sharded per-device tile-read balance, the scheduler's
co-scheduled-cycle fraction / traversals-per-cycle advantage, or the
split-KV critical-path speedup on the long-context sweep regresses.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine

MODES = (
    # (name, kernel_mode, single_port)
    ("pallas", "pallas", False),
    ("reference", "reference", False),
    ("single_port", "reference", True),
)

PREFILL_BATCHES = (1, 4, 8)

# tile sweep workload: S_max and the tile size the decode kernel traverses
TILE_S_MAX = 64
TILE_SEQ = 8
# steady decode cache_len targets as fractions of S_max
TILE_FRACS = (8, 4, 2)


def _setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def run(n_requests: int = 8, max_new: int = 6) -> dict:
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(n_requests)]

    out = {}
    tokens_by_mode = {}
    for mode, kernel_mode, single in MODES:
        eng = MultiPortEngine(params, cfg, slots=4, max_len=64,
                              prefill_bucket=8, seq_tile=TILE_SEQ,
                              kernel_mode=kernel_mode, single_port=single)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=5000)
        dt = time.perf_counter() - t0
        assert len(done) == n_requests
        toks = sum(len(r.generated) for r in done)
        tokens_by_mode[mode] = {r.rid: tuple(r.generated) for r in done}
        steady = max(eng.steady_decode_steps, 1)
        out[mode] = {
            "cycles": eng.cycles, "seconds": dt, "tokens": toks,
            "cycles_per_token": eng.cycles / toks,
            "pool_traversals": eng.pool_traversals,
            "traversals_per_token": eng.pool_traversals / toks,
            "traversals_per_decode": (eng.decode_traversals
                                      / max(eng.decode_steps, 1)),
            # steady state: decode cycles carrying both append + read ports
            "traversals_per_decode_steady": (eng.steady_decode_traversals
                                             / steady),
            # length-bounded traversal accounting (seq_tile tiles the decode
            # R port touches vs the ideal ceil((cache_len+1)/seq_tile) budget)
            "seq_tile": eng.seq_tile,
            "tile_reads": eng.decode_tile_reads,
            "tile_reads_per_decode_steady": (eng.steady_decode_tile_reads
                                             / steady),
            "tile_bound_per_decode_steady": (eng.steady_decode_tile_bound
                                             / steady),
            "within_tile_bound": (eng.steady_decode_tile_reads
                                  <= eng.steady_decode_tile_bound),
            "pool_tile_reads": eng.pool.tile_reads,
            "pool_tile_writes": eng.pool.tile_writes,
            # jit retraces of the decode / chunk steps over the whole run
            "decode_traces": eng.decode_traces,
            "prefill_traces": eng.prefill_traces,
            "dynamic_grid": eng.dynamic_grid,
        }
    # all modes must agree token-for-token (same greedy decode)
    assert (tokens_by_mode["pallas"] == tokens_by_mode["reference"]
            == tokens_by_mode["single_port"]), "modes disagree on tokens"
    out["cycle_ratio"] = (out["single_port"]["cycles"]
                          / out["pallas"]["cycles"])
    out["traversal_ratio"] = (
        out["reference"]["traversals_per_decode_steady"]
        / out["pallas"]["traversals_per_decode_steady"])
    return out


def run_prefill(batch_sizes=PREFILL_BATCHES, prompt_len: int = 24,
                chunk_tokens: int = 8) -> dict:
    """Chunked batched prefill: pool traversals per admitted prompt token as
    the concurrent admission batch grows (slot pool growing past the seed's
    4 along the way), plus tile reads per chunk — the fused chunk kernel
    touches only live tiles where the dense reference reads all of S_max."""
    cfg, params = _setup()
    rng = np.random.default_rng(1)
    dense_tiles = -(-TILE_S_MAX // TILE_SEQ)
    out = {"prompt_len": prompt_len, "chunk_tokens": chunk_tokens,
           "seq_tile": TILE_SEQ, "dense_tiles_per_chunk": dense_tiles,
           "per_batch": {}}
    for n in batch_sizes:
        eng = MultiPortEngine(params, cfg, slots=1, max_slots=max(n, 1),
                              max_len=TILE_S_MAX, chunk_tokens=chunk_tokens,
                              seq_tile=TILE_SEQ)
        for _ in range(n):
            eng.submit(list(rng.integers(0, cfg.vocab, prompt_len)),
                       max_new=1)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=2000)
        dt = time.perf_counter() - t0
        assert len(done) == n
        out["per_batch"][str(n)] = {
            "seconds": dt,
            "prefill_tokens": eng.prefill_tokens,
            "prefill_cycles": eng.prefill_steps,
            "prefill_traversals": eng.prefill_traversals,
            "traversals_per_token": (eng.prefill_traversals
                                     / max(eng.prefill_tokens, 1)),
            "tile_reads_per_chunk": (eng.prefill_tile_reads
                                     / max(eng.prefill_chunks, 1)),
            "grown_slots": eng.n_slots,
        }
    return out


def measure_kernel_tiles() -> dict:
    """Direct KERNEL-MEASURED serviced-tile check — the teeth behind
    ``--enforce-tile-bound``. The engine's per-step counters are host-side
    accounting of the kernels' skip formula; this probe asks the kernels
    themselves (``return_tiles``) how many tiles they serviced for a
    steady-decode-shaped batch (including a dead padded row) and for one
    prefill chunk, and compares against the ceil budgets. A kernel
    regression that stops skipping dead tiles fails HERE, in the bench job,
    independent of the tier-1 suite."""
    import jax.numpy as jnp

    from repro.kernels.kv_multiport import fused_append_attend
    from repro.kernels.kv_prefill_chunk import fused_chunk_append_attend

    rng = np.random.default_rng(3)
    s, tile, hkv, g, d = TILE_S_MAX, TILE_SEQ, 2, 2, 16
    h = hkv * g

    lens = np.array([s // 8, s // 4, s // 2 - 1, -1])     # last row = padding
    q = jnp.asarray(rng.normal(size=(4, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(4, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(4, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(4, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(4, hkv, d)), jnp.float32)
    *_, dec = fused_append_attend(q, ck, cv, nk, nv,
                                  jnp.asarray(lens, jnp.int32),
                                  seq_tile=tile, return_tiles=True)
    dec_budget = [int(-(-(p + 1) // tile)) if p >= 0 else 0 for p in lens]

    c = 4
    offs = np.array([0, s // 4, -1])                      # last row = padding
    cls = np.array([c, c - 1, 0])
    qc = jnp.asarray(rng.normal(size=(3, c, h, d)), jnp.float32)
    ck3, cv3 = ck[:3], cv[:3]
    nk3 = jnp.asarray(rng.normal(size=(3, c, hkv, d)), jnp.float32)
    nv3 = jnp.asarray(rng.normal(size=(3, c, hkv, d)), jnp.float32)
    *_, pf = fused_chunk_append_attend(qc, ck3, cv3, nk3, nv3,
                                       jnp.asarray(offs, jnp.int32),
                                       jnp.asarray(cls, jnp.int32),
                                       seq_tile=tile, return_tiles=True)
    pf_budget = [int(-(-(o + n) // tile)) if o >= 0 else 0
                 for o, n in zip(offs, cls)]

    dec, pf = np.asarray(dec).tolist(), np.asarray(pf).tolist()
    return {"seq_tile": tile, "s_max": s,
            "decode_measured": dec, "decode_budget": dec_budget,
            "prefill_measured": pf, "prefill_budget": pf_budget,
            "within": (all(m <= b for m, b in zip(dec, dec_budget))
                       and all(m <= b for m, b in zip(pf, pf_budget)))}


def run_tiles(max_new: int = 4, requests: int = 4) -> dict:
    """Decode read traffic vs live cache length: steady-decode tile reads
    per step per slot for the length-bounded kernel against the unbounded
    traversal, at cache_len targets S_max/8, S_max/4, S_max/2."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    out = {"s_max": TILE_S_MAX, "seq_tile": TILE_SEQ, "per_cache_len": {}}

    def measure(prompt_len, length_bound):
        eng = MultiPortEngine(params, cfg, slots=requests,
                              max_len=TILE_S_MAX, seq_tile=TILE_SEQ,
                              chunk_tokens=8, length_bound=length_bound)
        for _ in range(requests):
            eng.submit(list(rng.integers(0, cfg.vocab, prompt_len)),
                       max_new=max_new)
        done = eng.run(max_cycles=2000)
        assert len(done) == requests
        steps = max(eng.steady_decode_steps, 1)
        return {
            "tile_reads_per_step": (eng.steady_decode_tile_reads
                                    / steps / requests),
            "tile_bound_per_step": (eng.steady_decode_tile_bound
                                    / steps / requests),
            "within_tile_bound": (eng.steady_decode_tile_reads
                                  <= eng.steady_decode_tile_bound),
            "decode_traces": eng.decode_traces,
        }

    for frac in TILE_FRACS:
        target = TILE_S_MAX // frac
        prompt_len = max(2, target - max_new // 2)
        bounded = measure(prompt_len, True)
        unbounded = measure(prompt_len, False)
        out["per_cache_len"][str(target)] = {
            "prompt_len": prompt_len,
            "bounded": bounded,
            "unbounded": unbounded,
            "tile_ratio": (unbounded["tile_reads_per_step"]
                           / max(bounded["tile_reads_per_step"], 1e-9)),
        }
    # headline: the ratio at cache_len = S_max/8
    out["tile_ratio_at_s8"] = (
        out["per_cache_len"][str(TILE_S_MAX // 8)]["tile_ratio"])
    out["kernel_measured"] = measure_kernel_tiles()
    return out


def run_kv_balance(n_requests: int = 8, prompt_len: int = 5,
                   max_new: int = 6) -> dict:
    """Data-parallel KV: shard the pool (and the kernels) across the
    largest power-of-two count of visible devices (<= 8) and measure the
    per-device steady-decode tile-read balance plus the headline gates
    UNDER SHARDING. Equal-length prompts and one request per slot make the
    ideal balance 1.0 — the gate budget (1.25x) leaves room only for
    admission-order skew, not systematic imbalance."""
    avail = len(jax.devices())
    shards = 1
    while shards * 2 <= min(avail, 8):
        shards *= 2
    out = {"available_devices": avail, "kv_shards": shards,
           "s_max": TILE_S_MAX, "seq_tile": TILE_SEQ,
           "prompt_len": prompt_len, "requests": n_requests}
    if shards == 1:
        out.update({"skipped": True, "balance": 1.0})
        return out

    from repro.launch.mesh import make_kv_mesh
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    prompts = [list(rng.integers(0, cfg.vocab, prompt_len))
               for _ in range(n_requests)]
    mesh = make_kv_mesh(shards)

    def serve(kernel_mode, use_mesh, length_bound=True):
        eng = MultiPortEngine(params, cfg, slots=n_requests,
                              max_len=TILE_S_MAX, seq_tile=TILE_SEQ,
                              chunk_tokens=8, kernel_mode=kernel_mode,
                              length_bound=length_bound,
                              mesh=mesh if use_mesh else None)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        done = eng.run(max_cycles=2000)
        # completion/identity failures are RECORDED, not raised: the JSON
        # record and the gate diagnostics must materialize on regressions
        # too (CI uploads the artifact precisely when a gate fails)
        return eng, (len(done) == n_requests,
                     {r.rid: tuple(r.generated) for r in done})

    ep, (ok_p, tp) = serve("pallas", True)
    er, (ok_r, tr) = serve("reference", True)
    e1, (ok_1, t1) = serve("pallas", False)
    eu, (ok_u, tu) = serve("pallas", True, length_bound=False)
    steady = max(ep.steady_decode_steps, 1)
    out.update({
        "skipped": False,
        "completed": ok_p and ok_r and ok_1 and ok_u,
        "tokens_match_unsharded": tp == tr == t1 == tu
        and ok_p and ok_r and ok_1 and ok_u,
        "balance": ep.kv_tile_balance,
        "tile_reads_by_dev": list(ep.steady_decode_tile_reads_by_dev),
        "pool_tile_reads_by_shard": list(ep.pool.tile_reads_by_shard),
        "pool_tile_writes_by_shard": list(ep.pool.tile_writes_by_shard),
        "pages_per_shard": ep.pool.plan.pages_per_shard,
        # max(..., 1e-9) denominators: a stalled sharded engine must surface
        # as a failed gate with a written record, never a ZeroDivisionError
        "traversal_ratio": (er.steady_decode_traversals
                            / max(er.steady_decode_steps, 1)
                            / max(ep.steady_decode_traversals / steady,
                                  1e-9)),
        "within_tile_bound": (ep.steady_decode_tile_reads
                              <= ep.steady_decode_tile_bound),
        "tile_ratio": (eu.steady_decode_tile_reads
                       / max(eu.steady_decode_steps, 1)
                       / max(ep.steady_decode_tile_reads / steady, 1e-9)),
        "decode_traces": ep.decode_traces,
    })
    return out


SCHEDULE_PROMPT_LENS = (6, 14, 22, 30)


def run_schedule(prompt_lens=SCHEDULE_PROMPT_LENS, max_new: int = 10,
                 chunk_tokens: int = 8) -> dict:
    """Configurable per-cycle port mix: the dependency-tracked macro-cycle
    scheduler (``schedule_mode='ooo'``) against the rigid one-traversal-per-
    phase walk (``'static'``). STAGGERED prompt lengths with a small prefill
    chunk keep some slots mid-prefill while others decode, so macro-cycles
    carry evict + bulk-fill + decode phases together; the scheduler merges
    the hazard-free ones (disjoint page footprints) into shared pool
    traversals with up-to-4-port mixes (e.g. ``2W+1R``). Reported per
    config: pool traversals, traversals per macro-cycle and per token, the
    fraction of multi-phase cycles that actually co-scheduled, and the
    per-mix traversal histogram. Greedy decode must stay token-identical
    across every schedule mode, kernel mode, and port budget."""
    cfg, params = _setup()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, plen)) for plen in prompt_lens]
    configs = (
        # (name, kernel_mode, schedule_mode, max_ports)
        ("pallas_ooo", "pallas", "ooo", 4),
        ("pallas_static", "pallas", "static", 4),
        ("reference_ooo", "reference", "ooo", 4),
        ("reference_static", "reference", "static", 4),
        ("pallas_ooo_2port", "pallas", "ooo", 2),
        ("pallas_ooo_1port", "pallas", "ooo", 1),
    )
    out = {"prompt_lens": list(prompt_lens), "max_new": max_new,
           "chunk_tokens": chunk_tokens, "s_max": TILE_S_MAX,
           "seq_tile": TILE_SEQ, "per_config": {}}
    tokens_by_config = {}
    for name, kernel_mode, schedule_mode, max_ports in configs:
        eng = MultiPortEngine(params, cfg, slots=len(prompts),
                              max_len=TILE_S_MAX, seq_tile=TILE_SEQ,
                              chunk_tokens=chunk_tokens,
                              kernel_mode=kernel_mode,
                              schedule_mode=schedule_mode,
                              max_ports=max_ports)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=2000)
        dt = time.perf_counter() - t0
        assert len(done) == len(prompts)
        toks = sum(len(r.generated) for r in done)
        tokens_by_config[name] = {r.rid: tuple(r.generated) for r in done}
        out["per_config"][name] = {
            "kernel_mode": kernel_mode, "schedule_mode": schedule_mode,
            "max_ports": max_ports, "seconds": dt, "tokens": toks,
            "cycles": eng.cycles,
            "pool_traversals": eng.pool_traversals,
            "traversals_per_cycle": eng.pool_traversals / max(eng.cycles, 1),
            "traversals_per_token": eng.pool_traversals / max(toks, 1),
            "multi_phase_cycles": eng.multi_phase_cycles,
            "coscheduled_cycles": eng.coscheduled_cycles,
            "coschedule_frac": eng.coschedule_frac,
            "mix_counts": dict(sorted(eng.pool.mix_counts.items())),
        }
    first = next(iter(tokens_by_config.values()))
    out["tokens_match"] = all(t == first for t in tokens_by_config.values())
    pc = out["per_config"]
    # headline: OOO pool traversals per macro-cycle vs the static oracle,
    # same kernel mode (pallas fused path)
    out["traversals_per_cycle_ooo"] = pc["pallas_ooo"]["traversals_per_cycle"]
    out["traversals_per_cycle_static"] = (
        pc["pallas_static"]["traversals_per_cycle"])
    out["coschedule_frac"] = pc["pallas_ooo"]["coschedule_frac"]
    return out


SPLIT_S_MAX = 128
SPLIT_COUNTS = (1, 2, 4)
SPLIT_PROMPT_LENS = (88, 6, 6, 6)


def run_split(prompt_lens=SPLIT_PROMPT_LENS, max_new: int = 4,
              splits=SPLIT_COUNTS) -> dict:
    """Split-KV flash-decode on a long-context sweep: ONE near-capacity
    prompt among short ones makes its serial tile chain (ceil(cache_len /
    seq_tile) tiles, walked in order for the online-softmax dependency) the
    critical path of every steady decode step. ``num_kv_splits`` breaks the
    chain into grid-parallel partial-attention banks plus one LSE-combine
    pass, so the latency proxy — critical-path tiles per steady decode step
    — drops toward ``ceil(chain / splits) + 1`` while tiles SERVICED (the
    bandwidth accounting the tile-bound gate budgets) are identical at
    every split count, and greedy decode stays token-identical."""
    cfg, params = _setup()
    rng = np.random.default_rng(6)
    prompts = [list(rng.integers(0, cfg.vocab, n)) for n in prompt_lens]
    out = {"s_max": SPLIT_S_MAX, "seq_tile": TILE_SEQ,
           "prompt_lens": list(prompt_lens), "max_new": max_new,
           "per_splits": {}}
    tokens = {}
    for ns in splits:
        eng = MultiPortEngine(params, cfg, slots=len(prompts),
                              max_len=SPLIT_S_MAX, seq_tile=TILE_SEQ,
                              chunk_tokens=8, num_kv_splits=ns)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=2000)
        dt = time.perf_counter() - t0
        assert len(done) == len(prompts)
        tokens[ns] = {r.rid: tuple(r.generated) for r in done}
        steady = max(eng.steady_decode_steps, 1)
        out["per_splits"][str(ns)] = {
            "seconds": dt,
            "critical_tiles_per_step": (eng.steady_decode_critical_tiles
                                        / steady),
            "tile_reads_per_step": (eng.steady_decode_tile_reads / steady),
            "within_tile_bound": (eng.steady_decode_tile_reads
                                  <= eng.steady_decode_tile_bound),
        }
    base = out["per_splits"][str(splits[0])]
    best = out["per_splits"][str(max(splits))]
    out["tokens_match"] = all(t == tokens[splits[0]]
                              for t in tokens.values())
    # the split path must not change WHAT is read, only how it is chained
    out["tile_reads_match"] = all(
        x["tile_reads_per_step"] == base["tile_reads_per_step"]
        for x in out["per_splits"].values())
    out["split_speedup"] = (base["critical_tiles_per_step"]
                            / max(best["critical_tiles_per_step"], 1e-9))
    return out


def run_traces(prompt_lens=(6, 20, 40), max_new: int = 4,
               requests: int = 4) -> dict:
    """Retrace accounting across a cache-length sweep: the SAME engine
    serves waves of requests whose live lengths cross several stage-length
    buckets. The dynamic-grid path (default) keeps ONE decode trace — the
    live bound is a runtime scalar read from SMEM — while the bucketed
    fallback retraces once per power-of-two tile bucket it visits."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)

    def sweep(dynamic_grid):
        eng = MultiPortEngine(params, cfg, slots=requests,
                              max_len=TILE_S_MAX, seq_tile=TILE_SEQ,
                              chunk_tokens=8, dynamic_grid=dynamic_grid)
        for plen in prompt_lens:
            for _ in range(requests):
                eng.submit(list(rng.integers(0, cfg.vocab, plen)),
                           max_new=max_new)
            done = eng.run(max_cycles=2000)
        assert len(done) == requests * len(prompt_lens)
        return {"decode_traces": eng.decode_traces,
                "prefill_traces": eng.prefill_traces,
                "stage_lens": sorted(eng.stage_lens_seen),
                "steady_within_bound": (eng.steady_decode_tile_reads
                                        <= eng.steady_decode_tile_bound)}

    return {"s_max": TILE_S_MAX, "seq_tile": TILE_SEQ,
            "prompt_lens": list(prompt_lens),
            "dynamic": sweep(True), "bucketed": sweep(False)}


def report(r: dict, pf: dict, tl: dict, tr: dict, kv: dict,
           sc: dict, sk: dict) -> None:
    print("# serving engine: fused multi-port vs reference vs single-port "
          "(claim C1)")
    print("mode,cycles,seconds,tokens,cycles/token,pool_traversals,"
          "traversals/token,traversals/decode,traversals/decode(steady),"
          "tiles/decode(steady),tile_bound(steady),decode_traces")
    for m, _, _ in MODES:
        x = r[m]
        print(f"{m},{x['cycles']},{x['seconds']:.3f},{x['tokens']},"
              f"{x['cycles_per_token']:.2f},{x['pool_traversals']},"
              f"{x['traversals_per_token']:.2f},"
              f"{x['traversals_per_decode']:.2f},"
              f"{x['traversals_per_decode_steady']:.2f},"
              f"{x['tile_reads_per_decode_steady']:.2f},"
              f"{x['tile_bound_per_decode_steady']:.2f},"
              f"{x['decode_traces']}")
    print(f"cycle_ratio,{r['cycle_ratio']:.2f}")
    print(f"traversal_ratio,{r['traversal_ratio']:.2f}")
    print()
    print("# chunked batched prefill: pool traversals per admitted token "
          f"(prompt_len={pf['prompt_len']}, chunk={pf['chunk_tokens']}); "
          f"fused chunk tile reads vs {pf['dense_tiles_per_chunk']} dense "
          "tiles/chunk")
    print("batch,prefill_cycles,prefill_traversals,prefill_tokens,"
          "traversals/token,tiles/chunk,grown_slots")
    for n, x in pf["per_batch"].items():
        print(f"{n},{x['prefill_cycles']},{x['prefill_traversals']},"
              f"{x['prefill_tokens']},{x['traversals_per_token']:.3f},"
              f"{x['tile_reads_per_chunk']:.2f},{x['grown_slots']}")
    print()
    print("# length-bounded decode: steady tile reads/step/slot vs "
          f"cache_len (S_max={tl['s_max']}, seq_tile={tl['seq_tile']})")
    print("cache_len,bounded_tiles,unbounded_tiles,tile_bound,tile_ratio,"
          "decode_traces(bounded)")
    for cl, x in tl["per_cache_len"].items():
        print(f"{cl},{x['bounded']['tile_reads_per_step']:.2f},"
              f"{x['unbounded']['tile_reads_per_step']:.2f},"
              f"{x['bounded']['tile_bound_per_step']:.2f},"
              f"{x['tile_ratio']:.2f},{x['bounded']['decode_traces']}")
    print(f"tile_ratio_at_s8,{tl['tile_ratio_at_s8']:.2f}")
    km = tl["kernel_measured"]
    print(f"kernel_measured: decode {km['decode_measured']} <= "
          f"{km['decode_budget']}, prefill {km['prefill_measured']} <= "
          f"{km['prefill_budget']} -> within={km['within']}")
    print()
    print("# retrace accounting: one engine, cache lengths swept across "
          f"buckets (prompt_lens={tr['prompt_lens']}, S_max={tr['s_max']}, "
          f"seq_tile={tr['seq_tile']})")
    print("path,decode_traces,prefill_traces,stage_lens")
    for name in ("dynamic", "bucketed"):
        x = tr[name]
        print(f"{name},{x['decode_traces']},{x['prefill_traces']},"
              f"{'/'.join(map(str, x['stage_lens']))}")
    print()
    print("# configurable port mix: dependency-tracked scheduler (ooo) vs "
          f"rigid walk (static); staggered prompts {sc['prompt_lens']}, "
          f"chunk={sc['chunk_tokens']}, max_new={sc['max_new']}")
    print("config,cycles,pool_traversals,traversals/cycle,traversals/token,"
          "coscheduled/multi_phase,coschedule_frac,mixes")
    for name, x in sc["per_config"].items():
        mixes = " ".join(f"{k}:{v}" for k, v in x["mix_counts"].items())
        print(f"{name},{x['cycles']},{x['pool_traversals']},"
              f"{x['traversals_per_cycle']:.3f},"
              f"{x['traversals_per_token']:.3f},"
              f"{x['coscheduled_cycles']}/{x['multi_phase_cycles']},"
              f"{x['coschedule_frac']:.2f},{mixes}")
    print(f"tokens_match,{sc['tokens_match']}")
    print()
    print("# split-KV flash-decode: critical-path tiles per steady decode "
          f"step vs num_kv_splits (prompts {sk['prompt_lens']}, "
          f"S_max={sk['s_max']}, seq_tile={sk['seq_tile']})")
    print("num_kv_splits,critical_tiles/step,tile_reads/step,"
          "within_tile_bound")
    for ns, x in sk["per_splits"].items():
        print(f"{ns},{x['critical_tiles_per_step']:.2f},"
              f"{x['tile_reads_per_step']:.2f},{x['within_tile_bound']}")
    print(f"split_speedup,{sk['split_speedup']:.2f}")
    print(f"tokens_match,{sk['tokens_match']}")
    print(f"tile_reads_match,{sk['tile_reads_match']}")
    print()
    print(f"# data-parallel KV: pool page-aligned over {kv['kv_shards']} "
          f"device(s) of {kv['available_devices']} visible "
          f"(S_max={kv['s_max']}, seq_tile={kv['seq_tile']})")
    if kv.get("skipped"):
        print("skipped: needs > 1 device (set XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 before jax init)")
    else:
        print("tile_reads_by_dev,balance,traversal_ratio,tile_ratio,"
              "within_tile_bound,decode_traces,tokens_match_unsharded")
        print(f"{'/'.join(map(str, kv['tile_reads_by_dev']))},"
              f"{kv['balance']:.2f},{kv['traversal_ratio']:.2f},"
              f"{kv['tile_ratio']:.2f},{kv['within_tile_bound']},"
              f"{kv['decode_traces']},{kv['tokens_match_unsharded']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the bench-engine/v6 record (BENCH_engine.json)")
    ap.add_argument("--min-traversal-ratio", type=float, default=None,
                    help="exit non-zero if fused-vs-reference steady-decode "
                         "traversal ratio drops below this gate")
    ap.add_argument("--enforce-tile-bound", action="store_true",
                    help="exit non-zero if fused steady-decode tile reads "
                         "exceed ceil((cache_len+1)/seq_tile) per step")
    ap.add_argument("--min-tile-ratio", type=float, default=None,
                    help="exit non-zero if bounded-vs-unbounded decode tile "
                         "reads at cache_len=S_max/8 drop below this gate")
    ap.add_argument("--enforce-single-trace", action="store_true",
                    help="exit non-zero if the dynamic-grid decode path "
                         "needs more than ONE jit trace across the "
                         "cache-length sweep")
    ap.add_argument("--min-coschedule-frac", type=float, default=None,
                    help="exit non-zero if the ooo scheduler co-schedules "
                         "fewer than this fraction of multi-phase macro-"
                         "cycles on the mixed prefill+decode workload, if "
                         "ooo fails to commit strictly fewer pool "
                         "traversals per macro-cycle than the static walk, "
                         "or if any schedule config disagrees on tokens")
    ap.add_argument("--max-kv-balance", type=float, default=None,
                    help="exit non-zero if the sharded per-device steady-"
                         "decode tile-read balance (max/mean) exceeds this, "
                         "or any sharded headline gate (traversal/tile/"
                         "trace/token identity) regresses; skipped with a "
                         "warning when only one device is visible")
    ap.add_argument("--min-split-speedup", type=float, default=None,
                    help="exit non-zero if split-KV decode's critical-path "
                         "latency proxy on the long-context sweep improves "
                         "by less than this factor at the largest split "
                         "count, if the split path changes the serviced "
                         "tile accounting, or if any split count disagrees "
                         "on tokens")
    args = ap.parse_args(argv)

    r = run(args.requests, args.max_new)
    pf = run_prefill()
    tl = run_tiles()
    tr = run_traces()
    kv = run_kv_balance()
    sc = run_schedule()
    sk = run_split()
    report(r, pf, tl, tr, kv, sc, sk)

    # the gate combines the engine's accounting invariant with the DIRECT
    # kernel-measured serviced-tile probe (the part that can actually catch
    # a kernel that stops skipping dead tiles)
    tile_bound_ok = (r["pallas"]["within_tile_bound"]
                     and all(x["bounded"]["within_tile_bound"]
                             for x in tl["per_cache_len"].values())
                     and tl["kernel_measured"]["within"])
    if args.json:
        per_tok = [pf["per_batch"][str(n)]["traversals_per_token"]
                   for n in PREFILL_BATCHES]
        record = {
            "schema": "bench-engine/v6",
            "config": {"arch": "tinyllama-1.1b", "reduced": True,
                       "requests": args.requests, "max_new": args.max_new,
                       "seq_tile": TILE_SEQ, "s_max": TILE_S_MAX},
            "decode": {m: r[m] for m, _, _ in MODES},
            "cycle_ratio": r["cycle_ratio"],
            "traversal_ratio": r["traversal_ratio"],
            "prefill": pf,
            "tiles": tl,
            "traces": tr,
            "kv": kv,
            "schedule": sc,
            "split": sk,
            "gate": {
                "min_traversal_ratio": args.min_traversal_ratio,
                "traversal_ratio": r["traversal_ratio"],
                "prefill_traversals_per_token_monotonic":
                    all(a >= b for a, b in zip(per_tok, per_tok[1:])),
                "enforce_tile_bound": args.enforce_tile_bound,
                "within_tile_bound": tile_bound_ok,
                "min_tile_ratio": args.min_tile_ratio,
                "tile_ratio_at_s8": tl["tile_ratio_at_s8"],
                "enforce_single_trace": args.enforce_single_trace,
                "dynamic_decode_traces": tr["dynamic"]["decode_traces"],
                "max_kv_balance": args.max_kv_balance,
                "kv_balance": kv["balance"],
                "kv_shards": kv["kv_shards"],
                "min_coschedule_frac": args.min_coschedule_frac,
                "coschedule_frac": sc["coschedule_frac"],
                "traversals_per_cycle_ooo": sc["traversals_per_cycle_ooo"],
                "traversals_per_cycle_static":
                    sc["traversals_per_cycle_static"],
                "schedule_tokens_match": sc["tokens_match"],
                "min_split_speedup": args.min_split_speedup,
                "split_speedup": sk["split_speedup"],
                "split_tokens_match": sk["tokens_match"],
                "split_tile_reads_match": sk["tile_reads_match"],
            },
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\nwrote {args.json}")

    failed = False
    if args.min_traversal_ratio is not None:
        if r["traversal_ratio"] < args.min_traversal_ratio:
            print(f"GATE FAIL: traversal_ratio {r['traversal_ratio']:.2f} < "
                  f"{args.min_traversal_ratio}", file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: traversal_ratio {r['traversal_ratio']:.2f} >= "
                  f"{args.min_traversal_ratio}")
    if args.enforce_tile_bound:
        if not tile_bound_ok:
            print("GATE FAIL: steady-decode tile reads exceed "
                  "ceil((cache_len+1)/seq_tile) per step", file=sys.stderr)
            failed = True
        else:
            print("GATE OK: steady-decode tile reads within the "
                  "ceil((cache_len+1)/seq_tile) budget")
    if args.min_tile_ratio is not None:
        if tl["tile_ratio_at_s8"] < args.min_tile_ratio:
            print(f"GATE FAIL: tile_ratio at S_max/8 "
                  f"{tl['tile_ratio_at_s8']:.2f} < {args.min_tile_ratio}",
                  file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: tile_ratio at S_max/8 "
                  f"{tl['tile_ratio_at_s8']:.2f} >= {args.min_tile_ratio}")
    if args.enforce_single_trace:
        dyn = tr["dynamic"]["decode_traces"]
        sweep_traces = [x["bounded"]["decode_traces"]
                        for x in tl["per_cache_len"].values()]
        if dyn < 0 or any(t < 0 for t in sweep_traces):
            # -1 = this jax build exposes no jit-cache probe; that is an
            # environment gap, not a retrace regression — don't fail on it
            print("GATE SKIP: jit trace probe unavailable on this jax "
                  "version; single-trace property not checked")
        elif dyn != 1 or any(t != 1 for t in sweep_traces):
            print(f"GATE FAIL: dynamic-grid decode path retraced "
                  f"(sweep: {dyn}, per-cache-len: {sweep_traces}; want 1)",
                  file=sys.stderr)
            failed = True
        else:
            print("GATE OK: 1 decode trace across the cache-length sweep "
                  f"(bucketed fallback: {tr['bucketed']['decode_traces']})")
    if args.max_kv_balance is not None:
        if kv.get("skipped"):
            print("GATE SKIP: kv balance needs > 1 visible device (set "
                  "XLA_FLAGS=--xla_force_host_platform_device_count=8)")
        else:
            sharded_ok = (kv["tokens_match_unsharded"]
                          and kv["within_tile_bound"]
                          and (args.min_traversal_ratio is None
                               or kv["traversal_ratio"]
                               >= args.min_traversal_ratio)
                          and (args.min_tile_ratio is None
                               or kv["tile_ratio"] >= args.min_tile_ratio)
                          and (not args.enforce_single_trace
                               or kv["decode_traces"] in (-1, 1)))
            if kv["balance"] > args.max_kv_balance or not sharded_ok:
                print(f"GATE FAIL: data-parallel KV over {kv['kv_shards']} "
                      f"devices — balance {kv['balance']:.2f} (max "
                      f"{args.max_kv_balance}), traversal_ratio "
                      f"{kv['traversal_ratio']:.2f}, tile_ratio "
                      f"{kv['tile_ratio']:.2f}, within_tile_bound "
                      f"{kv['within_tile_bound']}, decode_traces "
                      f"{kv['decode_traces']}, tokens_match "
                      f"{kv['tokens_match_unsharded']}", file=sys.stderr)
                failed = True
            else:
                print(f"GATE OK: kv balance {kv['balance']:.2f} <= "
                      f"{args.max_kv_balance} over {kv['kv_shards']} devices "
                      f"(sharded traversal {kv['traversal_ratio']:.2f}x, "
                      f"tile {kv['tile_ratio']:.2f}x, traces "
                      f"{kv['decode_traces']})")
    if args.min_coschedule_frac is not None:
        frac = sc["coschedule_frac"]
        ooo_tc = sc["traversals_per_cycle_ooo"]
        static_tc = sc["traversals_per_cycle_static"]
        if (frac < args.min_coschedule_frac or ooo_tc >= static_tc
                or not sc["tokens_match"]):
            print(f"GATE FAIL: schedule — coschedule_frac {frac:.2f} (min "
                  f"{args.min_coschedule_frac}), traversals/cycle ooo "
                  f"{ooo_tc:.3f} vs static {static_tc:.3f} (want strictly "
                  f"fewer), tokens_match {sc['tokens_match']}",
                  file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: ooo co-scheduled {frac:.2f} of multi-phase "
                  f"cycles (min {args.min_coschedule_frac}) and committed "
                  f"{ooo_tc:.3f} traversals/cycle vs static {static_tc:.3f}, "
                  f"tokens identical across schedule configs")
    if args.min_split_speedup is not None:
        sp = sk["split_speedup"]
        if (sp < args.min_split_speedup or not sk["tokens_match"]
                or not sk["tile_reads_match"]):
            print(f"GATE FAIL: split-KV — speedup {sp:.2f} (min "
                  f"{args.min_split_speedup}), tokens_match "
                  f"{sk['tokens_match']}, tile_reads_match "
                  f"{sk['tile_reads_match']}", file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: split-KV critical-path speedup {sp:.2f}x >= "
                  f"{args.min_split_speedup} at num_kv_splits="
                  f"{max(SPLIT_COUNTS)}, tokens identical and serviced "
                  f"tiles unchanged across split counts")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
