"""System-level claim C1: the multi-port engine completes a request batch in
fewer macro-cycles (and less wall time) than single-port scheduling."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine


def run(n_requests: int = 8, max_new: int = 6) -> dict:
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(n_requests)]

    out = {}
    for mode, single in [("multiport", False), ("single_port", True)]:
        eng = MultiPortEngine(params, cfg, slots=4, max_len=64,
                              prefill_bucket=8, single_port=single)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=5000)
        dt = time.perf_counter() - t0
        assert len(done) == n_requests
        out[mode] = {"cycles": eng.cycles, "seconds": dt,
                     "tokens": sum(len(r.generated) for r in done)}
    out["cycle_ratio"] = out["single_port"]["cycles"] / out["multiport"]["cycles"]
    return out


def main() -> None:
    r = run()
    print("# serving engine: multi-port vs single-port scheduling (claim C1)")
    print("mode,cycles,seconds,tokens")
    for m in ("multiport", "single_port"):
        print(f"{m},{r[m]['cycles']},{r[m]['seconds']:.3f},{r[m]['tokens']}")
    print(f"cycle_ratio,{r['cycle_ratio']:.2f}")


if __name__ == "__main__":
    main()
