"""System-level claim C1: the multi-port engine's fused (pallas) data plane
completes a request batch with ONE pool traversal per decode step where the
two-pass reference does >= 2, and the 4-port schedule finishes in fewer
macro-cycles (and less wall time) than single-port scheduling.

Reported per mode: macro-cycles, wall seconds, generated tokens,
cycles/token, physical pool traversals, traversals/token, and
traversals-per-decode-step (the headline C1 ratio: ~1 fused vs >= 2
reference)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine

MODES = (
    # (name, kernel_mode, single_port)
    ("pallas", "pallas", False),
    ("reference", "reference", False),
    ("single_port", "reference", True),
)


def run(n_requests: int = 8, max_new: int = 6) -> dict:
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab, int(rng.integers(3, 8))))
               for _ in range(n_requests)]

    out = {}
    tokens_by_mode = {}
    for mode, kernel_mode, single in MODES:
        eng = MultiPortEngine(params, cfg, slots=4, max_len=64,
                              prefill_bucket=8, kernel_mode=kernel_mode,
                              single_port=single)
        for p in prompts:
            eng.submit(p, max_new=max_new)
        t0 = time.perf_counter()
        done = eng.run(max_cycles=5000)
        dt = time.perf_counter() - t0
        assert len(done) == n_requests
        toks = sum(len(r.generated) for r in done)
        tokens_by_mode[mode] = {r.rid: tuple(r.generated) for r in done}
        out[mode] = {
            "cycles": eng.cycles, "seconds": dt, "tokens": toks,
            "cycles_per_token": eng.cycles / toks,
            "pool_traversals": eng.pool_traversals,
            "traversals_per_token": eng.pool_traversals / toks,
            "traversals_per_decode": (eng.decode_traversals
                                      / max(eng.decode_steps, 1)),
            # steady state: decode cycles carrying both append + read ports
            "traversals_per_decode_steady": (eng.steady_decode_traversals
                                             / max(eng.steady_decode_steps,
                                                   1)),
        }
    # all modes must agree token-for-token (same greedy decode)
    assert (tokens_by_mode["pallas"] == tokens_by_mode["reference"]
            == tokens_by_mode["single_port"]), "modes disagree on tokens"
    out["cycle_ratio"] = (out["single_port"]["cycles"]
                          / out["pallas"]["cycles"])
    out["traversal_ratio"] = (
        out["reference"]["traversals_per_decode_steady"]
        / out["pallas"]["traversals_per_decode_steady"])
    return out


def main() -> None:
    r = run()
    print("# serving engine: fused multi-port vs reference vs single-port "
          "(claim C1)")
    print("mode,cycles,seconds,tokens,cycles/token,pool_traversals,"
          "traversals/token,traversals/decode,traversals/decode(steady)")
    for m, _, _ in MODES:
        x = r[m]
        print(f"{m},{x['cycles']},{x['seconds']:.3f},{x['tokens']},"
              f"{x['cycles_per_token']:.2f},{x['pool_traversals']},"
              f"{x['traversals_per_token']:.2f},"
              f"{x['traversals_per_decode']:.2f},"
              f"{x['traversals_per_decode_steady']:.2f}")
    print(f"cycle_ratio,{r['cycle_ratio']:.2f}")
    print(f"traversal_ratio,{r['traversal_ratio']:.2f}")


if __name__ == "__main__":
    main()
