"""Per-kernel micro-costs: fused decode append+attend vs two-pass reference,
flash attention vs dense reference — compiled cost_analysis (flops / bytes)
plus CPU wall time (relative trend only; the kernels target TPU)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []

    # fused decode: 2-port single traversal vs append-then-attend two-pass
    b, s, hkv, g, d = 4, 1024, 4, 4, 64
    h = hkv * g
    q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    nk = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    nv = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
    lens = jnp.asarray(rng.integers(0, s - 1, b), jnp.int32)

    fused = jax.jit(lambda *a: ops.fused_decode_attention(*a, seq_tile=256))
    two_pass = jax.jit(ref.decode_attention_ref)
    for name, f in [("decode_fused_2port", fused),
                    ("decode_two_pass_ref", two_pass)]:
        cost = f.lower(q, ck, cv, nk, nv, lens).compile().cost_analysis()
        rows.append({"kernel": name,
                     "us": _time(f, q, ck, cv, nk, nv, lens) * 1e6,
                     "flops": float(cost.get("flops", 0)),
                     "bytes": float(cost.get("bytes accessed", 0))})

    # flash attention vs dense reference
    b, h, hkv, sq, d = 1, 4, 2, 512, 64
    qq = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(b, hkv, sq, d)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(b, hkv, sq, d)), jnp.float32)
    fa = jax.jit(lambda *a: ops.flash_attention(*a, causal=True, q_tile=128,
                                                k_tile=128))
    dense = jax.jit(lambda *a: ref.attention_ref(*a, causal=True))
    for name, f in [("flash_attention", fa), ("dense_attention_ref", dense)]:
        cost = f.lower(qq, kk, vv).compile().cost_analysis()
        rows.append({"kernel": name,
                     "us": _time(f, qq, kk, vv) * 1e6,
                     "flops": float(cost.get("flops", 0)),
                     "bytes": float(cost.get("bytes accessed", 0))})
    return rows


def main() -> None:
    print("# kernel micro-costs (interpret-mode wall time; compiled flops/bytes)")
    print("kernel,us_per_call,flops,bytes")
    for r in run():
        print(f"{r['kernel']},{r['us']:.0f},{r['flops']:.3g},{r['bytes']:.3g}")


if __name__ == "__main__":
    main()
