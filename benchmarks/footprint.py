"""Paper Tables I/II — area (footprint) analogue, claim C2.

For equal logical capacity (a 16 Kb-scaled macro) and a 1W/3R port mix:
proposed wrapper (1x storage + port metadata) vs bitcell-widening replication
(one replica per read port) vs XOR-coded banks (paper ref [11]). The paper's
8% wrapper overhead maps to port-queue metadata / storage bytes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import MemorySpec, PortConfig, READ, WRITE
from repro.core.baselines import ReplicatedReads, SinglePortNPass, XorCoded

# a 16 Kb bit-equivalent macro (paper array size), word width 128 f32
SPEC = MemorySpec(num_words=4096, word_width=128, num_banks=16)
# queue depth 64: the wrapper metadata (port queues + staging registers)
# amortizes to single-digit % of the macro, matching the paper's 8% regime;
# deeper queues trade metadata for fewer macro-cycles (a knob the paper's
# fixed-function wrapper does not have).
Q = 64
CFG = PortConfig(enabled=(True, True, True, True),
                 roles=(WRITE, READ, READ, READ))


def run() -> list[dict]:
    word_bytes = SPEC.word_width * jnp.dtype(SPEC.dtype).itemsize
    storage_bytes = SPEC.num_words * word_bytes
    # wrapper metadata: 4 port queues (addr int32 + mask byte + staging data)
    meta_bytes = 4 * Q * (4 + 1 + word_bytes)
    rows = [{
        "design": "proposed-wrapper(6T)",
        "footprint_bytes": storage_bytes + meta_bytes,
        "relative_area": (storage_bytes + meta_bytes) / storage_bytes,
        "overhead_pct": 100 * meta_bytes / storage_bytes,   # paper: 8%
        "ports": "4 configurable",
    }]
    for name, counters, ports in [
        ("single-port(bare 6T)", SinglePortNPass(SPEC).counters(CFG, Q), "1 (N-pass)"),
        ("replicated(8T/12T school)", ReplicatedReads(SPEC, 3).counters(CFG, Q),
         "1W+3R fixed"),
        ("xor-coded(ref [11])", XorCoded(SPEC).counters(CFG, Q), "2 eff. fixed"),
    ]:
        fb = counters.footprint_words * word_bytes
        rows.append({
            "design": name,
            "footprint_bytes": fb,
            "relative_area": fb / storage_bytes,
            "overhead_pct": 100 * (fb - storage_bytes) / storage_bytes,
            "ports": ports,
        })
    return rows


def main() -> None:
    print("# footprint / area analogue (paper Tables I & II, claim C2)")
    print("design,footprint_bytes,relative_area,overhead_pct,ports")
    for r in run():
        print(f"{r['design']},{r['footprint_bytes']},"
              f"{r['relative_area']:.3f},{r['overhead_pct']:.1f},{r['ports']}")


if __name__ == "__main__":
    main()
