"""Open-loop serving bench: latency SLOs under real traffic (bench-serve/v3).

Every other bench in this repo is CLOSED-loop — all requests submitted up
front, ratio gates on traversals/tiles/traces. This one drives the engine
the way production traffic does: requests ARRIVE on a seeded virtual-clock
schedule (``serve/traffic.py``: Poisson arrivals, heavy-tailed
prompt/output lengths over the config registry's scenario spread, or a
JSONL trace replay), wait in the arrival-ordered admission queue while
slots are contended, and the engine runs macro-cycles continuously.

**The clock is virtual**: one tick per pool traversal (idle macro-cycles
cost one tick), so every latency number is deterministic on CI and prices
exactly what the paper prices — a scheduler that spends more pool
traversals per macro-cycle (``schedule_mode="static"``, the rigid
one-traversal-per-phase walk) burns more ticks for the same work, its
queues grow, and its TAIL latency blows up. The bench serves the SAME
arrival schedule under ``ooo`` (the PR-6 dependency-tracked port-mix
scheduler) and ``static`` and reports, per mode: p50/p99 TTFT, p50/p99
per-token latency, p50/p99 queue delay (all in virtual ticks; wall-clock
columns opt-in via ``--wall-clock``), goodput (tokens from SLO-meeting
requests per tick), queue-depth mean/max, and the engine's
slot-contention / eviction-pressure counters.

A second section checks the open-loop contract itself: with "infinite"
slots (one per request) the open-loop admission path must reproduce the
closed-loop token output EXACTLY — arrival timing may never change what
gets generated, only when.

CI gate (.github/workflows/ci.yml ``bench-serve``, via
benchmarks/ci_gates.sh; schema + semantics in benchmarks/README.md):

    python benchmarks/serve_bench.py --json BENCH_serve.json \
        --max-p99-ttft-cycles T --min-goodput G

exits non-zero unless, at the same arrival rate, ``ooo`` meets BOTH SLOs
(p99 TTFT <= T virtual ticks, goodput >= G tokens/tick) AND the SLO still
differentiates the schedulers: ``static`` misses the p99-TTFT SLO, or
``ooo`` is strictly better on p99 TTFT with at-least-equal goodput. Token
identity (open vs closed loop, and per-request ooo vs static) is part of
the gate; ``BENCH_serve.json`` is written before the gate exits so the
record uploads on failures too.

**Overload section (v2, ``--overload-sweep``)**: a SUSTAINED
above-saturation arrival-rate sweep — requests scale with rate so
arrivals cover the same virtual-tick window at every rate and the
backlog never drains — comparing a PROTECTED engine (admission TTL,
bounded queue, graceful-degradation controller — the overload-safe
serving layer) against a no-shedding BASELINE on the same schedules.
Goodput counts only tokens from requests whose TTFT met the overload
SLO. The gate asserts
the protected engine's goodput stays within ``--overload-band`` (default
20%) of the pre-overload plateau at EVERY overload rate while the
baseline degrades past the band at the deepest rate; that shed requests
never touched the engine (no admit stamp, no slot, no tokens, no pool
pages); and that every survivor's tokens are identical to the
pressure-free run — load shedding changes WHO gets served, never WHAT is
generated.

**Chaos section (v2, ``--chaos-seed``)**: a seeded
:class:`~repro.serve.chaos.FaultPlan` (capacity squeezes, mid-stream
cancels, delayed retirement) injected into a driven engine via
:class:`~repro.serve.chaos.ChaosHarness`, with the pool/engine invariant
audit after every fault — a violation is a hard exit — and survivor
tokens (not shed, not cancelled) gated identical to the fault-free run.
``--chaos-only`` runs just this section (the CI ``chaos`` invocation,
writing ``BENCH_chaos.json``).

**Prefix section (v3, ``--prefix-mix``)**: a shared-prefix traffic mix —
one scenario whose requests draw their prompt heads from a small pool of
common headers (``serve/traffic.py`` scenario pools) — served twice on
the same schedule: prefix cache ON (refcounted copy-on-write page
sharing; matched prompt heads attach by refcount bump and skip prefill
compute) and OFF (every request computes its own KV — today's exclusive
ownership). Reports admitted-tokens-computed / admitted-tokens-served
(computed = served minus prefix-attached tokens), the prefix hit rate
over admissions, and CoW copy counts. The gates
(``--max-computed-ratio``, ``--min-prefix-hit-rate``) assert the cache
actually deduplicates — ratio <= the bound with the cache on, EXACTLY
1.0 with it off — while greedy tokens stay bit-identical between the two
runs, against a ``static``/``reference`` oracle, and across a
1/2/4/8-shard device sweep (forced host devices; skipped counts are
recorded, never silent).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from collections import Counter

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.admission import OverloadController
from repro.serve.chaos import ChaosHarness, FaultPlan, InvariantViolation
from repro.serve.engine import MultiPortEngine
from repro.serve.traffic import drive, poisson_arrivals, trace_arrivals

# workload geometry (shared with engine_bench's tile sweep): small enough
# for CPU interpret mode, contended enough that queues actually form
S_MAX = 64
SEQ_TILE = 8
CHUNK_TOKENS = 8
SLOTS = 4
MAX_PROMPT = 40
MAX_OUTPUT = 10

SCHEDULE_MODES = ("ooo", "static")

# overload sweep geometry: the plateau rate sits below the 4-slot
# engine's saturation knee (~1.3 req/tick on this workload); the sweep
# rates are 3x and 6x it. Arrivals SUSTAIN for OVERLOAD_DURATION virtual
# ticks at every rate (requests = rate * duration) — a fixed request
# COUNT would turn the deep rates into a finite burst the baseline can
# drain after arrivals stop, compressing its wall-clock enough to hide
# the SLO misses from the goodput-per-tick metric. The protected
# engine's admission TTL bounds queue WAIT; the goodput SLO adds service
# grace on top (a request admitted right at its deadline still needs
# prefill cycles)
OVERLOAD_PLATEAU_RATE = 1.0
OVERLOAD_RATES = (3.0, 6.0)
OVERLOAD_DURATION = 24.0
OVERLOAD_TTL = 8.0
OVERLOAD_SLO_TTFT = 12.0
OVERLOAD_QUEUE_DEPTH = 8

# chaos section geometry: enough contention that cancels hit live slots
# and squeezes actually park admissions (the engine's 32-page pool)
CHAOS_REQUESTS = 20
CHAOS_RATE = 0.8
CHAOS_FAULTS = 6
CHAOS_MAX_SQUEEZE = 16

# prefix section geometry: ONE scenario with a 2-header pool so requests
# actually collide on content; headers span 3 full pages (24 tokens at
# page_tokens=8) and prompts are long enough to carry a whole header plus
# a private tail. Attached pages only survive while some sequence
# references them (no tombstones), so the mix needs OVERLAPPING
# lifetimes: arrivals staggered slower than a prefill (a sharer admitted
# before the registrant's prefill commits cannot match) and a decode
# floor long enough that holders stay live while the next sharer admits
PREFIX_REQUESTS = 16
PREFIX_RATE = 0.25
PREFIX_PACE_TICKS = 2
PREFIX_SLOTS = 8
PREFIX_HEADERS = 2
PREFIX_TOKENS = 24
PREFIX_MIN_PROMPT = 26
PREFIX_MIN_OUTPUT = 6
PREFIX_SWEEP_SHARDS = (1, 2, 4, 8)
PREFIX_SWEEP_REQUESTS = 8


def _setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _pct(vals, q) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


def summarize(eng: MultiPortEngine, qdepth: list, wall: float,
              slo_ttft=None) -> dict:
    """Latency/goodput record for one open-loop run. Goodput counts only
    tokens from requests whose TTFT met ``slo_ttft`` (all tokens when no
    SLO is given); throughput counts everything."""
    reqs = eng.finished
    ttft = [r.ttft_ticks for r in reqs if r.ttft_ticks is not None]
    tpot = [r.tpot_ticks for r in reqs if r.tpot_ticks is not None]
    qdelay = [r.admit_tick - r.arrival_tick for r in reqs
              if r.admit_tick is not None]
    toks = sum(len(r.generated) for r in reqs)
    ticks = max(eng.vclock, 1)
    good = toks if slo_ttft is None else sum(
        len(r.generated) for r in reqs
        if r.ttft_ticks is not None and r.ttft_ticks <= slo_ttft)
    ttft_wall = [r.t_first - r.t_submit for r in reqs
                 if r.t_first is not None]
    return {
        "requests_finished": len(reqs),
        "tokens": toks,
        "total_ticks": eng.vclock,
        "cycles": eng.cycles,
        "pool_traversals": eng.pool_traversals,
        "traversals_per_cycle": eng.pool_traversals / max(eng.cycles, 1),
        "ttft_p50": _pct(ttft, 50), "ttft_p99": _pct(ttft, 99),
        "tpot_p50": _pct(tpot, 50), "tpot_p99": _pct(tpot, 99),
        "queue_delay_p50": _pct(qdelay, 50),
        "queue_delay_p99": _pct(qdelay, 99),
        "goodput_tokens_per_tick": good / ticks,
        "throughput_tokens_per_tick": toks / ticks,
        "queue_depth_mean": float(np.mean(qdepth)) if qdepth else 0.0,
        "queue_depth_max": int(max(qdepth)) if qdepth else 0,
        "peak_queue_depth": eng.admission.peak_depth,
        "slot_contention_cycles": eng.slot_contention_cycles,
        "evict_pressure_admissions": eng.evict_pressure_admissions,
        "evictions": eng.evictions,
        "coschedule_frac": eng.coschedule_frac,
        # wall-clock column: recorded always, reported via --wall-clock,
        # never gated (virtual ticks are the deterministic SLO base)
        "wall": {
            "seconds": wall,
            "tokens_per_s": toks / max(wall, 1e-9),
            "ttft_p50_s": _pct(ttft_wall, 50),
            "ttft_p99_s": _pct(ttft_wall, 99),
        },
    }


def _tokens_by_index(reqs) -> dict:
    """rid -> generated tokens; rids are submission-ordered in every run
    of the same arrival list, so they align across modes."""
    return {r.rid: tuple(r.generated) for r in reqs}


def run_modes(params, cfg, arrivals, slo_ttft=None) -> dict:
    """The same arrival schedule under each schedule mode, contended
    (slots = SLOTS, no growth): the open-loop pressure run."""
    out = {}
    toks = {}
    for mode in SCHEDULE_MODES:
        eng = MultiPortEngine(params, cfg, slots=SLOTS, max_slots=SLOTS,
                              max_len=S_MAX, seq_tile=SEQ_TILE,
                              chunk_tokens=CHUNK_TOKENS,
                              schedule_mode=mode)
        qdepth, wall = drive(eng, arrivals)
        s = summarize(eng, qdepth, wall, slo_ttft=slo_ttft)
        s["schedule_mode"] = mode
        out[mode] = s
        toks[mode] = _tokens_by_index(eng.finished)
    out["tokens_match"] = toks["ooo"] == toks["static"]
    return out


def run_identity(params, cfg, arrivals) -> dict:
    """Open-loop admission with 'infinite' slots (one per request) must
    reproduce the closed-loop token output exactly: arrival timing decides
    WHEN work happens, never WHAT is generated."""
    n = len(arrivals)
    open_eng = MultiPortEngine(params, cfg, slots=n, max_slots=n,
                               max_len=S_MAX, seq_tile=SEQ_TILE,
                               chunk_tokens=CHUNK_TOKENS)
    drive(open_eng, arrivals)
    closed_eng = MultiPortEngine(params, cfg, slots=n, max_slots=n,
                                 max_len=S_MAX, seq_tile=SEQ_TILE,
                                 chunk_tokens=CHUNK_TOKENS)
    for a in arrivals:
        closed_eng.submit(list(a.prompt), a.max_new, arrival_tick=0)
    closed_eng.run(max_cycles=20000)
    to, tc = (_tokens_by_index(open_eng.finished),
              _tokens_by_index(closed_eng.finished))
    return {
        "slots": n,
        "open_finished": len(open_eng.finished),
        "closed_finished": len(closed_eng.finished),
        "open_vs_closed_tokens_match": (
            to == tc and len(open_eng.finished) == n),
    }


def _shed_untouched(eng) -> bool:
    """True iff every shed request never consumed engine resources: no
    admit stamp, no slot, no generated token, no pool pages — the "shed
    work is free work" contract the overload gate enforces."""
    return all(r.admit_tick is None and r.slot is None
               and not r.generated and r.rid not in eng.pool.tables
               for r in eng.shed)


def _overload_engine(params, cfg, protected: bool) -> MultiPortEngine:
    kw = dict(slots=SLOTS, max_slots=SLOTS, max_len=S_MAX,
              seq_tile=SEQ_TILE, chunk_tokens=CHUNK_TOKENS)
    if protected:
        kw.update(default_ttl_ticks=OVERLOAD_TTL,
                  max_queue_depth=OVERLOAD_QUEUE_DEPTH,
                  overload=OverloadController())
    return MultiPortEngine(params, cfg, **kw)


def _overload_run(params, cfg, arrivals, protected: bool) -> tuple:
    eng = _overload_engine(params, cfg, protected)
    res = drive(eng, arrivals)
    s = summarize(eng, res.qdepth, res.wall, slo_ttft=OVERLOAD_SLO_TTFT)
    ov = eng.overload
    s.update({
        "protected": protected,
        "submitted": res.submitted,
        "shed": res.shed,
        "shed_deadline": res.shed_deadline,
        "shed_queue_full": res.shed_queue_full,
        "shed_capacity": res.shed_capacity,
        "capacity_recoveries": res.capacity_recoveries,
        "capacity_parked_cycles": eng.capacity_parked_cycles,
        "shed_untouched": _shed_untouched(eng),
        "degraded_cycles": ov.degraded_cycles if ov else 0,
        "overload_transitions": list(ov.transitions) if ov else [],
    })
    return s, _tokens_by_index(eng.finished)


def run_overload(params, cfg, seed: int, band: float) -> dict:
    """The above-saturation sweep: one pressure-free plateau run, then at
    each overload rate a PROTECTED run (TTL + bounded queue + degradation
    controller) and a no-shedding BASELINE run of the same schedule.

    All runs draw from ONE master arrival list (generated at rate 1.0),
    truncated to ``rate * OVERLOAD_DURATION`` requests and re-stamped at
    the run's rate — so request index i carries the SAME prompt in every
    run and rids align across the whole sweep. The deepest-rate baseline,
    which sheds nothing and therefore serves every master request, is the
    token reference: every survivor in every run (including the
    pressure-free plateau, which anchors the reference transitively) must
    generate exactly its reference tokens."""
    n_max = max(1, round(max(OVERLOAD_RATES) * OVERLOAD_DURATION))
    master = poisson_arrivals(
        n_max, 1.0, seed=seed, vocab=cfg.vocab,
        max_prompt=MAX_PROMPT, max_output=MAX_OUTPUT)

    def _arr(rate):
        # dividing the rate-1.0 Poisson ticks by `rate` is a Poisson
        # process at `rate` over the same ~OVERLOAD_DURATION window
        n = max(1, round(rate * OVERLOAD_DURATION))
        return tuple(dataclasses.replace(
            a, arrival_tick=int(a.arrival_tick / rate))
            for a in master[:n])

    plateau, plateau_toks = _overload_run(params, cfg,
                                          _arr(OVERLOAD_PLATEAU_RATE), True)
    plateau_goodput = plateau["goodput_tokens_per_tick"]
    sweep = []
    run_toks = [plateau_toks]
    untouched_ok = plateau["shed_untouched"]
    ref_tokens = None
    for rate in OVERLOAD_RATES:
        arrivals = _arr(rate)
        for protected in (True, False):
            s, toks = _overload_run(params, cfg, arrivals, protected)
            s["rate"] = rate
            s["goodput_vs_plateau"] = (s["goodput_tokens_per_tick"]
                                       / max(plateau_goodput, 1e-9))
            untouched_ok = untouched_ok and s["shed_untouched"]
            sweep.append(s)
            run_toks.append(toks)
            if not protected and rate == max(OVERLOAD_RATES):
                ref_tokens = toks
    survivors_ok = (
        len(ref_tokens) == n_max      # the reference covers every rid
        and plateau["requests_finished"] == len(_arr(OVERLOAD_PLATEAU_RATE))
        and all(toks[rid] == ref_tokens[rid]
                for toks in run_toks for rid in toks))
    prot = [s for s in sweep if s["protected"]]
    base = [s for s in sweep if not s["protected"]]
    deepest = max(base, key=lambda s: s["rate"])
    return {
        "plateau_rate": OVERLOAD_PLATEAU_RATE,
        "rates": list(OVERLOAD_RATES),
        "duration_ticks": OVERLOAD_DURATION,
        "requests_per_rate": {str(r): max(1, round(r * OVERLOAD_DURATION))
                              for r in (OVERLOAD_PLATEAU_RATE,
                                        *OVERLOAD_RATES)},
        "ttl_ticks": OVERLOAD_TTL,
        "slo_ttft": OVERLOAD_SLO_TTFT,
        "max_queue_depth": OVERLOAD_QUEUE_DEPTH,
        "band": band,
        "plateau": plateau,
        "sweep": sweep,
        "gate": {
            "plateau_goodput": plateau_goodput,
            "protected_min_vs_plateau": min(
                s["goodput_vs_plateau"] for s in prot),
            "baseline_deepest_vs_plateau": deepest["goodput_vs_plateau"],
            "protected_within_band": all(
                s["goodput_vs_plateau"] >= 1.0 - band for s in prot),
            "baseline_degrades": (
                deepest["goodput_vs_plateau"] < 1.0 - band),
            "shed_untouched": untouched_ok,
            "survivor_tokens_match": survivors_ok,
        },
    }


def run_chaos(params, cfg, chaos_seed: int, arrival_seed: int) -> dict:
    """The fault-injection section: drive the same seeded schedule twice
    — fault-free, then under a generated :class:`FaultPlan` — auditing
    the engine/pool invariants after every injection and comparing
    survivor tokens (neither shed nor cancelled) against the fault-free
    run. An :class:`InvariantViolation` is recorded and fails the gate;
    it never silently passes."""
    arrivals = poisson_arrivals(
        CHAOS_REQUESTS, CHAOS_RATE, seed=arrival_seed, vocab=cfg.vocab,
        max_prompt=MAX_PROMPT, max_output=MAX_OUTPUT)
    kw = dict(slots=SLOTS, max_slots=SLOTS, max_len=S_MAX,
              seq_tile=SEQ_TILE, chunk_tokens=CHUNK_TOKENS)
    ref = MultiPortEngine(params, cfg, **kw)
    drive(ref, arrivals)
    ref_tokens = _tokens_by_index(ref.finished)

    plan = FaultPlan.generate(chaos_seed, horizon=max(ref.vclock, 1),
                              n_faults=CHAOS_FAULTS,
                              max_squeeze=CHAOS_MAX_SQUEEZE)
    eng = MultiPortEngine(params, cfg, **kw)
    harness = ChaosHarness(plan)
    violation = None
    try:
        res = drive(eng, arrivals, on_cycle=harness)
        harness.finalize(eng)
    except InvariantViolation as e:
        violation = str(e)
        res = None
    survivors = {r.rid: tuple(r.generated) for r in eng.finished
                 if not r.cancelled and r.shed_reason is None}
    kinds_fired = sorted({i["kind"] for i in harness.injected
                          if i.get("rid", "") is not None})
    return {
        "chaos_seed": chaos_seed,
        "arrival_seed": arrival_seed,
        "requests": CHAOS_REQUESTS,
        "rate": CHAOS_RATE,
        "plan": [{"tick": f.tick, "kind": f.kind,
                  "magnitude": f.magnitude, "duration": f.duration}
                 for f in plan.faults],
        "injected": harness.injected,
        "invariant_checks": harness.invariant_checks,
        "invariant_violation": violation,
        "straggler_events": harness.straggler_events,
        "cancelled": eng.cancelled,
        "shed": len(eng.shed),
        "shed_capacity": eng.shed_capacity,
        "capacity_recoveries": eng.capacity_recoveries,
        "fault_free_finished": len(ref.finished),
        "chaos_finished": res.served if res is not None else None,
        "survivors": len(survivors),
        "kinds_fired": kinds_fired,
        "gate": {
            "invariants_ok": violation is None,
            "survivor_tokens_match": all(
                survivors[rid] == ref_tokens.get(rid)
                for rid in survivors),
            "all_kinds_injected": all(
                any(i["kind"] == k for i in harness.injected)
                for k in ("squeeze", "cancel", "stall")),
        },
    }


def _prefix_arrivals(cfg, seed: int):
    from repro.serve.traffic import scenario_spread
    sp = scenario_spread(arch_ids=("tinyllama-1.1b",),
                         shared_prefixes=PREFIX_HEADERS,
                         prefix_tokens=PREFIX_TOKENS)
    arr = poisson_arrivals(
        PREFIX_REQUESTS, PREFIX_RATE, seed=seed, vocab=cfg.vocab,
        max_prompt=MAX_PROMPT, max_output=MAX_OUTPUT,
        min_prompt=PREFIX_MIN_PROMPT, min_output=PREFIX_MIN_OUTPUT,
        scenarios=sp)
    # Pace the mix: re-stamp arrival ticks on a fixed cadence so
    # admissions stagger. Poisson bursts admit several same-header
    # requests in ONE macro-cycle — none can match a prefix that is not
    # registered yet — and long gaps let every holder die (pages leave
    # the index with their last reference; there is no tombstone cache).
    # Neither regime measures dedup; the steady cadence does. Prompts,
    # headers, and output lengths still come from the seeded pools.
    return [dataclasses.replace(a, arrival_tick=1 + PREFIX_PACE_TICKS * i)
            for i, a in enumerate(arr)]


def _prefix_run(params, cfg, arrivals, *, prefix_cache: bool,
                mesh=None, schedule_mode: str = "ooo",
                kernel_mode: str = "pallas") -> tuple:
    # wider slot table than the SLO mix: the dedup measurement needs the
    # paced arrivals ADMITTED on their cadence — a full slot table parks
    # matched heads until their donors die (uniform service times then
    # re-batch admissions into convoys)
    eng = MultiPortEngine(params, cfg, slots=PREFIX_SLOTS,
                          max_slots=PREFIX_SLOTS,
                          max_len=S_MAX, seq_tile=SEQ_TILE,
                          chunk_tokens=CHUNK_TOKENS, mesh=mesh,
                          schedule_mode=schedule_mode,
                          kernel_mode=kernel_mode,
                          prefix_cache=prefix_cache)
    res = drive(eng, arrivals)
    served = sum(len(r.prompt) + len(r.generated) for r in eng.finished)
    stats = eng.prefix_stats
    computed = served - stats["attached_tokens"]
    s = {
        "prefix_cache": prefix_cache,
        "requests_finished": len(eng.finished),
        "total_ticks": eng.vclock,
        "prefill_tokens": eng.prefill_tokens,
        "admitted": eng.admission.admitted,
        "tokens_served": served,
        "tokens_computed": computed,
        "computed_over_served": computed / max(served, 1),
        "hit_rate": stats["hits"] / max(eng.admission.admitted, 1),
        "wall_seconds": res.wall,
        **{f"prefix_{k}": v for k, v in stats.items()},
    }
    return s, _tokens_by_index(eng.finished)


def run_prefix(params, cfg, seed: int) -> dict:
    """The shared-prefix mix: cache on vs off on one schedule, a
    static/reference oracle, and a 1/2/4/8-shard device sweep — every leg
    must generate bit-identical greedy tokens (sharing is storage, never
    numerics), and only the cache-on legs may skip computed tokens."""
    from repro.launch.mesh import make_kv_mesh
    arrivals = _prefix_arrivals(cfg, seed)
    on, toks_on = _prefix_run(params, cfg, arrivals, prefix_cache=True)
    off, toks_off = _prefix_run(params, cfg, arrivals, prefix_cache=False)
    oracle, toks_oracle = _prefix_run(params, cfg, arrivals,
                                      prefix_cache=True,
                                      schedule_mode="static",
                                      kernel_mode="reference")
    sweep = []
    sweep_ok = True
    sub = arrivals[:PREFIX_SWEEP_REQUESTS]
    _, sub_ref = _prefix_run(params, cfg, sub, prefix_cache=False)
    for k in PREFIX_SWEEP_SHARDS:
        if jax.device_count() < k:
            sweep.append({"shards": k, "skipped":
                          f"{jax.device_count()} devices available"})
            continue
        mesh = make_kv_mesh(k) if k > 1 else None
        s, toks = _prefix_run(params, cfg, sub, prefix_cache=True,
                              mesh=mesh)
        s["shards"] = k
        s["tokens_match_unsharded_off"] = toks == sub_ref
        sweep_ok = sweep_ok and s["tokens_match_unsharded_off"]
        sweep.append(s)
    return {
        "requests": PREFIX_REQUESTS,
        "rate": PREFIX_RATE,
        "headers": PREFIX_HEADERS,
        "prefix_tokens": PREFIX_TOKENS,
        "min_prompt": PREFIX_MIN_PROMPT,
        "on": on,
        "off": off,
        "oracle_static_reference": oracle,
        "device_sweep": sweep,
        "gate_inputs": {
            "ratio_on": on["computed_over_served"],
            "ratio_off": off["computed_over_served"],
            "hit_rate": on["hit_rate"],
            "tokens_match_on_off": toks_on == toks_off,
            "tokens_match_oracle": toks_on == toks_oracle,
            "device_sweep_tokens_match": sweep_ok,
            "off_ratio_is_one": off["computed_over_served"] == 1.0,
        },
    }


def arrival_stats(arrivals) -> dict:
    plens = [a.prompt_len for a in arrivals]
    olens = [a.max_new for a in arrivals]
    return {
        "count": len(arrivals),
        "first_tick": arrivals[0].arrival_tick if arrivals else 0,
        "last_tick": arrivals[-1].arrival_tick if arrivals else 0,
        "prompt_len": {"min": min(plens), "max": max(plens),
                       "mean": float(np.mean(plens))},
        "max_new": {"min": min(olens), "max": max(olens),
                    "mean": float(np.mean(olens))},
        "scenarios": dict(sorted(Counter(
            a.scenario for a in arrivals).items())),
    }


def report(modes: dict, ident: dict, ast: dict, wall_clock: bool) -> None:
    print("# open-loop serving: latency SLOs under the virtual clock "
          "(1 tick = 1 pool traversal)")
    print(f"arrivals: {ast['count']} over ticks "
          f"[{ast['first_tick']}, {ast['last_tick']}], prompt_len "
          f"{ast['prompt_len']['min']}..{ast['prompt_len']['max']} "
          f"(mean {ast['prompt_len']['mean']:.1f}), max_new "
          f"{ast['max_new']['min']}..{ast['max_new']['max']} "
          f"(mean {ast['max_new']['mean']:.1f})")
    cols = ("mode,ttft_p50,ttft_p99,tpot_p50,tpot_p99,qdelay_p99,"
            "goodput_tok/tick,ticks,cycles,trav/cycle,qdepth_mean/max,"
            "contention,evict_pressure")
    if wall_clock:
        cols += ",wall_s,wall_tok/s,wall_ttft_p99_s"
    print(cols)
    for mode in SCHEDULE_MODES:
        s = modes[mode]
        row = (f"{mode},{s['ttft_p50']:.1f},{s['ttft_p99']:.1f},"
               f"{s['tpot_p50']:.2f},{s['tpot_p99']:.2f},"
               f"{s['queue_delay_p99']:.1f},"
               f"{s['goodput_tokens_per_tick']:.3f},{s['total_ticks']},"
               f"{s['cycles']},{s['traversals_per_cycle']:.3f},"
               f"{s['queue_depth_mean']:.2f}/{s['queue_depth_max']},"
               f"{s['slot_contention_cycles']},"
               f"{s['evict_pressure_admissions']}")
        if wall_clock:
            w = s["wall"]
            row += (f",{w['seconds']:.2f},{w['tokens_per_s']:.1f},"
                    f"{w['ttft_p99_s']:.3f}")
        print(row)
    print(f"tokens_match(ooo==static),{modes['tokens_match']}")
    print()
    print("# open-loop == closed-loop identity (infinite slots)")
    print(f"slots,{ident['slots']},open_finished,{ident['open_finished']},"
          f"closed_finished,{ident['closed_finished']},tokens_match,"
          f"{ident['open_vs_closed_tokens_match']}")


def report_overload(ov: dict) -> None:
    print()
    print("# overload sweep: goodput (SLO-met tokens/tick, "
          f"TTFT<={ov['slo_ttft']:.0f}) vs the pre-overload plateau "
          f"(rate {ov['plateau_rate']}, "
          f"goodput {ov['gate']['plateau_goodput']:.3f})")
    print("rate,engine,served,shed(ddl/qfull/cap),goodput,vs_plateau,"
          "degraded_cycles,ticks")
    for s in ov["sweep"]:
        eng = "protected" if s["protected"] else "baseline"
        print(f"{s['rate']},{eng},{s['requests_finished']},"
              f"{s['shed']}({s['shed_deadline']}/{s['shed_queue_full']}/"
              f"{s['shed_capacity']}),"
              f"{s['goodput_tokens_per_tick']:.3f},"
              f"{s['goodput_vs_plateau']:.2f},{s['degraded_cycles']},"
              f"{s['total_ticks']}")
    g = ov["gate"]
    print(f"protected_within_band,{g['protected_within_band']},"
          f"baseline_degrades,{g['baseline_degrades']},"
          f"shed_untouched,{g['shed_untouched']},"
          f"survivor_tokens_match,{g['survivor_tokens_match']}")


def report_chaos(ch: dict) -> None:
    print()
    print(f"# chaos: seeded fault injection (seed {ch['chaos_seed']}, "
          f"{len(ch['plan'])} faults) with invariant audit")
    for i in ch["injected"]:
        print(f"tick {i['tick']},{i['kind']},"
              + ",".join(f"{k}={v}" for k, v in i.items()
                         if k not in ("tick", "kind")))
    g = ch["gate"]
    print(f"invariant_checks,{ch['invariant_checks']},violations,"
          f"{ch['invariant_violation'] or 'none'}")
    print(f"survivors,{ch['survivors']}/{ch['fault_free_finished']},"
          f"cancelled,{ch['cancelled']},shed,{ch['shed']},"
          f"straggler_events,{ch['straggler_events']}")
    print(f"invariants_ok,{g['invariants_ok']},survivor_tokens_match,"
          f"{g['survivor_tokens_match']},all_kinds_injected,"
          f"{g['all_kinds_injected']}")


def report_prefix(pf: dict) -> None:
    print()
    print(f"# prefix mix: {pf['requests']} requests, {pf['headers']} shared "
          f"{pf['prefix_tokens']}-token headers (1 scenario), refcounted "
          f"CoW page sharing on vs off")
    print("cache,finished,served_toks,computed_toks,computed/served,"
          "hit_rate,attached_toks,cow_copies,prefill_toks,ticks")
    for s in (pf["on"], pf["off"], pf["oracle_static_reference"]):
        name = "on" if s["prefix_cache"] else "off"
        if s is pf["oracle_static_reference"]:
            name = "on(static/ref)"
        print(f"{name},{s['requests_finished']},{s['tokens_served']},"
              f"{s['tokens_computed']},{s['computed_over_served']:.3f},"
              f"{s['hit_rate']:.2f},{s['prefix_attached_tokens']},"
              f"{s['prefix_cow_copies']},{s['prefill_tokens']},"
              f"{s['total_ticks']}")
    for s in pf["device_sweep"]:
        if "skipped" in s:
            print(f"sweep shards={s['shards']}: skipped ({s['skipped']})")
        else:
            print(f"sweep shards={s['shards']}: ratio "
                  f"{s['computed_over_served']:.3f}, tokens_match "
                  f"{s['tokens_match_unsharded_off']}")
    g = pf["gate_inputs"]
    print(f"tokens_match on==off,{g['tokens_match_on_off']},"
          f"oracle,{g['tokens_match_oracle']},"
          f"device_sweep,{g['device_sweep_tokens_match']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=14,
                    help="open-loop arrivals to generate (ignored with "
                         "--trace)")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="Poisson arrival rate in requests per virtual "
                         "tick (pool traversal)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a JSONL arrival trace instead of the "
                         "seeded Poisson generator")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the bench-serve/v1 record "
                         "(BENCH_serve.json)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="report the wall-clock columns alongside the "
                         "virtual-clock ones (recorded in the JSON either "
                         "way; never gated)")
    ap.add_argument("--max-p99-ttft-cycles", type=float, default=None,
                    help="SLO gate: exit non-zero unless ooo's p99 TTFT "
                         "(virtual-clock ticks) is <= this AND the SLO "
                         "still differentiates ooo from static")
    ap.add_argument("--min-goodput", type=float, default=None,
                    help="SLO gate: exit non-zero if ooo's goodput "
                         "(tokens/tick from SLO-meeting requests) drops "
                         "below this")
    ap.add_argument("--overload-sweep", action="store_true",
                    help="run the above-saturation overload sweep "
                         "(protected vs no-shedding baseline) and gate "
                         "goodput against the pre-overload plateau")
    ap.add_argument("--overload-band", type=float, default=0.2,
                    help="overload gate band: protected goodput must stay "
                         "within this fraction of the plateau at every "
                         "overload rate while the baseline degrades past "
                         "it at the deepest rate (default 0.2)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="run the seeded fault-injection section "
                         "(capacity squeezes, mid-stream cancels, delayed "
                         "retirement) with invariant checks as hard "
                         "failures")
    ap.add_argument("--chaos-only", action="store_true",
                    help="run ONLY the chaos section (requires "
                         "--chaos-seed); the CI chaos invocation")
    ap.add_argument("--prefix-mix", action="store_true",
                    help="run the shared-prefix traffic section: refcounted "
                         "CoW page sharing on vs off on one schedule, with "
                         "a static/reference oracle and a 1/2/4/8-shard "
                         "device sweep, all token-identical")
    ap.add_argument("--min-prefix-hit-rate", type=float, default=None,
                    help="prefix gate: exit non-zero unless the cache-on "
                         "run's prefix hit rate (hits / admissions) is >= "
                         "this (implies --prefix-mix)")
    ap.add_argument("--max-computed-ratio", type=float, default=None,
                    help="prefix gate: exit non-zero unless cache-on "
                         "computed/served tokens <= this while the "
                         "cache-off ratio is exactly 1.0 (implies "
                         "--prefix-mix)")
    args = ap.parse_args(argv)
    if args.min_prefix_hit_rate is not None \
            or args.max_computed_ratio is not None:
        args.prefix_mix = True
    if args.chaos_only and args.chaos_seed is None:
        ap.error("--chaos-only requires --chaos-seed")

    cfg, params = _setup()
    if args.trace:
        arrivals = trace_arrivals(args.trace, vocab=cfg.vocab,
                                  seed=args.seed)
        for a in arrivals:
            if a.prompt_len + a.max_new > S_MAX:
                raise SystemExit(
                    f"--trace: request ({a.prompt_len}+{a.max_new}) "
                    f"exceeds the bench max_len {S_MAX}")
    else:
        arrivals = poisson_arrivals(
            args.requests, args.arrival_rate, seed=args.seed,
            vocab=cfg.vocab, max_prompt=MAX_PROMPT, max_output=MAX_OUTPUT)

    chaos = (run_chaos(params, cfg, args.chaos_seed, args.seed)
             if args.chaos_seed is not None else None)
    if args.chaos_only:
        report_chaos(chaos)
        if args.json:
            record = {"schema": "bench-serve/v3", "chaos": chaos}
            with open(args.json, "w") as f:
                json.dump(record, f, indent=2)
            print(f"\nwrote {args.json}")
        g = chaos["gate"]
        failed = False
        for name in ("invariants_ok", "survivor_tokens_match",
                     "all_kinds_injected"):
            if not g[name]:
                print(f"GATE FAIL: chaos {name} is False"
                      + (f" ({chaos['invariant_violation']})"
                         if name == "invariants_ok" else ""),
                      file=sys.stderr)
                failed = True
        if not failed:
            print(f"GATE OK: {chaos['invariant_checks']} invariant audits "
                  f"clean, {chaos['survivors']} survivors token-identical "
                  f"to the fault-free run")
        sys.exit(1 if failed else 0)

    ast = arrival_stats(arrivals)
    modes = run_modes(params, cfg, arrivals,
                      slo_ttft=args.max_p99_ttft_cycles)
    ident = run_identity(params, cfg, arrivals)
    overload = (run_overload(params, cfg, args.seed, args.overload_band)
                if args.overload_sweep else None)
    prefix = (run_prefix(params, cfg, args.seed)
              if args.prefix_mix else None)
    report(modes, ident, ast, args.wall_clock)
    if overload is not None:
        report_overload(overload)
    if chaos is not None:
        report_chaos(chaos)
    if prefix is not None:
        report_prefix(prefix)

    ooo, static = modes["ooo"], modes["static"]
    slo_differentiates = True
    if args.max_p99_ttft_cycles is not None:
        slo_differentiates = (
            static["ttft_p99"] > args.max_p99_ttft_cycles
            or (ooo["ttft_p99"] < static["ttft_p99"]
                and ooo["goodput_tokens_per_tick"]
                >= static["goodput_tokens_per_tick"]))

    if args.json:
        record = {
            "schema": "bench-serve/v3",
            "config": {
                "arch": "tinyllama-1.1b", "reduced": True,
                "requests": ast["count"],
                "arrival_rate": None if args.trace else args.arrival_rate,
                "trace": args.trace, "seed": args.seed,
                "slots": SLOTS, "max_len": S_MAX, "seq_tile": SEQ_TILE,
                "chunk_tokens": CHUNK_TOKENS,
                "max_prompt": MAX_PROMPT, "max_output": MAX_OUTPUT,
                "clock": "virtual (1 tick = 1 pool traversal; idle "
                         "macro-cycle = 1 tick)",
            },
            "arrivals": ast,
            "per_mode": {m: modes[m] for m in SCHEDULE_MODES},
            "identity": ident,
            "overload": overload,
            "chaos": chaos,
            "prefix": prefix,
            "gate": {
                "max_p99_ttft_cycles": args.max_p99_ttft_cycles,
                "min_prefix_hit_rate": args.min_prefix_hit_rate,
                "max_computed_ratio": args.max_computed_ratio,
                "min_goodput": args.min_goodput,
                "ooo_ttft_p99": ooo["ttft_p99"],
                "static_ttft_p99": static["ttft_p99"],
                "ooo_goodput": ooo["goodput_tokens_per_tick"],
                "static_goodput": static["goodput_tokens_per_tick"],
                "slo_differentiates": slo_differentiates,
                "schedule_tokens_match": modes["tokens_match"],
                "open_vs_closed_tokens_match":
                    ident["open_vs_closed_tokens_match"],
            },
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\nwrote {args.json}")

    failed = False
    if args.max_p99_ttft_cycles is not None:
        if ooo["ttft_p99"] > args.max_p99_ttft_cycles:
            print(f"GATE FAIL: ooo p99 TTFT {ooo['ttft_p99']:.1f} ticks > "
                  f"{args.max_p99_ttft_cycles}", file=sys.stderr)
            failed = True
        elif not slo_differentiates:
            print(f"GATE FAIL: SLO no longer differentiates — static p99 "
                  f"TTFT {static['ttft_p99']:.1f} also meets "
                  f"{args.max_p99_ttft_cycles} and ooo is not strictly "
                  f"better (ooo {ooo['ttft_p99']:.1f} ticks / "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick vs "
                  f"static {static['ttft_p99']:.1f} / "
                  f"{static['goodput_tokens_per_tick']:.3f})",
                  file=sys.stderr)
            failed = True
        else:
            how = ("misses the SLO"
                   if static["ttft_p99"] > args.max_p99_ttft_cycles
                   else "strictly worse")
            print(f"GATE OK: ooo p99 TTFT {ooo['ttft_p99']:.1f} <= "
                  f"{args.max_p99_ttft_cycles} ticks; static "
                  f"{static['ttft_p99']:.1f} ({how})")
    if args.min_goodput is not None:
        if ooo["goodput_tokens_per_tick"] < args.min_goodput:
            print(f"GATE FAIL: ooo goodput "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick < "
                  f"{args.min_goodput}", file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: ooo goodput "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick >= "
                  f"{args.min_goodput} (static "
                  f"{static['goodput_tokens_per_tick']:.3f})")
    if args.max_p99_ttft_cycles is not None or args.min_goodput is not None:
        if not modes["tokens_match"]:
            print("GATE FAIL: ooo and static disagree on generated tokens",
                  file=sys.stderr)
            failed = True
        if not ident["open_vs_closed_tokens_match"]:
            print("GATE FAIL: open-loop admission with infinite slots "
                  "does not reproduce closed-loop tokens", file=sys.stderr)
            failed = True
    if overload is not None:
        g = overload["gate"]
        if not g["protected_within_band"]:
            print(f"GATE FAIL: protected goodput fell to "
                  f"{g['protected_min_vs_plateau']:.2f}x of the plateau "
                  f"(band: >= {1.0 - args.overload_band:.2f}x)",
                  file=sys.stderr)
            failed = True
        if not g["baseline_degrades"]:
            print(f"GATE FAIL: the no-shedding baseline held "
                  f"{g['baseline_deepest_vs_plateau']:.2f}x of the plateau "
                  f"at the deepest rate — the sweep is not actually "
                  f"above saturation", file=sys.stderr)
            failed = True
        if not g["shed_untouched"]:
            print("GATE FAIL: a shed request consumed engine resources "
                  "(admit stamp, slot, tokens, or pool pages)",
                  file=sys.stderr)
            failed = True
        if not g["survivor_tokens_match"]:
            print("GATE FAIL: a surviving request's tokens differ from "
                  "the pressure-free run", file=sys.stderr)
            failed = True
        if not failed:
            print(f"GATE OK: protected goodput >= "
                  f"{g['protected_min_vs_plateau']:.2f}x plateau at every "
                  f"overload rate; baseline fell to "
                  f"{g['baseline_deepest_vs_plateau']:.2f}x; sheds "
                  f"untouched; survivors token-identical")
    if chaos is not None:
        for name in ("invariants_ok", "survivor_tokens_match",
                     "all_kinds_injected"):
            if not chaos["gate"][name]:
                print(f"GATE FAIL: chaos {name} is False"
                      + (f" ({chaos['invariant_violation']})"
                         if name == "invariants_ok" else ""),
                      file=sys.stderr)
                failed = True
    if prefix is not None:
        g = prefix["gate_inputs"]
        for name in ("tokens_match_on_off", "tokens_match_oracle",
                     "device_sweep_tokens_match"):
            if not g[name]:
                print(f"GATE FAIL: prefix {name} is False — sharing "
                      f"changed generated tokens", file=sys.stderr)
                failed = True
        if args.max_computed_ratio is not None:
            if g["ratio_on"] > args.max_computed_ratio:
                print(f"GATE FAIL: cache-on computed/served "
                      f"{g['ratio_on']:.3f} > {args.max_computed_ratio} — "
                      f"the prefix cache is not deduplicating",
                      file=sys.stderr)
                failed = True
            elif not g["off_ratio_is_one"]:
                print(f"GATE FAIL: cache-off computed/served "
                      f"{g['ratio_off']:.3f} != 1.0 — tokens skipped with "
                      f"the cache disabled", file=sys.stderr)
                failed = True
            else:
                print(f"GATE OK: computed/served {g['ratio_on']:.3f} <= "
                      f"{args.max_computed_ratio} with the cache on, "
                      f"exactly 1.0 off")
        if args.min_prefix_hit_rate is not None:
            if g["hit_rate"] < args.min_prefix_hit_rate:
                print(f"GATE FAIL: prefix hit rate {g['hit_rate']:.2f} < "
                      f"{args.min_prefix_hit_rate}", file=sys.stderr)
                failed = True
            else:
                print(f"GATE OK: prefix hit rate {g['hit_rate']:.2f} >= "
                      f"{args.min_prefix_hit_rate}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
