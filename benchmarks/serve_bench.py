"""Open-loop serving bench: latency SLOs under real traffic (bench-serve/v1).

Every other bench in this repo is CLOSED-loop — all requests submitted up
front, ratio gates on traversals/tiles/traces. This one drives the engine
the way production traffic does: requests ARRIVE on a seeded virtual-clock
schedule (``serve/traffic.py``: Poisson arrivals, heavy-tailed
prompt/output lengths over the config registry's scenario spread, or a
JSONL trace replay), wait in the arrival-ordered admission queue while
slots are contended, and the engine runs macro-cycles continuously.

**The clock is virtual**: one tick per pool traversal (idle macro-cycles
cost one tick), so every latency number is deterministic on CI and prices
exactly what the paper prices — a scheduler that spends more pool
traversals per macro-cycle (``schedule_mode="static"``, the rigid
one-traversal-per-phase walk) burns more ticks for the same work, its
queues grow, and its TAIL latency blows up. The bench serves the SAME
arrival schedule under ``ooo`` (the PR-6 dependency-tracked port-mix
scheduler) and ``static`` and reports, per mode: p50/p99 TTFT, p50/p99
per-token latency, p50/p99 queue delay (all in virtual ticks; wall-clock
columns opt-in via ``--wall-clock``), goodput (tokens from SLO-meeting
requests per tick), queue-depth mean/max, and the engine's
slot-contention / eviction-pressure counters.

A second section checks the open-loop contract itself: with "infinite"
slots (one per request) the open-loop admission path must reproduce the
closed-loop token output EXACTLY — arrival timing may never change what
gets generated, only when.

CI gate (.github/workflows/ci.yml ``bench-serve``, via
benchmarks/ci_gates.sh; schema + semantics in benchmarks/README.md):

    python benchmarks/serve_bench.py --json BENCH_serve.json \
        --max-p99-ttft-cycles T --min-goodput G

exits non-zero unless, at the same arrival rate, ``ooo`` meets BOTH SLOs
(p99 TTFT <= T virtual ticks, goodput >= G tokens/tick) AND the SLO still
differentiates the schedulers: ``static`` misses the p99-TTFT SLO, or
``ooo`` is strictly better on p99 TTFT with at-least-equal goodput. Token
identity (open vs closed loop, and per-request ooo vs static) is part of
the gate; ``BENCH_serve.json`` is written before the gate exits so the
record uploads on failures too.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter

import jax
import numpy as np

from repro.configs import registry
from repro.models import init_params
from repro.serve.engine import MultiPortEngine
from repro.serve.traffic import drive, poisson_arrivals, trace_arrivals

# workload geometry (shared with engine_bench's tile sweep): small enough
# for CPU interpret mode, contended enough that queues actually form
S_MAX = 64
SEQ_TILE = 8
CHUNK_TOKENS = 8
SLOTS = 4
MAX_PROMPT = 40
MAX_OUTPUT = 10

SCHEDULE_MODES = ("ooo", "static")


def _setup():
    cfg = registry.get("tinyllama-1.1b", reduced=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _pct(vals, q) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) \
        if vals else 0.0


def summarize(eng: MultiPortEngine, qdepth: list, wall: float,
              slo_ttft=None) -> dict:
    """Latency/goodput record for one open-loop run. Goodput counts only
    tokens from requests whose TTFT met ``slo_ttft`` (all tokens when no
    SLO is given); throughput counts everything."""
    reqs = eng.finished
    ttft = [r.ttft_ticks for r in reqs if r.ttft_ticks is not None]
    tpot = [r.tpot_ticks for r in reqs if r.tpot_ticks is not None]
    qdelay = [r.admit_tick - r.arrival_tick for r in reqs
              if r.admit_tick is not None]
    toks = sum(len(r.generated) for r in reqs)
    ticks = max(eng.vclock, 1)
    good = toks if slo_ttft is None else sum(
        len(r.generated) for r in reqs
        if r.ttft_ticks is not None and r.ttft_ticks <= slo_ttft)
    ttft_wall = [r.t_first - r.t_submit for r in reqs
                 if r.t_first is not None]
    return {
        "requests_finished": len(reqs),
        "tokens": toks,
        "total_ticks": eng.vclock,
        "cycles": eng.cycles,
        "pool_traversals": eng.pool_traversals,
        "traversals_per_cycle": eng.pool_traversals / max(eng.cycles, 1),
        "ttft_p50": _pct(ttft, 50), "ttft_p99": _pct(ttft, 99),
        "tpot_p50": _pct(tpot, 50), "tpot_p99": _pct(tpot, 99),
        "queue_delay_p50": _pct(qdelay, 50),
        "queue_delay_p99": _pct(qdelay, 99),
        "goodput_tokens_per_tick": good / ticks,
        "throughput_tokens_per_tick": toks / ticks,
        "queue_depth_mean": float(np.mean(qdepth)) if qdepth else 0.0,
        "queue_depth_max": int(max(qdepth)) if qdepth else 0,
        "peak_queue_depth": eng.admission.peak_depth,
        "slot_contention_cycles": eng.slot_contention_cycles,
        "evict_pressure_admissions": eng.evict_pressure_admissions,
        "evictions": eng.evictions,
        "coschedule_frac": eng.coschedule_frac,
        # wall-clock column: recorded always, reported via --wall-clock,
        # never gated (virtual ticks are the deterministic SLO base)
        "wall": {
            "seconds": wall,
            "tokens_per_s": toks / max(wall, 1e-9),
            "ttft_p50_s": _pct(ttft_wall, 50),
            "ttft_p99_s": _pct(ttft_wall, 99),
        },
    }


def _tokens_by_index(reqs) -> dict:
    """rid -> generated tokens; rids are submission-ordered in every run
    of the same arrival list, so they align across modes."""
    return {r.rid: tuple(r.generated) for r in reqs}


def run_modes(params, cfg, arrivals, slo_ttft=None) -> dict:
    """The same arrival schedule under each schedule mode, contended
    (slots = SLOTS, no growth): the open-loop pressure run."""
    out = {}
    toks = {}
    for mode in SCHEDULE_MODES:
        eng = MultiPortEngine(params, cfg, slots=SLOTS, max_slots=SLOTS,
                              max_len=S_MAX, seq_tile=SEQ_TILE,
                              chunk_tokens=CHUNK_TOKENS,
                              schedule_mode=mode)
        qdepth, wall = drive(eng, arrivals)
        s = summarize(eng, qdepth, wall, slo_ttft=slo_ttft)
        s["schedule_mode"] = mode
        out[mode] = s
        toks[mode] = _tokens_by_index(eng.finished)
    out["tokens_match"] = toks["ooo"] == toks["static"]
    return out


def run_identity(params, cfg, arrivals) -> dict:
    """Open-loop admission with 'infinite' slots (one per request) must
    reproduce the closed-loop token output exactly: arrival timing decides
    WHEN work happens, never WHAT is generated."""
    n = len(arrivals)
    open_eng = MultiPortEngine(params, cfg, slots=n, max_slots=n,
                               max_len=S_MAX, seq_tile=SEQ_TILE,
                               chunk_tokens=CHUNK_TOKENS)
    drive(open_eng, arrivals)
    closed_eng = MultiPortEngine(params, cfg, slots=n, max_slots=n,
                                 max_len=S_MAX, seq_tile=SEQ_TILE,
                                 chunk_tokens=CHUNK_TOKENS)
    for a in arrivals:
        closed_eng.submit(list(a.prompt), a.max_new, arrival_tick=0)
    closed_eng.run(max_cycles=20000)
    to, tc = (_tokens_by_index(open_eng.finished),
              _tokens_by_index(closed_eng.finished))
    return {
        "slots": n,
        "open_finished": len(open_eng.finished),
        "closed_finished": len(closed_eng.finished),
        "open_vs_closed_tokens_match": (
            to == tc and len(open_eng.finished) == n),
    }


def arrival_stats(arrivals) -> dict:
    plens = [a.prompt_len for a in arrivals]
    olens = [a.max_new for a in arrivals]
    return {
        "count": len(arrivals),
        "first_tick": arrivals[0].arrival_tick if arrivals else 0,
        "last_tick": arrivals[-1].arrival_tick if arrivals else 0,
        "prompt_len": {"min": min(plens), "max": max(plens),
                       "mean": float(np.mean(plens))},
        "max_new": {"min": min(olens), "max": max(olens),
                    "mean": float(np.mean(olens))},
        "scenarios": dict(sorted(Counter(
            a.scenario for a in arrivals).items())),
    }


def report(modes: dict, ident: dict, ast: dict, wall_clock: bool) -> None:
    print("# open-loop serving: latency SLOs under the virtual clock "
          "(1 tick = 1 pool traversal)")
    print(f"arrivals: {ast['count']} over ticks "
          f"[{ast['first_tick']}, {ast['last_tick']}], prompt_len "
          f"{ast['prompt_len']['min']}..{ast['prompt_len']['max']} "
          f"(mean {ast['prompt_len']['mean']:.1f}), max_new "
          f"{ast['max_new']['min']}..{ast['max_new']['max']} "
          f"(mean {ast['max_new']['mean']:.1f})")
    cols = ("mode,ttft_p50,ttft_p99,tpot_p50,tpot_p99,qdelay_p99,"
            "goodput_tok/tick,ticks,cycles,trav/cycle,qdepth_mean/max,"
            "contention,evict_pressure")
    if wall_clock:
        cols += ",wall_s,wall_tok/s,wall_ttft_p99_s"
    print(cols)
    for mode in SCHEDULE_MODES:
        s = modes[mode]
        row = (f"{mode},{s['ttft_p50']:.1f},{s['ttft_p99']:.1f},"
               f"{s['tpot_p50']:.2f},{s['tpot_p99']:.2f},"
               f"{s['queue_delay_p99']:.1f},"
               f"{s['goodput_tokens_per_tick']:.3f},{s['total_ticks']},"
               f"{s['cycles']},{s['traversals_per_cycle']:.3f},"
               f"{s['queue_depth_mean']:.2f}/{s['queue_depth_max']},"
               f"{s['slot_contention_cycles']},"
               f"{s['evict_pressure_admissions']}")
        if wall_clock:
            w = s["wall"]
            row += (f",{w['seconds']:.2f},{w['tokens_per_s']:.1f},"
                    f"{w['ttft_p99_s']:.3f}")
        print(row)
    print(f"tokens_match(ooo==static),{modes['tokens_match']}")
    print()
    print("# open-loop == closed-loop identity (infinite slots)")
    print(f"slots,{ident['slots']},open_finished,{ident['open_finished']},"
          f"closed_finished,{ident['closed_finished']},tokens_match,"
          f"{ident['open_vs_closed_tokens_match']}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=14,
                    help="open-loop arrivals to generate (ignored with "
                         "--trace)")
    ap.add_argument("--arrival-rate", type=float, default=0.25,
                    help="Poisson arrival rate in requests per virtual "
                         "tick (pool traversal)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="replay a JSONL arrival trace instead of the "
                         "seeded Poisson generator")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the bench-serve/v1 record "
                         "(BENCH_serve.json)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="report the wall-clock columns alongside the "
                         "virtual-clock ones (recorded in the JSON either "
                         "way; never gated)")
    ap.add_argument("--max-p99-ttft-cycles", type=float, default=None,
                    help="SLO gate: exit non-zero unless ooo's p99 TTFT "
                         "(virtual-clock ticks) is <= this AND the SLO "
                         "still differentiates ooo from static")
    ap.add_argument("--min-goodput", type=float, default=None,
                    help="SLO gate: exit non-zero if ooo's goodput "
                         "(tokens/tick from SLO-meeting requests) drops "
                         "below this")
    args = ap.parse_args(argv)

    cfg, params = _setup()
    if args.trace:
        arrivals = trace_arrivals(args.trace, vocab=cfg.vocab,
                                  seed=args.seed)
        for a in arrivals:
            if a.prompt_len + a.max_new > S_MAX:
                raise SystemExit(
                    f"--trace: request ({a.prompt_len}+{a.max_new}) "
                    f"exceeds the bench max_len {S_MAX}")
    else:
        arrivals = poisson_arrivals(
            args.requests, args.arrival_rate, seed=args.seed,
            vocab=cfg.vocab, max_prompt=MAX_PROMPT, max_output=MAX_OUTPUT)

    ast = arrival_stats(arrivals)
    modes = run_modes(params, cfg, arrivals,
                      slo_ttft=args.max_p99_ttft_cycles)
    ident = run_identity(params, cfg, arrivals)
    report(modes, ident, ast, args.wall_clock)

    ooo, static = modes["ooo"], modes["static"]
    slo_differentiates = True
    if args.max_p99_ttft_cycles is not None:
        slo_differentiates = (
            static["ttft_p99"] > args.max_p99_ttft_cycles
            or (ooo["ttft_p99"] < static["ttft_p99"]
                and ooo["goodput_tokens_per_tick"]
                >= static["goodput_tokens_per_tick"]))

    if args.json:
        record = {
            "schema": "bench-serve/v1",
            "config": {
                "arch": "tinyllama-1.1b", "reduced": True,
                "requests": ast["count"],
                "arrival_rate": None if args.trace else args.arrival_rate,
                "trace": args.trace, "seed": args.seed,
                "slots": SLOTS, "max_len": S_MAX, "seq_tile": SEQ_TILE,
                "chunk_tokens": CHUNK_TOKENS,
                "max_prompt": MAX_PROMPT, "max_output": MAX_OUTPUT,
                "clock": "virtual (1 tick = 1 pool traversal; idle "
                         "macro-cycle = 1 tick)",
            },
            "arrivals": ast,
            "per_mode": {m: modes[m] for m in SCHEDULE_MODES},
            "identity": ident,
            "gate": {
                "max_p99_ttft_cycles": args.max_p99_ttft_cycles,
                "min_goodput": args.min_goodput,
                "ooo_ttft_p99": ooo["ttft_p99"],
                "static_ttft_p99": static["ttft_p99"],
                "ooo_goodput": ooo["goodput_tokens_per_tick"],
                "static_goodput": static["goodput_tokens_per_tick"],
                "slo_differentiates": slo_differentiates,
                "schedule_tokens_match": modes["tokens_match"],
                "open_vs_closed_tokens_match":
                    ident["open_vs_closed_tokens_match"],
            },
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2)
        print(f"\nwrote {args.json}")

    failed = False
    if args.max_p99_ttft_cycles is not None:
        if ooo["ttft_p99"] > args.max_p99_ttft_cycles:
            print(f"GATE FAIL: ooo p99 TTFT {ooo['ttft_p99']:.1f} ticks > "
                  f"{args.max_p99_ttft_cycles}", file=sys.stderr)
            failed = True
        elif not slo_differentiates:
            print(f"GATE FAIL: SLO no longer differentiates — static p99 "
                  f"TTFT {static['ttft_p99']:.1f} also meets "
                  f"{args.max_p99_ttft_cycles} and ooo is not strictly "
                  f"better (ooo {ooo['ttft_p99']:.1f} ticks / "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick vs "
                  f"static {static['ttft_p99']:.1f} / "
                  f"{static['goodput_tokens_per_tick']:.3f})",
                  file=sys.stderr)
            failed = True
        else:
            how = ("misses the SLO"
                   if static["ttft_p99"] > args.max_p99_ttft_cycles
                   else "strictly worse")
            print(f"GATE OK: ooo p99 TTFT {ooo['ttft_p99']:.1f} <= "
                  f"{args.max_p99_ttft_cycles} ticks; static "
                  f"{static['ttft_p99']:.1f} ({how})")
    if args.min_goodput is not None:
        if ooo["goodput_tokens_per_tick"] < args.min_goodput:
            print(f"GATE FAIL: ooo goodput "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick < "
                  f"{args.min_goodput}", file=sys.stderr)
            failed = True
        else:
            print(f"GATE OK: ooo goodput "
                  f"{ooo['goodput_tokens_per_tick']:.3f} tok/tick >= "
                  f"{args.min_goodput} (static "
                  f"{static['goodput_tokens_per_tick']:.3f})")
    if args.max_p99_ttft_cycles is not None or args.min_goodput is not None:
        if not modes["tokens_match"]:
            print("GATE FAIL: ooo and static disagree on generated tokens",
                  file=sys.stderr)
            failed = True
        if not ident["open_vs_closed_tokens_match"]:
            print("GATE FAIL: open-loop admission with infinite slots "
                  "does not reproduce closed-loop tokens", file=sys.stderr)
            failed = True
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
