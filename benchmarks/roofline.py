"""§Roofline: three-term model per (arch x shape x mesh) from dry-run
artifacts (artifacts/dryrun/*.json — written by repro.launch.dryrun).

Terms (seconds per step, PER CHIP; HLO numbers are already per-device):
  compute    = dot_flops / 197e12            (v5e bf16 peak)
  memory     = traffic_bytes / 819e9         (HBM bw)
  collective = wire_bytes / 50e9             (one ICI link, conservative;
               ring multipliers: all-reduce 2x, others 1x)

Also reports MODEL_FLOPS (6*N_active*D train, 2*N_active*D inference),
the useful-compute ratio MODEL_FLOPS / (dot_flops * chips), the dominant
term, and a what-would-move-it hint.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import registry

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_RING_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def wire_bytes(coll: dict) -> float:
    return sum(_RING_MULT[k] * v for k, v in coll.items())


def model_flops(arch: str, kind: str, tokens: int) -> float:
    cfg = registry.get(arch)
    n = cfg.active_param_count()
    return (6.0 if kind == "train" else 2.0) * n * tokens


def analyze_record(rec: dict) -> dict:
    h = rec["hlo"]
    chips = rec["chips"]
    compute = h["dot_flops"] / PEAK_FLOPS
    memory = h["traffic_bytes"] / HBM_BW
    coll = wire_bytes(h["collective_bytes"]) / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["kind"], rec["tokens_per_step"])
    useful = mf / max(h["dot_flops"] * chips, 1.0)
    step_time = max(terms.values())
    mfu = (mf / chips / PEAK_FLOPS) / max(step_time, 1e-30)
    hints = {
        "compute": "raise MFU: cut non-model dot flops (remat policy, "
                   "attention chunking) or use a faster layout",
        "memory": "cut HBM traffic: bf16 intermediates, fuse elementwise "
                  "chains, larger per-step tiles, avoid scan-carry copies",
        "collective": "reshard: fewer all-gathers (FSDP prefetch), 2D-shard "
                      "logits, hierarchical/int8 cross-pod reduce",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": h["dot_flops"] * chips,
        "useful_ratio": useful, "roofline_mfu": mfu,
        "memory_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
        "hint": hints[dominant],
    }


def load_all(art_dir: str = "artifacts/dryrun", variant: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        base = os.path.basename(path)[:-5]
        is_variant = base.count("__") > 2
        if (variant and variant not in base) or (not variant and is_variant):
            continue
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "error": rec.get("error")})
            continue
        rows.append(analyze_record(rec))
    return rows


def main() -> None:
    rows = load_all()
    print("# roofline terms per cell (seconds/step/chip; v5e constants)")
    print("arch,shape,mesh,chips,compute_s,memory_s,collective_s,dominant,"
          "useful_ratio,roofline_mfu")
    for r in rows:
        if "error" in r:
            print(f"{r['arch']},{r['shape']},{r['mesh']},ERROR")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
              f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
              f"{r['collective_s']:.3e},{r['dominant']},"
              f"{r['useful_ratio']:.3f},{r['roofline_mfu']:.4f}")


if __name__ == "__main__":
    main()
